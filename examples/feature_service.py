"""FeatureService walkthrough: pump-driven, coalescing ADV feature serving.

Serving architecture — every request flows through the same pipeline::

    request --> bucket --> unified coalescer --> pump --> launch
    submit()    chunk to    ONE queue; up to      background thread: the
    returns a   static      `coalesce` chunks     ONLY dispatcher. Keeps
    ticket      bucket      of one bucket shape   `prefetch` launches in
                shapes      share a launch        flight, retires oldest

``submit`` only enqueues; ``poll``/``result``/``drain`` only inspect or
wait. Over a packed plan (``FeaturePlan(packed=True)``) the word streams
are device-resident and EVERY chunk — word-aligned scan range or arbitrary
row set — is served by the indexed gather kernel, which computes word index
+ bit offset on the device. ``stats['bytes_h2d']`` therefore reports INDEX
bytes (4B x padded rows, independent of column count): random requests ship
indices, never codes. int32 plans still ship (C, bucket) code slices.

Mesh-sharded serving (``sharded=True`` over a packed plan): the table's
IMCU partitions become per-shard RESIDENT word-stream slices, each
committed to its own mesh device (``jax.device_put`` placement via
``repro.distributed.sharding.serve_devices`` — round-robin when shards and
devices differ in count). A request's rows are bucketed by owning IMCU at
submit; whole-shard requests (the clustered per-user pattern) route with
two scalar bisects and no per-row work. One multiplexing pump keeps
``prefetch`` launches in flight PER SHARD and coalesces each shard's
same-bucket chunks into single launches, so independent shards' gathers
run concurrently on their own devices — compute moves to the shard that
owns the data, never shard bytes to one compute device. ``linger_us``
bounds how long a pump holds a partial coalescing group open under light
load (fuller groups for a bounded latency); ``drain()`` force-flushes
lingering groups. Per-shard attribution: ``stats['shard_launches'/
'shard_bytes_h2d']`` and ``plan.stats['per_shard']`` roll up into totals.

Adaptive shard management (the paper's feedback cycle, applied to layout):
a mesh service's shard set is no longer frozen at plan-build time. A load
monitor fed by the per-shard stats deltas (request-rate EWMA over
``stats['shard_batches']``) drives two policies, automatically every
``rebalance_every`` launches or on demand via ``service.rebalance()``:

- hot-key skew -> **replicate**: when one shard's request rate runs
  ``hot_factor`` x the mean, its resident word stream is committed to the
  least-loaded device too and the pump round-robins that shard's launches
  across the copies (read fan-out; every copy re-syncs from the plan's
  versioned words after a refresh, so writes invalidate replicas for
  free). Cold shards shed their replicas again.
- streaming growth -> **re-shard**: appends extend only the open tail
  shard; past ``row_budget`` rows the tail splits at a word-aligned cut,
  the new shard's slice moves to an under-loaded device, and the routing
  table swaps atomically — queued chunks are re-routed (split when they
  straddle the cut) without dropping or reordering a single ticket.

Tiered residency (``hbm_budget_bytes``): the same monitor extends from
"replicate hot" to a full residency ladder, so the table no longer has
to fit on device. Every shard is **hot** (device-resident packed words),
**warm** (host packed words, served by the host-gather slow path — a
small thread pool fans wide gathers out when the host has spare cores)
or **cold** (RLE runs only, ~bits/32 of the word bytes on run-heavy
columns); a per-device byte budget caps what stays hot. Budget pressure
demotes the coldest-EWMA residents, warm shards idle for ``cold_after``
monitor ticks compress to runs, and traffic on an off-device shard
triggers ASYNC promotion on the pump (cold rehydrates first; a full
device displaces colder residents). Every miss serves bit-exact through
the host path while the promotion races — availability never dips, and
tables many times the device budget serve near hot-tier throughput
under skewed access.

Builds a columnar table, compiles a FeaturePlan (device-resident fused ADV
tables), then serves featurization requests ten ways:

1. request queue with tickets (submit / result),
2. arbitrary-row ("millions of users") lookups over a packed plan — the
   coalescer folds them into single index-only launches,
3. mesh-sharded serving: per-IMCU resident shards + routed pump launches
   (run under XLA_FLAGS=--xla_force_host_platform_device_count=4 to see
   true multi-device placement on CPU),
4. skewed traffic -> monitor -> replicate -> re-shard: the adaptive cycle
   above, driven by Zipf-hot lookups and a streaming append,
5. predicate-filtered serving (query pushdown): ``submit(where=...)``
   evaluates dictionary-code predicates directly on the resident packed
   words (scan -> compact -> gather, one device pipeline — no decoded
   code stream, no host round trip), plus dict-aware masked aggregates
   (``count_where`` / ``groupby_where`` / ``agg_where``),
6. failure handling: a chaos-injected launch fault stream on one shard —
   retries + replica failover keep every ticket completing; the breaker
   marks the sick stream, ``rebalance()`` re-replicates around it, and
   when NO replica exists only the faulted tickets resolve to typed
   ``ServeError``s (the service keeps serving; ``deadline_ms``/
   ``timeout=`` bound every wait). Phase 2 extends this past stream
   faults: a killed DEVICE (6b) has its streams evicted, missed shards
   host-gather-served, and each orphan rebuilt on a survivor from the
   host packed words; a STALLED launch (6c) is raced by a speculative
   duplicate on another healthy stream once its wait crosses the hedge
   cutoff — first buffer ready wins, the straggler is discarded. A pump
   infrastructure crash is supervised too: the pump restarts with the
   ledger intact (``FaultPolicy.pump_restarts`` bounds the budget),
7. streaming double-buffered iteration (serve_stream),
8. a streaming insert followed by an incremental plan refresh — only the
   columns whose dictionaries changed are re-put on device; appended rows
   extend the open-ended LAST shard, so sharded services keep serving,
9. tiered residency: the hot/warm/cold shard ladder above, driven by an
   ``hbm_budget_bytes`` cap half the table's size — explicit demotion
   down to RLE runs, a bit-exact cold miss, and async promotion back,
10. the production front door: a ``FeatureFrontend`` over per-tenant
    request classes (``interactive``/``batch``/``background``) — the
    pump schedules launches by class priority with anti-starvation
    aging and per-class coalescing/linger, admission is bounded per
    class (``max_inflight`` + ``queue_depth``, then a typed
    ``Overloaded`` with a retry-after hint), and per-class streaming
    latency histograms feed the stats/SLO endpoint (unbiased p99s —
    every completed ticket, not a sliding sample window).

Run:  PYTHONPATH=src python examples/feature_service.py
"""
import time

import numpy as np

from repro.columnar import Table
from repro.core import FeatureSet, FeaturePlan
from repro.serve import FeatureFrontend, FeatureService, Overloaded


def main() -> None:
    rng = np.random.default_rng(0)
    n = 100_000
    table = Table.from_data({
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
    }, imcu_rows=1 << 15)
    features = (FeatureSet()
                .add("age", "zscore")
                .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
                .add("state", "onehot")
                .add("income", "minmax"))
    plan = FeaturePlan(table, features)
    print(f"plan: {len(plan.plans)} columns, out_dim={plan.out_dim}, "
          f"resident_tables={plan.bytes_resident_tables()}B, "
          f"imcus={table['age'].n_imcus}")

    # 1. ticketed request queue (double-buffered dispatch under the hood)
    svc = FeatureService(plan, prefetch=2)
    t0 = time.perf_counter()
    tickets = [svc.submit(rng.integers(0, n, 512)) for _ in range(64)]
    feats = svc.result(tickets[0])
    svc.drain()
    wall = time.perf_counter() - t0
    print(f"served 64 requests: first result {feats.shape}, "
          f"{svc.throughput_stats(wall)['rows_per_s']:.0f} rows/s")

    # 2. packed plan: arbitrary-row requests ship ONLY indices — the pump
    # coalesces them and the device computes word/bit offsets itself
    with FeatureService(FeaturePlan(table, features, packed=True),
                        prefetch=2, buckets=(512,)) as svcp:
        tickets = [svcp.submit(rng.integers(0, n, 512)) for _ in range(64)]
        svcp.drain()
        st = svcp.stats
        print(f"packed random serving: {st['launches']} launches for "
              f"{st['requests']} requests, h2d={st['bytes_h2d']}B "
              f"(indices only, ~4B/row x {svcp.coalesce} coalesced)")

    # 3. mesh-sharded serving: per-IMCU resident word-stream shards, each
    # on its own device; rows route to their owning shard and each shard's
    # launches coalesce independently (linger trades <=1ms for fuller
    # groups). Requests here are clustered per-user blocks — the whole
    # request lands on one shard, so routing is two bisects.
    from repro.distributed.sharding import serve_mesh
    mesh = serve_mesh()                # 1-D ('shard',) mesh over the devices
    plan_mesh = FeaturePlan(table, features, packed=True)
    with FeatureService(plan_mesh, sharded=True, buckets=(512,),
                        coalesce=8, linger_us=1000,
                        devices=mesh.devices.tolist()) as svcs:
        for s in rng.integers(0, (n - 512) // 32, 64) * 32:
            svcs.submit(np.arange(s, s + 512))
        svcs.drain()
        st = svcs.stats
        print(f"mesh serving: {svcs.n_shards} shards over "
              f"{mesh.shape['shard']} mesh device(s), "
              f"launches per shard={st['shard_launches']}, "
              f"h2d per shard={st['shard_bytes_h2d']}B (indices only); "
              f"plan per-shard words_put="
              f"{[s['words_put'] for s in plan_mesh.stats['per_shard']]}")

    # 4. adaptive shard management: skewed traffic -> monitor -> replicate
    # -> re-shard. Zipf-hot lookups concentrate on shard 0; the monitor's
    # request-rate EWMA flags it and fans reads out over a replica. A
    # streaming append then pushes the open tail past its row budget and
    # the next rebalance splits it — all while requests keep flowing.
    plan_ad = FeaturePlan(table, features, packed=True)
    with FeatureService(plan_ad, sharded=True, buckets=(512,), coalesce=8,
                        linger_us=1000, rebalance_every=6,
                        row_budget=1 << 15, hot_factor=2.0,
                        max_replicas=2) as svca:
        hot = rng.integers(0, (1 << 15) // 32 - 16, 96) * 32   # shard 0
        for s in hot:
            svca.submit(np.arange(s, s + 512))
        svca.drain()                       # pump ticks the monitor en route
        print(f"skew: monitor replicated hot shard 0 -> "
              f"{svca.replicas} replicas/shard "
              f"(EWMA={[round(e, 1) for e in svca.monitor_ewma]})")
        m = 1 << 15
        grow = {c: table[c].dictionary.add_rows(
            table[c].dictionary.values[
                rng.integers(0, table[c].dictionary.cardinality, m)])
            for c in plan_ad.columns}
        plan_ad.refresh(grow)              # tail now exceeds row_budget
        actions = svca.rebalance()
        print(f"growth: tail re-shard at {actions['split']}; now "
              f"{svca.n_shards} shards, starts={svca.shard_starts}")
        tail = svca.submit(np.arange(plan_ad.n_rows - 64, plan_ad.n_rows))
        print(f"fresh tail serves: {svca.result(tail).shape}, stats: "
              f"splits={svca.stats['shard_splits']}, "
              f"replicas_added={svca.stats['replicas_added']}")

    # 5. query pushdown: serve features WHERE ... as ONE device pipeline.
    # The predicate compiles to code-space terms (equality/ranges ->
    # [lo, hi] compares, IN-sets -> a K-entry LUT probe), the scan
    # evaluates them on the resident packed words without decoding a code
    # stream, the selection compacts to row indices on device, and those
    # indices feed the same packed gather every other request uses. Only
    # the match count (one scalar) and the features come back to the host.
    from repro.columnar import query as Q
    pred = Q.isin("state", [3, 7, 11]) & Q.gt("age", 60)
    with FeatureService(FeaturePlan(table, features, packed=True),
                        sharded=True, buckets=(512,), coalesce=8,
                        linger_us=1000) as svcq:
        tq = svcq.submit(where=pred)       # sharded: each shard scans and
        feats = svcq.result(tq)            # serves its own matches locally
        print(f"filtered serving: {pred!r} -> {feats.shape[0]} rows "
              f"({svcq.stats['filtered_requests']} filtered request(s), "
              f"features {feats.shape})")
        # dict-aware masked aggregates: a masked per-code histogram over
        # the resident words, then K-entry tail math — COUNT/SUM/MEAN
        # under a predicate never touch an N-row value stream
        vals, counts = svcq.groupby_where("state", Q.gt("age", 60))
        top = vals[np.argmax(counts)]
        print(f"aggregates: count={svcq.count_where(pred)}, "
              f"mean(income | pred)={svcq.agg_where(pred, 'income', 'mean'):.0f}, "
              f"busiest state over 60: {top} ({counts.max()} rows)")

    # 6. failure handling: inject faults -> observe failover -> recover.
    # The FaultInjector scripts deterministic launch faults on the pump's
    # dispatch path (exactly where a real device error would land). With a
    # replica resident, retries fail over to it and NOTHING is lost; the
    # struck stream's circuit breaker marks the shard unhealthy and
    # rebalance() re-replicates around it.
    from repro.serve import FaultInjector, FaultPolicy, ServeError
    inj = FaultInjector().fail_launches(6, shard=0, stream=0)
    pol = FaultPolicy(max_retries=3, backoff_s=0.005,
                      breaker_fails=3, breaker_cooldown_s=0.2)
    with FeatureService(FeaturePlan(table, features, packed=True),
                        sharded=True, buckets=(512,), coalesce=1,
                        faults=inj, fault_policy=pol,
                        max_replicas=2) as svcf:
        svcf.add_replica(0)                # the failover target
        hot = [svcf.submit(np.arange(s, s + 512))
               for s in rng.integers(0, (1 << 15) // 32 - 16, 24) * 32]
        ok = sum(svcf.result(t).shape[0] == 512 for t in hot)
        st = svcf.throughput_stats(1.0)
        print(f"chaos: {inj.faults_injected} injected faults -> {ok}/24 "
              f"tickets served (availability={st['availability']:.2f}), "
              f"retries={st['retries']}, failovers={st['failovers']}, "
              f"unhealthy={svcf.unhealthy}")
        if svcf.unhealthy:                 # monitor re-replicates around it
            acts = svcf.rebalance()
            print(f"recovery: replicated={acts['replicated']} "
                  f"failover_replicated={acts['failover_replicated']}, "
                  f"replicas={svcf.replicas}")
    # without replicas, a persistent fault fails ONLY its own tickets —
    # each resolves to a typed ServeError; the service keeps serving
    # (3 faults = 1 launch + 2 retries: the shard-0 ticket exhausts them,
    # then the fault heals and the closing submit proves recovery)
    inj2 = FaultInjector().fail_launches(3, shard=0)
    with FeatureService(FeaturePlan(table, features, packed=True),
                        sharded=True, buckets=(512,), coalesce=8,
                        faults=inj2,
                        fault_policy=FaultPolicy(max_retries=2)) as svcn:
        t_bad = svcn.submit(np.arange(0, 512), deadline_ms=30_000)
        t_ok = svcn.submit(np.arange(1 << 15, (1 << 15) + 512))
        outcome = {}
        for name, t in (("shard0", t_bad), ("shard1", t_ok)):
            try:
                outcome[name] = f"served {svcn.result(t, timeout=30).shape}"
            except ServeError as e:
                outcome[name] = (f"failed after {e.attempts} attempts "
                                 f"({type(e).__name__})")
        print(f"isolation: {outcome} — failed_tickets="
              f"{svcn.stats['failed_tickets']}, service still accepting: "
              f"{svcn.result(svcn.submit(np.arange(64, 128))).shape}")

    # 6b. device-loss recovery: kill a device -> evict -> rebuild -> resume.
    # One DeviceDown (injected here; a real runtime raises its own when an
    # accelerator falls off the bus) marks the device dead. Its resident
    # streams are evicted; the missed shards serve from the HOST packed
    # words meanwhile (bit-exact, slower); the pump rebuilds each orphaned
    # shard on a surviving device via the version-keyed re-put and device
    # serving resumes. With only one device in the pool (the default CPU
    # run) there is no survivor — host gathers carry the whole service,
    # availability still 1.0.
    import jax
    from repro.serve import DeviceDown  # noqa: F401  (the class one kills)
    inj3 = FaultInjector()
    with FeatureService(FeaturePlan(table, features, packed=True),
                        sharded=True, buckets=(512,), coalesce=8,
                        faults=inj3,
                        fault_policy=FaultPolicy(max_retries=8)) as svcd:
        svcd.result(svcd.submit(np.arange(0, 512)))          # warm
        dead = svcd._sharded_ex.devices[0]                   # shard 0 owner
        inj3.kill_device(dead)
        served = [svcd.result(svcd.submit(np.arange(s, s + 512)))
                  for s in (0, 1 << 15)]                     # dead + alive
        time.sleep(0.05)                   # give the pump its rebuild tick
        st = svcd.stats
        mode = ("rebuilt on a survivor" if st["recoveries"]
                else "host-gather fallback (no surviving device)")
        print(f"device loss: killed {dead} -> devices_lost="
              f"{st['devices_lost']}, {mode}; host_gathers="
              f"{st['host_gathers']}, recoveries={st['recoveries']}, "
              f"served {[f.shape[0] for f in served]} rows through it, "
              f"failed_tickets={st['failed_tickets']}")

    # 6c. speculative hedged launches: the straggler timeline. A launch
    # whose retire wait crosses max(hedge_min_s, hedge_factor x the
    # shard's EWMA round-trip mean) gets a DUPLICATE launch on another
    # healthy stream of the shard; first buffer ready resolves the
    # tickets, the loser is discarded (and struck). Timeline for the
    # stalled launch below (stall=80ms, cutoff~=5ms):
    #
    #   t=0     launch on primary      (injected stall: buffer late 80ms)
    #   t~=5ms  wait crosses cutoff -> hedge launch on the replica
    #   t~=6ms  replica buffer ready -> tickets retire (hedge_wins += 1)
    #   t=80ms  primary buffer ready -> discarded, no double count
    inj4 = FaultInjector()
    polh = FaultPolicy(hedge=True, hedge_min_s=0.005, hedge_factor=4.0,
                       breaker_fails=1 << 30, straggler_min_s=1e9)
    with FeatureService(FeaturePlan(table, features, packed=True),
                        sharded=True, buckets=(512,), coalesce=1,
                        faults=inj4, fault_policy=polh) as svch:
        svch.add_replica(0)                # the stream hedges land on
        for _ in range(8):                 # train the EWMA past warmup
            svch.result(svch.submit(np.arange(0, 512)))
        inj4.stall_launches(0.08, 1, shard=0)
        t0 = time.perf_counter()
        out = svch.result(svch.submit(np.arange(0, 512)), timeout=30)
        dt = time.perf_counter() - t0
        st = svch.stats
        print(f"hedging: stalled launch served {out.shape} in "
              f"{dt * 1e3:.1f}ms (stall was 80ms) — hedges={st['hedges']}, "
              f"hedge_wins={st['hedge_wins']}, completed={st['completed']}")

    # 7. streaming
    stream = svc.serve_stream(rng.integers(0, n, 256) for _ in range(8))
    for rows, out in stream:
        pass
    print(f"streamed 8 batches, last={out.shape}")

    # 8. streaming insert + incremental refresh
    new_codes = {
        "age": table["age"].dictionary.add_rows(np.array([101, 102])),
        "state": table["state"].dictionary.add_rows(np.array([7, 7])),
        "income": table["income"].dictionary.add_rows(np.array([999_000,
                                                                21_000])),
    }
    refreshed = plan.refresh(new_codes)
    print(f"insert refreshed {refreshed} column plan(s) "
          f"(stats={plan.stats}); n_rows={plan.n_rows}")
    tail = svc.submit(np.array([n, n + 1]))
    print("features for the inserted rows:\n", svc.result(tail))
    svc.shutdown()                     # join the pump thread when disposing

    # 9. tiered residency: a device byte budget HALF the table's resident
    # word bytes. Shards commit hot in order while they fit; the rest
    # start warm (host packed words). We then walk shard 0 down the
    # ladder by hand — 'warm' frees its device words, 'cold' additionally
    # compresses the host copy to RLE runs — serve a request through the
    # cold slow path (bit-exact; the pump may race an async promotion,
    # misses never wait for it), and promote it back (cold rehydrates
    # from runs first, then re-commits under the budget, displacing a
    # colder resident if the device is full).
    from repro.core import ShardedFeatureExecutor
    probe = ShardedFeatureExecutor(FeaturePlan(table, features, packed=True),
                                   hbm_budget_bytes=1)   # commits nothing:
    total = sum(e.stream_nbytes() for e in probe.executors)  # size the cap
    with FeatureService(FeaturePlan(table, features, packed=True),
                        sharded=True, buckets=(512,), coalesce=8,
                        linger_us=1000, rebalance_every=4, max_replicas=0,
                        hbm_budget_bytes=max(1, total // 2),
                        cold_after=3) as svct:
        print(f"tiers under a {total // 2}B budget (table={total}B): "
              f"{svct.tiers}, resident="
              f"{sum(svct.device_bytes().values())}B")
        freed = svct.demote(0, "warm")     # device words released
        svct.demote(0, "cold")             # host words -> RLE runs
        miss = svct.result(svct.submit(np.arange(0, 512)))
        print(f"cold shard 0 served {miss.shape} bit-exact "
              f"(freed {freed}B device; tier_misses="
              f"{svct.stats['tier_misses']})")
        ok = svct.promote(0)               # rehydrate + re-commit
        st = svct.stats
        print(f"promoted back: {ok}; tiers={svct.tiers}; "
              f"promotions={st['promotions']} demotions={st['demotions']} "
              f"rehydrations={st['rehydrations']}; resident="
              f"{sum(svct.device_bytes().values())}B <= {total // 2}B")

    # 10. the production front door. for_plan() builds the service with
    # the preset three-tier class ladder (interactive: priority 3,
    # singleton immediate launches, 5s deadline; batch: priority 2,
    # normal coalescing; background: priority 1, small admission window,
    # aged up so it drains but never starves anyone) and wraps it in the
    # admission-controlled FeatureFrontend. Tenants share the service;
    # classes bound what each can have outstanding.
    with FeatureFrontend.for_plan(FeaturePlan(table, features, packed=True),
                                  sharded=True, buckets=(512,),
                                  coalesce=8, linger_us=500) as fe:
        tickets = [fe.submit(rng.integers(0, n, 512), klass="batch",
                             tenant="analytics") for _ in range(12)]
        tickets += [fe.submit(np.arange(s, s + 512), klass="interactive",
                              tenant="app") for s in (0, 4096)]
        tickets.append(fe.submit(rng.integers(0, n, 512),
                                 klass="background", tenant="scavenger"))
        fe.collect()
        # overload: hold the pump and flood the background window — the
        # bound rejects with a typed Overloaded + retry-after hint
        # instead of growing an unbounded queue
        fe.service.pause()
        rejected, hint = 0, 0.0
        try:
            for _ in range(2048):
                fe.submit(np.arange(0, 64), klass="background",
                          tenant="scavenger")
        except Overloaded as e:
            rejected, hint = 1, e.retry_after_s
        fe.service.resume()
        fe.collect()
        st = fe.stats()
        lat = {k: f"p99={v['p99_ms']:.2f}ms" for k, v in
               st["classes"].items() if v["samples"]}
        print(f"front door: {lat}; admitted="
              f"{ {k: v['admitted'] for k, v in st['classes'].items()} }, "
              f"rejected typed Overloaded={rejected} "
              f"(retry in ~{hint * 1e3:.1f}ms), availability="
              f"{st['availability_admitted']:.3f}, tenants="
              f"{sorted(st['tenants'])}")


if __name__ == "__main__":
    main()
