"""FeatureService walkthrough: async, double-buffered ADV feature serving.

Builds a columnar table, compiles a FeaturePlan (device-resident fused ADV
tables), then serves featurization requests three ways:

1. request queue with tickets (submit / result),
2. streaming double-buffered iteration (serve_stream),
3. a streaming insert followed by an incremental plan refresh — only the
   columns whose dictionaries changed are re-put on device.

Run:  PYTHONPATH=src python examples/feature_service.py
"""
import time

import numpy as np

from repro.columnar import Table
from repro.core import FeatureSet, FeaturePlan
from repro.serve import FeatureService


def main() -> None:
    rng = np.random.default_rng(0)
    n = 100_000
    table = Table.from_data({
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
    }, imcu_rows=1 << 15)
    features = (FeatureSet()
                .add("age", "zscore")
                .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
                .add("state", "onehot")
                .add("income", "minmax"))
    plan = FeaturePlan(table, features)
    print(f"plan: {len(plan.plans)} columns, out_dim={plan.out_dim}, "
          f"resident_tables={plan.bytes_resident_tables()}B, "
          f"imcus={table['age'].n_imcus}")

    # 1. ticketed request queue (double-buffered dispatch under the hood)
    svc = FeatureService(plan, prefetch=2)
    t0 = time.perf_counter()
    tickets = [svc.submit(rng.integers(0, n, 512)) for _ in range(64)]
    feats = svc.result(tickets[0])
    svc.drain()
    wall = time.perf_counter() - t0
    print(f"served 64 requests: first result {feats.shape}, "
          f"{svc.throughput_stats(wall)['rows_per_s']:.0f} rows/s")

    # 2. streaming
    stream = svc.serve_stream(rng.integers(0, n, 256) for _ in range(8))
    for rows, out in stream:
        pass
    print(f"streamed 8 batches, last={out.shape}")

    # 3. streaming insert + incremental refresh
    new_codes = {
        "age": table["age"].dictionary.add_rows(np.array([101, 102])),
        "state": table["state"].dictionary.add_rows(np.array([7, 7])),
        "income": table["income"].dictionary.add_rows(np.array([999_000,
                                                                21_000])),
    }
    refreshed = plan.refresh(new_codes)
    print(f"insert refreshed {refreshed} column plan(s) "
          f"(stats={plan.stats}); n_rows={plan.n_rows}")
    tail = svc.submit(np.array([n, n + 1]))
    print("features for the inserted rows:\n", svc.result(tail))


if __name__ == "__main__":
    main()
