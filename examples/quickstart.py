"""Quickstart: the paper in 60 lines.

Build a columnar table -> dictionary-encode (Table 2) -> attach ADVs
(Tables 4/5) -> featurize via gathers -> train a Wide&Deep classifier on
device -> write the learned embedding back into the dictionary (Fig 2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.columnar import Table
from repro.core import FeatureSet, FeaturePipeline
from repro.core.feedback import store_embedding, rank_features
from repro.models.widedeep import (WideDeepConfig, init_widedeep,
                                   make_widedeep_train_step)

rng = np.random.default_rng(0)
N = 20_000

# 1. raw data -> columnar, dictionary-encoded storage ------------------------
states = np.array([f"State_{i:02d}" for i in range(50)])
raw = {
    "age": rng.integers(18, 90, N),
    "state": states[rng.integers(0, 50, N)],
    "income": rng.integers(20, 250, N) * 1000,
}
table = Table.from_data(raw)
print(table.summary())

# 2. featurization as ADVs (computed once on K dictionary rows) --------------
features = (FeatureSet()
            .add("age", "zscore")
            .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
            .add("income", "minmax")
            .add("income", "log"))
pipe = FeaturePipeline(table, features)
print(f"deep feature dim: {pipe.out_dim}; "
      f"batch bytes ADV path: {pipe.bytes_moved_adv(1024)} "
      f"vs f32 path: {pipe.bytes_moved_recompute(1024)}")

# 3. label + Wide&Deep model ---------------------------------------------------
age, income = raw["age"], raw["income"]
y = ((age > 45) & (income > 90_000)).astype(np.float32)
state_codes = table["state"].codes()
cfg = WideDeepConfig(wide_cards=(50,), deep_dim=pipe.out_dim,
                     embed_cols=((50, 8),), hidden=(32, 16))
params = init_widedeep(cfg, jax.random.PRNGKey(0))
step = make_widedeep_train_step(cfg, lr=0.2)

losses = []
for i in range(600):
    idx = rng.integers(0, N, 512)
    deep = pipe.batch(idx)                                # ADV gather
    wide = jnp.asarray(state_codes[idx])[None, :]
    emb = [jnp.asarray(state_codes[idx])]
    params, loss = step(params, wide, deep, jnp.asarray(y[idx]), emb)
    losses.append(float(loss))
final = float(np.mean(losses[-20:]))
print(f"wide&deep loss: {losses[0]:.4f} -> {final:.4f}")
# better than the base-rate entropy floor (~0.66) and clearly descending
assert final < 0.55 and final < 0.65 * losses[0]

# 4. analytics cycle (paper §7): learned artifacts back into the dictionary ---
aug_state = pipe.augmented.get("state")
if aug_state is None:
    from repro.core import AugmentedDictionary
    aug_state = AugmentedDictionary(table["state"].dictionary)
store_embedding(aug_state, "emb.v1", np.asarray(params["embeds"][0]),
                analysis="quickstart-run")
print(aug_state.summary())
print("feature ranking:",
      rank_features({"deep": np.asarray(pipe.batch(np.arange(64))),
                     "wide": np.asarray(params["wide"])})[:2])
print("OK")
