"""Batched serving: prefill + decode loop with a KV cache (serve_step path).

Uses the xLSTM arch to show the recurrent-state serving path (O(1) state per
token, the long_500k-capable family); switch --arch for transformer KV-cache
serving.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or []) + [
    "--arch", "xlstm-1.3b", "--preset", "small",
    "--requests", "4", "--prompt-len", "16", "--max-new", "24",
]

from repro.launch.serve import main

if __name__ == "__main__":
    main()
