"""End-to-end LM training on the columnar token pipeline.

Tokens are stored dictionary-encoded + bit-packed (the paper's §5 storage);
the trainer consumes shuffled windows with restart-safe seeding, checkpoints
asynchronously, and the run resumes from the latest step if interrupted —
kill it mid-run and start again to see the restart path.

CPU-sized default (~15M params, 300 steps). The same driver trains any
--arch at full config on a real mesh (see repro/launch/train.py and the
dry-run for the production meshes).

Run:  PYTHONPATH=src python examples/train_lm_columnar.py [--steps 300]
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or []) + [
    "--arch", "qwen2-7b", "--preset", "small",
    "--batch", "8", "--seq", "128", "--lr", "3e-3",
    "--ckpt-dir", "/tmp/repro_lm_ckpt",
]
if "--steps" not in " ".join(sys.argv):
    sys.argv += ["--steps", "300"]

from repro.launch.train import main

if __name__ == "__main__":
    main()
