"""The analytics CYCLE (paper §7, Fig 2): learn -> write back -> reuse.

Round 1 trains a model on hand-designed ADV features of a column whose true
structure is hidden (a scrambled categorical where the label depends on a
latent grouping). The trained per-code embedding is then distilled into a
*learned bucketization* written back into the dictionary (the 'ML G1' column
of Table 5). Round 2 trains a smaller model on the learned ADV and matches /
beats round 1 — the feedback loop paying off.

Run:  PYTHONPATH=src python examples/analytics_cycle.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.columnar import Dictionary
from repro.core import AugmentedDictionary
from repro.core.feedback import learn_bucketization, store_embedding
from repro.models.widedeep import (WideDeepConfig, init_widedeep,
                                   make_widedeep_train_step)

rng = np.random.default_rng(7)
N, K, LATENT = 30_000, 200, 4

# hidden structure: each of 200 codes belongs to one of 4 latent groups
latent_group = rng.integers(0, LATENT, K)
codes_raw = rng.integers(0, K, N)
y = (latent_group[codes_raw] >= 2).astype(np.float32)
noise = rng.random(N) < 0.1
y = np.where(noise, 1 - y, y)

d, codes = Dictionary.from_data(codes_raw)
aug = AugmentedDictionary(d)
aug.add("hash8", "hash_bucket", n_buckets=8)          # round-1 guess feature


def train(deep_fn, embed_card, steps=400, dim=8, lr=0.15, seed=0):
    cfg = WideDeepConfig(wide_cards=(), deep_dim=deep_fn(codes[:1]).shape[1],
                         embed_cols=((embed_card, dim),) if embed_card else (),
                         hidden=(16,))
    params = init_widedeep(cfg, jax.random.PRNGKey(seed))
    step = make_widedeep_train_step(cfg, lr=lr)
    r = np.random.default_rng(seed)
    wide = jnp.zeros((0, 512), jnp.int32)
    losses = []
    for i in range(steps):
        idx = r.integers(0, N, 512)
        deep = jnp.asarray(deep_fn(codes[idx]))
        emb = [jnp.asarray(codes[idx])] if embed_card else None
        params, loss = step(params, wide, deep, jnp.asarray(y[idx]), emb)
        losses.append(float(loss))
    return params, losses


# ---- round 1: hash feature + per-code embedding -----------------------------
print("round 1: hash bucketization + learned embedding")
p1, l1 = train(lambda c: aug.featurize("hash8", c), embed_card=K,
               steps=800, lr=0.25)
print(f"  loss {l1[0]:.4f} -> {np.mean(l1[-20:]):.4f}")

# ---- feedback: distill the MODEL's per-code score into a bucketization -------
# score_k = round-1 model logit when shown dictionary code k (the 'average
# predicted logit per code' of core/feedback.py)
from repro.models.widedeep import forward_widedeep
emb = np.asarray(p1["embeds"][0])                     # (K, dim)
store_embedding(aug, "emb.round1", emb, analysis="round1")
all_codes = np.arange(K, dtype=np.int32)
cfg1 = WideDeepConfig(wide_cards=(), deep_dim=1, embed_cols=((K, 8),),
                      hidden=(16,))
scores = np.asarray(forward_widedeep(
    cfg1, p1, jnp.zeros((0, K), jnp.int32),
    jnp.asarray(aug.featurize("hash8", all_codes)),
    [jnp.asarray(all_codes)]))
learn_bucketization(aug, "ml_g1", scores, n_buckets=LATENT,
                    analysis="round1-distilled")
print("  wrote back ADVs:", sorted(aug.advs))

# purity of the learned buckets vs the DECISION-RELEVANT grouping: the label
# exposes only the binary split latent_group >= 2, so that is what a learned
# bucketization can (and should) recover.
buckets = aug["ml_g1"].table[:, 0].astype(int)
# align latent groups to DICTIONARY code order (codes are load-order indices)
binary_group = (latent_group[d.values.astype(int)] >= 2).astype(int)
purity = 0.0
for b in range(LATENT):
    mask = buckets == b
    if mask.sum():
        purity += max(np.bincount(binary_group[mask], minlength=2)) / K
print(f"  learned-bucket purity vs decision grouping: {purity:.2f}")

# ---- round 2: NO embedding, just the learned bucketization as one-hot --------
print("round 2: learned-ADV one-hot only (no embedding table)")
onehot = np.eye(LATENT, dtype=np.float32)


def deep2(c):
    return onehot[aug.featurize("ml_g1", c)[:, 0].astype(int)]


p2, l2 = train(deep2, embed_card=0, steps=400)
print(f"  loss {l2[0]:.4f} -> {np.mean(l2[-20:]):.4f}")

r1, r2 = np.mean(l1[-20:]), np.mean(l2[-20:])
print(f"\nanalytics cycle: round2 ({r2:.4f}) vs round1 ({r1:.4f}) "
      f"with {K}x{8} fewer feature params")
assert r2 < r1 * 1.2, "learned ADV should retain round-1 quality"
assert purity > 0.75, "learned bucketization should recover latent groups"
print("OK")
