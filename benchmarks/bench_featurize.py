"""Paper Table 6: the featurization catalog, one benchmark per row —
dictionary-domain cost (K) for each transform + the device gather path
through the Pallas kernels (interpret mode on CPU)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.columnar import Dictionary
from repro.core import AugmentedDictionary
from repro.kernels.adv_gather import adv_gather
from repro.kernels.hist import hist
from benchmarks.common import time_call, emit

N = 1 << 16          # device-path rows (interpret mode is slow; shape-true)
K = 999


def run() -> None:
    rng = np.random.default_rng(3)
    ages = rng.integers(0, K, N)
    d, codes = Dictionary.from_data(ages)
    aug = AugmentedDictionary(d)

    catalog = [
        ("float", {}), ("onehot", {"max_cardinality": 4096}),
        ("minmax", {}), ("mean_norm", {}), ("zscore", {}),
        ("binarize", {"threshold": 500.0}),
        ("quantile", {"q": 4}), ("hash_bucket", {"n_buckets": 32}),
        ("bucketize", {"boundaries": np.linspace(0, K, 7)[1:-1]}),
        ("embedding", {"dim": 16}),
    ]
    for kind, params in catalog:
        us = time_call(lambda k=kind, p=params:
                       AugmentedDictionary(d).add(f"b_{k}", k, **p),
                       repeats=5)
        emit(f"table6/build_{kind}", us, f"K={d.cardinality}")

    # row-space application = one gather regardless of transform
    aug.add("zscore", "zscore")
    us = time_call(aug.featurize, "zscore", codes, repeats=5)
    emit("table6/apply_gather_host", us, f"N={N}")

    # device path: Pallas adv_gather (interpret) + count-metadata hist build
    table = jnp.asarray(aug["zscore"].table)
    jcodes = jnp.asarray(codes)
    adv_gather(table, jcodes).block_until_ready()
    us = time_call(lambda: adv_gather(table, jcodes).block_until_ready(),
                   repeats=3)
    emit("table6/apply_gather_pallas_interp", us, f"N={N}")
    hist(jcodes, d.cardinality).block_until_ready()
    us = time_call(lambda: hist(jcodes, d.cardinality).block_until_ready(),
                   repeats=3)
    emit("table6/count_metadata_build_pallas", us, f"K={d.cardinality}")


if __name__ == "__main__":
    run()
