"""Paper Table 6: the featurization catalog, one benchmark per row —
dictionary-domain cost (K) for each transform + the device gather path
through the Pallas kernels (interpret mode on CPU) + the serving path:
seed-style synchronous FeaturePipeline.batch() loop vs the pump-driven
FeatureService (the ≥1.5x throughput gate) vs the packed fast path
(device-resident word streams: scan ranges AND uniform arbitrary-row
requests, both served by coalesced index-only launches)."""
from __future__ import annotations

import os
from collections import deque

import numpy as np
import jax
import jax.numpy as jnp

from repro.columnar import Dictionary, Table
from repro.core import (AugmentedDictionary, FeatureExecutor,
                        FeaturePipeline, FeaturePlan, FeatureSet,
                        ShardedFeatureExecutor)
from repro.core.pipeline import pad_rows_edge
from repro.kernels.adv_gather import adv_gather
from repro.kernels.hist import hist
from repro.serve import (FaultInjector, FaultPolicy, FeatureFrontend,
                         FeatureService, Overloaded, RequestClass)
from benchmarks.common import (MIN_REPEATS, time_call, emit, scaled,
                               interleaved_best)

K = 999


def _serve_comparison() -> None:
    """Seed loop (per-column dict transfer, sync retire per batch) vs
    FeatureService (stacked single transfer, background pump) vs the packed
    paths (device-resident words; scan ranges and random rows).

    All five loops are timed with ROUND-ROBIN best-of-N
    (``interleaved_best``): the CI gate compares ratios between them, and
    interleaving keeps machine-speed drift from landing on one contender.
    """
    rng = np.random.default_rng(11)
    n = scaled(200_000, 8_000)
    batch = scaled(512, 128)
    n_batches = scaled(200, 50)    # smoke needs enough batches for a stable
                                   # CI perf gate; loops timed best-of-N
    table = Table.from_data({
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
        "device": rng.integers(0, 4, n),
    })
    fs = (FeatureSet().add("age", "zscore")
          .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
          .add("state", "onehot")
          .add("income", "minmax").add("income", "log")
          .add("device", "onehot"))
    pipe = FeaturePipeline(table, fs)
    plan = pipe.plan
    idx_list = [rng.integers(0, n, batch) for _ in range(n_batches)]
    rows = batch * n_batches

    # 1. seed FeaturePipeline.batch() semantics: one transfer per column
    # (dict input), synchronous host retire of every batch
    cols = plan.columns
    codes_host = {c: plan.codes_matrix[i] for i, c in enumerate(cols)}
    tables = {c: plan.plans[i].fused_table for i, c in enumerate(cols)}

    @jax.jit
    def gather_dict(code_batch):
        outs = [jnp.take(tables[c], code_batch[c], axis=0) for c in cols]
        return jnp.concatenate(outs, axis=-1)

    def seed_loop():
        for ix in idx_list:
            np.asarray(gather_dict({c: jnp.asarray(codes_host[c][ix])
                                    for c in cols}))

    # 2. pump-driven service over the int32 plan
    svc = FeatureService(plan, prefetch=2, buckets=(batch,))

    def svc_loop():
        for ix in idx_list:
            svc.submit(ix)
        svc.drain()

    # 3. packed scan pattern: word-aligned ranges (the training-epoch serve
    # pattern) — the pump coalesces them into index-only launches
    plan_packed = FeaturePlan(table, fs, packed=True)
    svcp = FeatureService(plan_packed, prefetch=2, buckets=(batch,))
    start_list = [int(s) * batch
                  for s in rng.integers(0, n // batch, n_batches)]

    def packed_loop():
        for st in start_list:
            svcp.submit(np.arange(st, st + batch))
        svcp.drain()

    # 4/5. uniform arbitrary-row requests, mixed sizes — the realistic
    # 'millions of users' lookup pattern — served two ways over the SAME
    # packed plan: the pre-PR host-gather path (host word-gather + (C, B)
    # code shipping + one un-coalesced launch per request, prefetch-2
    # retire) vs the pump's coalesced indexed launches (the device computes
    # word index + bit offset itself; only 4B x rows of indices move)
    sizes = [int(s) for s in
             rng.choice([batch // 4, batch // 2, batch], n_batches)]
    req_list = [rng.integers(0, n, sz) for sz in sizes]
    rand_rows = int(np.sum(sizes))
    ex = FeatureExecutor(plan_packed, prefetch=2)

    def host_gather_loop():
        inflight = deque()
        for req in req_list:
            padded = pad_rows_edge(req, batch)
            codes = plan_packed.host_codes(padded)        # host materializes
            inflight.append(ex.gather_device(jax.device_put(codes)))
            if len(inflight) >= 2:
                np.asarray(inflight.popleft())
        while inflight:
            np.asarray(inflight.popleft())

    svcr = FeatureService(plan_packed, prefetch=2, buckets=(batch,))

    def random_loop():
        for req in req_list:
            svcr.submit(req)
        svcr.drain()

    loops = [seed_loop, svc_loop, packed_loop, host_gather_loop, random_loop]
    for loop in loops:
        loop()                                             # compile each
    h2d_before = svcr.stats["bytes_h2d"]
    launches_before = svcr.stats["launches"]
    # 10 interleaved repeats (not the 5-minimum): the pump-driven loops are
    # the most sensitive to transient box load (thread handoffs balloon
    # under contention), and extra rounds raise the odds every contender's
    # min comes from a comparably quiet window
    repeats = 2 * MIN_REPEATS
    seed_s, svc_s, packed_s, host_s, random_s = \
        interleaved_best(loops, repeats=repeats)
    assert svcp.stats["packed_ranges"] >= n_batches        # fast path taken
    # per-loop averages over the interleaved repeats (stats accumulate)
    launches = (svcr.stats["launches"] - launches_before) / repeats
    h2d = (svcr.stats["bytes_h2d"] - h2d_before) / repeats

    emit("serve/seed_batch_loop", seed_s / n_batches * 1e6,
         f"rows_per_s={rows/seed_s:.0f}")
    emit("serve/feature_service_prefetch2", svc_s / n_batches * 1e6,
         f"rows_per_s={rows/svc_s:.0f};speedup={seed_s/svc_s:.2f}x")
    emit("serve/feature_service_packed", packed_s / n_batches * 1e6,
         f"rows_per_s={rows/packed_s:.0f};"
         f"speedup_vs_prefetch2={svc_s/packed_s:.2f}x;"
         f"h2d_bytes_int32={plan.bytes_moved_adv(batch)};"
         f"h2d_bytes_packed={plan_packed.bytes_moved_adv(batch)};"
         f"bytes_reduction="
         f"{plan.bytes_moved_adv(batch)/plan_packed.bytes_moved_adv(batch):.1f}x")
    emit("serve/feature_service_random_hostgather", host_s / n_batches * 1e6,
         f"rows_per_s={rand_rows/host_s:.0f};"
         f"code_bytes_per_req={4 * len(plan_packed.plans) * batch}")
    emit("serve/feature_service_random", random_s / n_batches * 1e6,
         f"rows_per_s={rand_rows/random_s:.0f};"
         f"speedup_vs_hostgather={host_s/random_s:.2f}x;"
         f"launches_per_loop={launches:.0f};"
         f"index_bytes_per_loop={h2d:.0f}")
    for s in (svc, svcp, svcr):        # pump threads don't outlive the module
        s.shutdown()


def _sharded_serve_comparison() -> None:
    """Mesh-sharded packed serving vs the pre-mesh single-stream path.

    Workload: clustered 'user block' lookups (64 contiguous rows at random
    word-aligned offsets — the per-user serving pattern), over a table
    partitioned into 4 IMCUs. Three contenders, interleaved best-of-N:

    - ``serve/feature_service_sharded_1shard`` — the 1-shard baseline: the
      SAME load served without per-IMCU device residency, i.e. the pre-mesh
      deployment path where the data moves to the compute — host word-gather
      + per-request (C, B) code shipping + one un-coalesced launch stream
      (prefetch-2 retire). This is the ``feature_service_random_hostgather``
      methodology from the PR 3 gate, applied to the mesh workload.
    - ``serve/feature_service_sharded`` — the mesh service: per-IMCU
      resident word-stream shards committed to the mesh devices
      (XLA_FLAGS=--xla_force_host_platform_device_count=4 in CI), rows
      routed to their owning shard at submit, per-shard coalescing
      (coalesce=8) with a 1ms linger, per-shard prefetch windows, one
      multiplexing pump. Compute moves to the data; only 4B x rows of
      indices ever cross host->device.
    - the same-code RESIDENT 1-shard service, reported in the sharded
      record's derived field (``resident1_parity``): on a small-core CPU
      host same-code shard scaling is core-bound, so parity (~1x) is the
      ceiling — the mesh's win there is capacity (one device's memory
      cannot hold every stream at scale) while THIS record's gated claim is
      against the path a mesh deployment would otherwise serve through.
    """
    rng = np.random.default_rng(17)
    n = scaled(256_000, 64_000)
    n_req = scaled(600, 300)
    rsz = 64
    n_shards = 4
    data = {
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
        "device": rng.integers(0, 4, n),
    }
    fs = (FeatureSet().add("age", "zscore")
          .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
          .add("state", "onehot")
          .add("income", "minmax").add("income", "log")
          .add("device", "onehot"))
    plan_mesh = FeaturePlan(Table.from_data(data, imcu_rows=n // n_shards),
                            fs, packed=True)
    plan_one = FeaturePlan(Table.from_data(data), fs, packed=True)
    plan_res1 = FeaturePlan(Table.from_data(data), fs, packed=True)
    ex_one = FeatureExecutor(plan_one, prefetch=2)
    starts = rng.integers(0, (n - rsz) // 32, n_req) * 32
    reqs = [np.arange(s, s + rsz) for s in starts]
    rows = n_req * rsz

    def baseline_loop():
        # pre-mesh path: the host gathers packed words per request and
        # ships int32 code slices to the one compute device, one launch
        # per request, prefetch-2 retire — data moves to the compute
        inflight = deque()
        for r in reqs:
            codes = plan_one.host_codes(r)
            inflight.append(ex_one.gather_device(jax.device_put(codes)))
            if len(inflight) >= 2:
                np.asarray(inflight.popleft())
        while inflight:
            np.asarray(inflight.popleft())

    svc = FeatureService(plan_mesh, sharded=True, buckets=(rsz,),
                         coalesce=8, linger_us=1000)
    svc1 = FeatureService(plan_res1, sharded=True, buckets=(rsz,),
                          coalesce=8, linger_us=1000)

    def mesh_loop():
        for r in reqs:
            svc.submit(r)
        svc.drain()

    def resident1_loop():
        for r in reqs:
            svc1.submit(r)
        svc1.drain()

    loops = [baseline_loop, mesh_loop, resident1_loop]
    for loop in loops:
        loop()                                             # compile each
    launches_before = svc.stats["launches"]
    repeats = 2 * MIN_REPEATS
    base_s, mesh_s, res1_s = interleaved_best(loops, repeats=repeats)
    launches = (svc.stats["launches"] - launches_before) / repeats
    emit("serve/feature_service_sharded_1shard", base_s / n_req * 1e6,
         f"rows_per_s={rows/base_s:.0f};"
         f"path=host_word_gather+code_ship,1_launch_stream;"
         f"code_bytes_per_req={4 * len(plan_one.plans) * rsz}")
    emit("serve/feature_service_sharded", mesh_s / n_req * 1e6,
         f"rows_per_s={rows/mesh_s:.0f};"
         f"speedup_vs_1shard={base_s/mesh_s:.2f}x;"
         f"shards={svc.n_shards};devices={len(jax.devices())};"
         f"launches_per_loop={launches:.0f};"
         f"resident1_parity={res1_s/mesh_s:.2f}x;"
         f"shard_launches={svc.stats['shard_launches']}")
    for s in (svc, svc1):
        s.shutdown()


def _skewed_serve_comparison() -> None:
    """Adaptive hot-shard replication under Zipf-distributed hot keys.

    Workload: clustered 64-row 'user block' lookups whose block index is
    Zipf-distributed — the head of the distribution (the hot users) lives
    in shard 0's row range, so single-owner routing concentrates most
    traffic on ONE shard's launch stream while the other devices idle.
    Three contenders, interleaved best-of-N, per the PR 3/4 gate
    methodology (normalized same-run, machine speed cancels):

    - ``serve/feature_service_skewed_1owner`` — the single-owner-routing
      baseline: the SAME skewed load served without adaptive shard
      management, i.e. the pre-adaptive deployment path where every row
      has exactly one serving stream — host word-gather + per-request
      (C, B) code shipping + one un-coalesced launch stream (prefetch-2
      retire). The ``feature_service_sharded_1shard`` methodology, under
      skew.
    - ``serve/feature_service_skewed`` — the adaptive mesh service: the
      load monitor's request-rate EWMA flags shard 0 as hot during
      warm-up, ``rebalance()`` replicates its resident word stream across
      the under-loaded devices (read fan-out), and the steady state is
      timed. Each replica stream brings its own prefetch window + device
      queue: on a real mesh that multiplies the hot shard's HBM/compute
      capacity; on a shared-memory CPU host the fan-out win is pipeline
      depth only, so the same-code no-replication service is ALSO timed
      and reported as ``owner_routing_parity`` in the derived field (the
      ``resident1_parity`` transparency convention from PR 4).
    """
    rng = np.random.default_rng(29)
    n = scaled(256_000, 64_000)
    n_req = scaled(800, 400)
    rsz = 64
    n_shards = 4
    data = {
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
        "device": rng.integers(0, 4, n),
    }
    fs = (FeatureSet().add("age", "zscore")
          .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
          .add("state", "onehot")
          .add("income", "minmax").add("income", "log")
          .add("device", "onehot"))
    # Zipf-distributed hot keys: block rank r served with p ~ 1/r^1.2, the
    # head mapped to the lowest rows — hot users cluster in shard 0
    blocks = (n - rsz) // 32
    ranks = np.minimum(rng.zipf(1.2, n_req), blocks) - 1
    reqs = [np.arange(s, s + rsz) for s in ranks * 32]
    hot_share = float(np.mean(ranks * 32 < n // n_shards))
    rows = n_req * rsz

    table_mesh = Table.from_data(data, imcu_rows=n // n_shards)
    plan_one = FeaturePlan(Table.from_data(data), fs, packed=True)
    ex_one = FeatureExecutor(plan_one, prefetch=2)

    def owner_loop():
        # single-owner routing, pre-adaptive path: every request is served
        # by its one owning stream — host word-gather + code ship + one
        # launch stream, prefetch-2 retire (data moves to the compute)
        inflight = deque()
        for r in reqs:
            codes = plan_one.host_codes(r)
            inflight.append(ex_one.gather_device(jax.device_put(codes)))
            if len(inflight) >= 2:
                np.asarray(inflight.popleft())
        while inflight:
            np.asarray(inflight.popleft())

    svc = FeatureService(FeaturePlan(table_mesh, fs, packed=True),
                         sharded=True, buckets=(rsz,), coalesce=8,
                         linger_us=1000, hot_factor=2.0, max_replicas=3)
    svc_par = FeatureService(FeaturePlan(table_mesh, fs, packed=True),
                             sharded=True, buckets=(rsz,), coalesce=8,
                             linger_us=1000)

    def adaptive_loop():
        for r in reqs:
            svc.submit(r)
        svc.drain()

    def parity_loop():
        for r in reqs:
            svc_par.submit(r)
        svc_par.drain()

    loops = [owner_loop, adaptive_loop, parity_loop]
    for loop in loops:
        loop()                                             # compile each
    for _ in range(3):          # monitor converges on the skew in warm-up
        adaptive_loop()
        svc.rebalance()
    replicas = svc.replicas
    assert replicas[0] >= 1, "monitor failed to replicate the hot shard"
    repeats = 2 * MIN_REPEATS
    owner_s, adapt_s, par_s = interleaved_best(loops, repeats=repeats)
    emit("serve/feature_service_skewed_1owner", owner_s / n_req * 1e6,
         f"rows_per_s={rows/owner_s:.0f};"
         f"path=single_owner,host_word_gather+code_ship,1_launch_stream;"
         f"hot_share={hot_share:.2f}")
    emit("serve/feature_service_skewed", adapt_s / n_req * 1e6,
         f"rows_per_s={rows/adapt_s:.0f};"
         f"speedup_vs_1owner={owner_s/adapt_s:.2f}x;"
         f"owner_routing_parity={par_s/adapt_s:.2f}x;"
         f"replicas={replicas};hot_share={hot_share:.2f};"
         f"devices={len(jax.devices())};"
         f"shard_launches={svc.stats['shard_launches']}")
    for s in (svc, svc_par):
        s.shutdown()


def _chaos_serve_comparison() -> None:
    """Availability + tail latency under periodic injected replica faults.

    The same Zipf 'user block' workload as ``feature_service_skewed``,
    served by two same-run services: a fault-free reference and one whose
    hot shard (0) keeps taking periodic launch faults on its primary AND
    its first replica (deterministic FaultInjector rules — every 4th/5th
    launch of those streams fails, forever). With a third healthy stream
    resident, failover retries keep every ticket completing: the
    ``compare.py --require`` gate asserts ``availability=1`` on this
    record, and ``p99_vs_clean`` reports the recovery machinery's tail
    cost against the fault-free same-run baseline (machine speed cancels;
    there is no cross-run gate on the ratio because injected-fault timing
    is scheduler-sensitive on shared CI hosts).
    """
    rng = np.random.default_rng(43)
    n = scaled(128_000, 32_000)
    n_req = scaled(400, 200)
    rsz = 64
    n_shards = 4
    data = {
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
    }
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    blocks = (n - rsz) // 32
    ranks = np.minimum(rng.zipf(1.2, n_req), blocks) - 1
    reqs = [np.arange(s, s + rsz) for s in ranks * 32]
    rows = n_req * rsz
    table = Table.from_data(data, imcu_rows=n // n_shards)

    inj = (FaultInjector()
           .fail_launches(1 << 30, shard=0, stream=0, every=4)
           .fail_launches(1 << 30, shard=0, stream=1, every=5))
    # breakers off (threshold unreachably high): the benchmark measures
    # the retry/failover path itself under a PERSISTENT fault source, not
    # the breaker's learned avoidance of it
    pol = FaultPolicy(max_retries=3, backoff_s=0.0005, breaker_fails=1 << 30)

    def build(faults, policy):
        svc = FeatureService(FeaturePlan(table, fs, packed=True),
                             sharded=True, buckets=(rsz,), coalesce=8,
                             linger_us=1000, max_replicas=3, faults=faults,
                             fault_policy=policy)
        svc.add_replica(0)          # 3 streams: 2 faulty + 1 healthy under
        svc.add_replica(0)          # the injector rules above
        return svc

    svc_clean = build(None, None)
    svc_chaos = build(inj, pol)

    def clean_loop():
        for r in reqs:
            svc_clean.submit(r)
        svc_clean.drain()

    def chaos_loop():
        for r in reqs:
            svc_chaos.submit(r)
        svc_chaos.drain()

    loops = [clean_loop, chaos_loop]
    for loop in loops:
        loop()                                             # compile each
    svc_clean.latencies.clear()
    svc_chaos.latencies.clear()
    failovers0 = svc_chaos.stats["failovers"]
    clean_s, chaos_s = interleaved_best(loops, repeats=MIN_REPEATS)
    p99_clean = float(np.percentile(np.array(svc_clean.latencies), 99))
    p99_chaos = float(np.percentile(np.array(svc_chaos.latencies), 99))
    st = svc_chaos.throughput_stats(chaos_s)
    emit("serve/feature_service_chaos_clean", clean_s / n_req * 1e6,
         f"rows_per_s={rows/clean_s:.0f};p99_ms={p99_clean*1e3:.3f};"
         f"replicas={svc_clean.replicas[0]}")
    emit("serve/feature_service_chaos", chaos_s / n_req * 1e6,
         f"availability={st['availability']:.4f};"
         f"failed_tickets={st['failed_tickets']};"
         f"failovers={st['failovers'] - failovers0};"
         f"retries={st['retries']};"
         f"faults_injected={inj.faults_injected};"
         f"p99_ms={p99_chaos*1e3:.3f};"
         f"p99_vs_clean={p99_chaos/max(p99_clean, 1e-9):.2f}x;"
         f"slowdown_vs_clean={chaos_s/clean_s:.2f}x;"
         f"replicas={svc_chaos.replicas[0]};"
         f"devices={len(jax.devices())}")
    for s in (svc_clean, svc_chaos):
        s.shutdown()


def _hedged_serve_comparison() -> None:
    """Tail latency under injected stragglers: hedged vs no-hedge, same run.

    The Zipf 'user block' workload again, with ASYNC stragglers (stall
    rules: every 4th launch on shard 0's primary stream holds its result
    buffer for ``stall_s`` — the pump keeps running, only the retire
    waits) and a replica resident on the hot shard. Two services differ in
    ONE policy bit: ``hedge``. The no-hedge control rides every stall out,
    so its p99 ~= the stall; the hedged service duplicates the launch on
    the replica once the wait crosses the hedge cutoff and retires the
    fast copy. The ``compare.py --require`` gate asserts availability=1
    AND hedge_wins>=1 AND ``p99_vs_nohedge`` well under 1 on this record —
    the speculative duplicate must actually beat the straggler, same-run
    so machine speed cancels (no cross-run timing gate: stall timing is
    scheduler-sensitive on shared CI hosts).
    """
    rng = np.random.default_rng(47)
    n = scaled(128_000, 32_000)
    n_req = scaled(400, 200)
    rsz = 64
    n_shards = 4
    stall_s = 0.05
    data = {
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
    }
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    blocks = (n - rsz) // 32
    ranks = np.minimum(rng.zipf(1.2, n_req), blocks) - 1
    reqs = [np.arange(s, s + rsz) for s in ranks * 32]
    rows = n_req * rsz
    table = Table.from_data(data, imcu_rows=n // n_shards)

    def build(hedge: bool):
        # each service needs its OWN injector: stall rules consume per
        # launch, and the two pumps interleave nondeterministically
        inj = FaultInjector().stall_launches(stall_s, 1 << 30, shard=0,
                                             stream=0, every=4)
        # breakers + straggler strikes off (thresholds unreachable): the
        # benchmark isolates the hedging machinery from learned avoidance
        pol = FaultPolicy(breaker_fails=1 << 30, straggler_min_s=1e9,
                          hedge=hedge, hedge_min_s=0.005, hedge_factor=4.0)
        svc = FeatureService(FeaturePlan(table, fs, packed=True),
                             sharded=True, buckets=(rsz,), coalesce=8,
                             linger_us=1000, max_replicas=3, faults=inj,
                             fault_policy=pol)
        svc.add_replica(0)           # the healthy stream hedges land on
        return svc, inj

    svc_hedge, inj_h = build(True)
    svc_plain, inj_p = build(False)

    def hedge_loop():
        for r in reqs:
            svc_hedge.submit(r)
        svc_hedge.drain()

    def plain_loop():
        for r in reqs:
            svc_plain.submit(r)
        svc_plain.drain()

    loops = [plain_loop, hedge_loop]
    for loop in loops:
        loop()                       # compile + train the EWMA past warmup
    svc_hedge.latencies.clear()
    svc_plain.latencies.clear()
    plain_s, hedge_s = interleaved_best(loops, repeats=MIN_REPEATS)
    p99_plain = float(np.percentile(np.array(svc_plain.latencies), 99))
    p99_hedge = float(np.percentile(np.array(svc_hedge.latencies), 99))
    st = svc_hedge.throughput_stats(hedge_s)
    emit("serve/feature_service_hedged_nohedge", plain_s / n_req * 1e6,
         f"rows_per_s={rows/plain_s:.0f};p99_ms={p99_plain*1e3:.3f};"
         f"stalls_injected={inj_p.stalls_injected};stall_ms={stall_s*1e3:.0f};"
         f"availability={svc_plain.throughput_stats(plain_s)['availability']:.4f}")
    emit("serve/feature_service_hedged", hedge_s / n_req * 1e6,
         f"availability={st['availability']:.4f};"
         f"failed_tickets={st['failed_tickets']};"
         f"hedges={st['hedges']};hedge_wins={st['hedge_wins']};"
         f"stalls_injected={inj_h.stalls_injected};"
         f"p99_ms={p99_hedge*1e3:.3f};"
         f"p99_vs_nohedge={p99_hedge/max(p99_plain, 1e-9):.3f}x;"
         f"speedup_vs_nohedge={plain_s/hedge_s:.2f}x;"
         f"replicas={svc_hedge.replicas[0]};"
         f"devices={len(jax.devices())}")
    for s in (svc_hedge, svc_plain):
        s.shutdown()


def _tiered_serve_comparison() -> None:
    """Tiered residency under memory pressure: a table ~10x the per-device
    HBM byte budget, Zipf(1.2) access, vs a same-run all-hot control.

    The table is cut into 16 IMCU shards but the byte budget only lets a
    few streams be device-resident at once; the Zipf head is mapped to the
    END of the table, so the hot blocks land on shards that START off
    budget (host-warm). During warm-up the monitor promotes the hot
    shards up (displacing the idle early residents down to warm/cold) and
    the steady state is timed: hot-tier launches for the head, parallel
    host-gather misses for the tail, no request ever blocking on a tier
    change. The all-hot control serves the SAME load with no budget
    (every stream resident) — the capacity a real mesh cannot afford at
    this table:budget ratio. The ``compare.py --require`` gate asserts
    ``table_x_budget>=8``, ``tiered_vs_hot>=0.5`` (throughput within 2x
    of all-hot while holding 1/10th of the bytes), ``availability=1``,
    ``bitexact=1``, and at least one observed promotion AND demotion.

    A second, untimed phase measures the miss window itself: two all-warm
    services (budget=1, so EVERY request is a host-gather miss) differing
    only in ``host_gather_workers`` (4 vs 1); the fan-out's p99 cut is
    reported as ``miss_p99_cut`` (not gated: the cut needs spare physical
    cores — on a 1-core CI host the pool can only lose, which is why the
    service's worker default is ``min(4, cpu_count)`` — and thread timing
    is scheduler-sensitive on shared hosts anyway; the record carries
    ``cpus`` so readers can interpret a cut below 1).
    """
    rng = np.random.default_rng(53)
    n = scaled(256_000, 64_000)
    n_req = scaled(600, 300)
    rsz = 64
    n_shards = 16
    data = {
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
    }
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    table = Table.from_data(data, imcu_rows=n // n_shards)

    # size the budget off the real stream bytes: a probe executor with a
    # 1-byte budget commits nothing but still projects every stream
    probe = ShardedFeatureExecutor(
        FeaturePlan(Table.from_data(data, imcu_rows=n // n_shards), fs,
                    packed=True), hbm_budget_bytes=1)
    total_bytes = sum(e.stream_nbytes() for e in probe.executors)
    budget = max(1, total_bytes // 10)
    table_x_budget = total_bytes / budget

    # Zipf(1.2) block ranks mapped to the table END: the hot head lives in
    # the LAST shards — exactly the ones the in-order budget commit left
    # host-warm, so serving pressure must promote them up the ladder
    blocks = (n - rsz) // 32
    ranks = np.minimum(rng.zipf(1.2, n_req), blocks) - 1
    starts = (blocks - 1 - ranks) * 32
    reqs = [np.arange(s, s + rsz) for s in starts]
    rows = n_req * rsz

    plan_t = FeaturePlan(table, fs, packed=True)
    svc = FeatureService(plan_t, sharded=True, buckets=(rsz,), coalesce=8,
                         linger_us=1000, rebalance_every=8, max_replicas=0,
                         hbm_budget_bytes=budget, cold_after=4,
                         host_gather_workers=4)
    svc_hot = FeatureService(
        FeaturePlan(Table.from_data(data, imcu_rows=n // n_shards), fs,
                    packed=True),
        sharded=True, buckets=(rsz,), coalesce=8, linger_us=1000,
        max_replicas=0)

    def tiered_loop():
        for r in reqs:
            svc.submit(r)
        svc.drain()

    def hot_loop():
        for r in reqs:
            svc_hot.submit(r)
        svc_hot.drain()

    loops = [hot_loop, tiered_loop]
    for loop in loops:
        loop()                     # compile
    for _ in range(3):             # monitor converges: head promotes up
        tiered_loop()
    assert svc.stats["promotions"] >= 1, \
        f"monitor never promoted: tiers={svc.tiers} stats={svc.stats}"
    # bit-exact spot check across all tiers (untimed): service output vs
    # the parent plan's host featurize path
    checks = [reqs[0], reqs[-1], np.arange(0, rsz),          # cold/warm head
              rng.integers(0, n, 200)]                       # scatter
    bitexact = all(
        np.array_equal(svc.result(svc.submit(r)), plan_t.host_features(r))
        for r in checks)
    hot_s, tier_s = interleaved_best(loops, repeats=2 * MIN_REPEATS)
    st = svc.throughput_stats(tier_s)
    tiers = svc.tiers
    emit("serve/feature_service_tiered_allhot", hot_s / n_req * 1e6,
         f"rows_per_s={rows/hot_s:.0f};shards={svc_hot.n_shards};"
         f"devices={len(jax.devices())}")
    emit("serve/feature_service_tiered", tier_s / n_req * 1e6,
         f"rows_per_s={rows/tier_s:.0f};"
         f"tiered_vs_hot={hot_s/tier_s:.2f}x;"
         f"table_x_budget={table_x_budget:.1f}x;"
         f"availability={st['availability']:.4f};"
         f"bitexact={int(bitexact)};"
         f"promotions={svc.stats['promotions']};"
         f"demotions={svc.stats['demotions']};"
         f"rehydrations={svc.stats['rehydrations']};"
         f"tier_misses={svc.stats['tier_misses']};"
         f"tier_hot={tiers.count('hot')};tier_warm={tiers.count('warm')};"
         f"tier_cold={tiers.count('cold')};"
         f"budget_bytes={budget};stream_bytes={total_bytes};"
         f"devices={len(jax.devices())}")

    # miss-window phase: all-warm (budget=1) services, pool fan-out 4 vs 1
    def build_miss(workers: int) -> FeatureService:
        return FeatureService(
            FeaturePlan(Table.from_data(data, imcu_rows=n // n_shards), fs,
                        packed=True),
            sharded=True, buckets=(rsz,), coalesce=8, linger_us=1000,
            max_replicas=0, hbm_budget_bytes=1, host_gather_workers=workers)

    svc_m4, svc_m1 = build_miss(4), build_miss(1)
    p99 = {}
    for workers, sm in ((4, svc_m4), (1, svc_m1)):
        for r in reqs[:50]:
            sm.submit(r)
        sm.drain()                 # warm the pool + caches
        sm.latencies.clear()
        for r in reqs:
            sm.submit(r)
        sm.drain()
        p99[workers] = float(np.percentile(np.array(sm.latencies), 99))
        assert sm.stats["promotions"] == 0     # nothing ever fits
    emit("serve/feature_service_tiered_miss_p99",
         p99[4] * 1e6,
         f"miss_p99_ms={p99[4]*1e3:.3f};"
         f"miss_p99_1thread_ms={p99[1]*1e3:.3f};"
         f"miss_p99_cut={p99[1]/max(p99[4], 1e-9):.2f}x;"
         f"host_gather_workers=4;cpus={os.cpu_count()};"
         f"misses={svc_m4.stats['tier_misses']}")
    for s in (svc, svc_hot, svc_m4, svc_m1):
        s.shutdown()


def _frontend_serve_comparison() -> None:
    """Per-class SLOs through the multi-tenant front door under saturation.

    The Zipf 'user block' workload split across request classes and
    pushed through :class:`FeatureFrontend` as a saturating burst (every
    submit lands before the pump can drain, so queues build and the
    scheduler's choices decide who waits): ``interactive`` (priority 3,
    singleton groups, no linger) interleaved 1:3 into a ``batch`` stream
    (priority 2, coalesce 8, 1 ms linger) plus a trickle of ``background``
    scavenger work. The per-class p99s come from the service's streaming
    latency histograms (reset after the compile warmup, so they cover
    only steady-state tickets); the CI ``--require`` gates assert the
    SLO ordering ``p99_interactive_vs_batch < 1`` (priority scheduling
    actually protects the interactive tail — a same-run ratio, machine
    speed cancels), ``availability=1`` over every ADMITTED ticket,
    ``background_completed >= 1`` (anti-starvation aging drains the
    scavenger class under pressure) and ``overloaded >= 1`` (the
    admission probe below really exercised typed rejection). The FIFO
    control record serves the identical mixed burst classless through the
    same-shaped service — the one-queue world whose tail every class
    shares.
    """
    rng = np.random.default_rng(47)
    n = scaled(128_000, 32_000)
    n_inter = scaled(120, 60)
    n_batch = scaled(360, 180)
    n_bg = 8
    rsz = 64
    n_shards = 4
    data = {
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
    }
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    blocks = (n - rsz) // 32

    def zipf_reqs(count):
        ranks = np.minimum(rng.zipf(1.2, count), blocks) - 1
        return [np.arange(s, s + rsz) for s in ranks * 32]

    reqs_batch = zipf_reqs(n_batch)
    reqs_inter = zipf_reqs(n_inter)
    reqs_bg = zipf_reqs(n_bg)
    n_req = n_batch + n_inter + n_bg
    table = Table.from_data(data, imcu_rows=n // n_shards)

    classes = (
        RequestClass("interactive", priority=3, coalesce=1, linger_us=0.0,
                     max_inflight=512, queue_depth=512),
        RequestClass("batch", priority=2, coalesce=8, linger_us=1000.0,
                     max_inflight=1024, queue_depth=1024),
        # tiny admission window: the post-timing probe overflows it to
        # prove typed Overloaded rejection (the timed trickle fits)
        RequestClass("background", priority=1, aging_s=0.05,
                     max_inflight=16, queue_depth=16),
    )

    def build(klasses):
        return FeatureService(FeaturePlan(table, fs, packed=True),
                              sharded=True, buckets=(rsz,), coalesce=8,
                              linger_us=1000, classes=klasses)

    svc = build(classes)
    fe = FeatureFrontend(svc)
    svc_fifo = build(None)

    bg_step = n_batch // n_bg

    def fe_loop():
        k = 0
        for i, r in enumerate(reqs_batch):
            fe.submit(r, klass="batch", tenant="analytics")
            if i % 3 == 0 and k < n_inter:
                fe.submit(reqs_inter[k], klass="interactive",
                          tenant="app")
                k += 1
            if i % bg_step == 0 and i // bg_step < n_bg:
                fe.submit(reqs_bg[i // bg_step],
                          klass="background", tenant="scavenger")
        while k < n_inter:
            fe.submit(reqs_inter[k], klass="interactive", tenant="app")
            k += 1
        fe.collect()

    def fifo_loop():
        for i, r in enumerate(reqs_batch):
            svc_fifo.submit(r)
            if i % 3 == 0:
                svc_fifo.submit(reqs_inter[i // 3 % n_inter])
        svc_fifo.drain()

    loops = [fifo_loop, fe_loop]
    for loop in loops:
        loop()                                             # compile each
    svc.reset_latency_window()
    svc_fifo.reset_latency_window()
    fifo_s, fe_s = interleaved_best(loops, repeats=MIN_REPEATS)

    inter_p99 = svc.latency_percentile(99, "interactive")
    batch_p99 = svc.latency_percentile(99, "batch")
    cs = svc.class_stats()
    # admission probe: overflow the background window while the pump is
    # held — every submit past window + depth must raise typed Overloaded
    svc.pause()
    overloaded, retry_hint = 0, 0.0
    for _ in range(64):
        try:
            fe.submit(reqs_bg[0], klass="background", tenant="scavenger")
        except Overloaded as e:
            overloaded += 1
            retry_hint = e.retry_after_s
    svc.resume()
    fe.collect()
    st = fe.stats()
    emit("serve/feature_service_frontend_fifo", fifo_s / n_req * 1e6,
         f"p99_ms={svc_fifo.latency_percentile(99)*1e3:.3f};"
         f"rows_per_s={(n_batch + n_inter)*rsz/fifo_s:.0f}")
    emit("serve/feature_service_frontend", fe_s / n_req * 1e6,
         f"interactive_p99_ms={inter_p99*1e3:.3f};"
         f"batch_p99_ms={batch_p99*1e3:.3f};"
         f"p99_interactive_vs_batch={inter_p99/max(batch_p99, 1e-9):.3f}x;"
         f"availability={st['availability_admitted']:.4f};"
         f"background_completed={cs['background']['completed']};"
         f"overloaded={overloaded};"
         f"retry_after_ms={retry_hint*1e3:.3f};"
         f"admitted={sum(c['admitted'] for c in st['classes'].values())};"
         f"latency_samples={svc.stats['latency_samples_total']};"
         f"devices={len(jax.devices())}")
    fe.shutdown()
    svc_fifo.shutdown()


def run() -> None:
    N = scaled(1 << 16, 1 << 12)   # device-path rows (interpret mode is slow)
    rng = np.random.default_rng(3)
    ages = rng.integers(0, K, N)
    d, codes = Dictionary.from_data(ages)
    aug = AugmentedDictionary(d)

    catalog = [
        ("float", {}), ("onehot", {"max_cardinality": 4096}),
        ("minmax", {}), ("mean_norm", {}), ("zscore", {}),
        ("binarize", {"threshold": 500.0}),
        ("quantile", {"q": 4}), ("hash_bucket", {"n_buckets": 32}),
        ("bucketize", {"boundaries": np.linspace(0, K, 7)[1:-1]}),
        ("embedding", {"dim": 16}),
    ]
    for kind, params in catalog:
        us = time_call(lambda k=kind, p=params:
                       AugmentedDictionary(d).add(f"b_{k}", k, **p),
                       repeats=5)
        emit(f"table6/build_{kind}", us, f"K={d.cardinality}")

    # row-space application = one gather regardless of transform
    aug.add("zscore", "zscore")
    us = time_call(aug.featurize, "zscore", codes, repeats=5)
    emit("table6/apply_gather_host", us, f"N={N}")

    # device path: Pallas adv_gather (interpret) + count-metadata hist build
    table = jnp.asarray(aug["zscore"].table)
    jcodes = jnp.asarray(codes)
    adv_gather(table, jcodes).block_until_ready()
    us = time_call(lambda: adv_gather(table, jcodes).block_until_ready(),
                   repeats=3)
    emit("table6/apply_gather_pallas_interp", us, f"N={N}")
    hist(jcodes, d.cardinality).block_until_ready()
    us = time_call(lambda: hist(jcodes, d.cardinality).block_until_ready(),
                   repeats=3)
    emit("table6/count_metadata_build_pallas", us, f"K={d.cardinality}")

    _serve_comparison()
    _sharded_serve_comparison()
    _skewed_serve_comparison()
    _chaos_serve_comparison()
    _hedged_serve_comparison()
    _tiered_serve_comparison()
    _frontend_serve_comparison()


if __name__ == "__main__":
    run()
