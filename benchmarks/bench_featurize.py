"""Paper Table 6: the featurization catalog, one benchmark per row —
dictionary-domain cost (K) for each transform + the device gather path
through the Pallas kernels (interpret mode on CPU) + the serving path:
seed-style synchronous FeaturePipeline.batch() loop vs the double-buffered
FeatureService (the ≥1.5x throughput gate) vs the packed fast path
(device-resident word streams, range requests, ~0 per-batch code traffic)."""
from __future__ import annotations

import gc
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.columnar import Dictionary, Table
from repro.core import (AugmentedDictionary, FeaturePipeline, FeaturePlan,
                        FeatureSet)
from repro.kernels.adv_gather import adv_gather
from repro.kernels.hist import hist
from repro.serve import FeatureService
from benchmarks.common import time_call, emit, scaled

K = 999


def _serve_comparison() -> None:
    """Seed loop (per-column dict transfer, sync retire per batch) vs
    FeatureService (stacked single transfer, prefetch-2 double buffer) vs
    packed FeatureService (word-aligned scan ranges off resident words)."""
    rng = np.random.default_rng(11)
    n = scaled(200_000, 8_000)
    batch = scaled(512, 128)
    n_batches = scaled(200, 50)    # smoke needs enough batches for a stable
    repeats = 3                    # CI perf gate; each loop timed best-of-3
    table = Table.from_data({
        "age": rng.integers(18, 90, n),
        "state": rng.integers(0, 50, n),
        "income": rng.integers(20, 250, n) * 1000,
        "device": rng.integers(0, 4, n),
    })
    fs = (FeatureSet().add("age", "zscore")
          .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
          .add("state", "onehot")
          .add("income", "minmax").add("income", "log")
          .add("device", "onehot"))
    pipe = FeaturePipeline(table, fs)
    plan = pipe.plan
    idx_list = [rng.integers(0, n, batch) for _ in range(n_batches)]
    rows = batch * n_batches

    # seed FeaturePipeline.batch() semantics: one transfer per column (dict
    # input), synchronous host retire of every batch
    cols = plan.columns
    codes_host = {c: plan.codes_matrix[i] for i, c in enumerate(cols)}
    tables = {c: plan.plans[i].fused_table for i, c in enumerate(cols)}

    @jax.jit
    def gather_dict(code_batch):
        outs = [jnp.take(tables[c], code_batch[c], axis=0) for c in cols]
        return jnp.concatenate(outs, axis=-1)

    def seed_batch(ix):
        return gather_dict({c: jnp.asarray(codes_host[c][ix]) for c in cols})

    def best_of(loop) -> float:
        """Best-of-``repeats`` wall time: the gateable low-noise estimate."""
        best = float("inf")
        for _ in range(repeats):
            gc.collect()   # GC pauses from earlier modules distort the async
            t0 = time.perf_counter()
            loop()
            best = min(best, time.perf_counter() - t0)
        return best

    np.asarray(seed_batch(idx_list[0]))                    # compile
    seed_s = best_of(lambda: [np.asarray(seed_batch(ix)) for ix in idx_list])

    svc = FeatureService(plan, prefetch=2, buckets=(batch,))
    svc.result(svc.submit(idx_list[0]))                    # compile

    def svc_loop():
        for ix in idx_list:
            svc.submit(ix)
        svc.drain()
    svc_s = best_of(svc_loop)

    emit("serve/seed_batch_loop", seed_s / n_batches * 1e6,
         f"rows_per_s={rows/seed_s:.0f}")
    emit("serve/feature_service_prefetch2", svc_s / n_batches * 1e6,
         f"rows_per_s={rows/svc_s:.0f};speedup={seed_s/svc_s:.2f}x")

    # packed fast path: word streams device-resident, requests are
    # word-aligned scan ranges (the training-epoch serve pattern) — the only
    # per-batch host->device traffic is the start index
    plan_packed = FeaturePlan(table, fs, packed=True)
    svcp = FeatureService(plan_packed, prefetch=2, buckets=(batch,))
    start_list = [int(s) * batch
                  for s in rng.integers(0, n // batch, n_batches)]
    for st in start_list[:svcp.coalesce]:                  # compile the
        svcp.submit(np.arange(st, st + batch))             # coalesced shape
    svcp.drain()

    def packed_loop():
        for st in start_list:
            svcp.submit(np.arange(st, st + batch))
        svcp.drain()
    packed_s = best_of(packed_loop)
    assert svcp.stats["packed_ranges"] >= n_batches        # fast path taken
    emit("serve/feature_service_packed", packed_s / n_batches * 1e6,
         f"rows_per_s={rows/packed_s:.0f};"
         f"speedup_vs_prefetch2={svc_s/packed_s:.2f}x;"
         f"h2d_bytes_int32={plan.bytes_moved_adv(batch)};"
         f"h2d_bytes_packed={plan_packed.bytes_moved_adv(batch)};"
         f"bytes_reduction="
         f"{plan.bytes_moved_adv(batch)/plan_packed.bytes_moved_adv(batch):.1f}x")


def run() -> None:
    N = scaled(1 << 16, 1 << 12)   # device-path rows (interpret mode is slow)
    rng = np.random.default_rng(3)
    ages = rng.integers(0, K, N)
    d, codes = Dictionary.from_data(ages)
    aug = AugmentedDictionary(d)

    catalog = [
        ("float", {}), ("onehot", {"max_cardinality": 4096}),
        ("minmax", {}), ("mean_norm", {}), ("zscore", {}),
        ("binarize", {"threshold": 500.0}),
        ("quantile", {"q": 4}), ("hash_bucket", {"n_buckets": 32}),
        ("bucketize", {"boundaries": np.linspace(0, K, 7)[1:-1]}),
        ("embedding", {"dim": 16}),
    ]
    for kind, params in catalog:
        us = time_call(lambda k=kind, p=params:
                       AugmentedDictionary(d).add(f"b_{k}", k, **p),
                       repeats=5)
        emit(f"table6/build_{kind}", us, f"K={d.cardinality}")

    # row-space application = one gather regardless of transform
    aug.add("zscore", "zscore")
    us = time_call(aug.featurize, "zscore", codes, repeats=5)
    emit("table6/apply_gather_host", us, f"N={N}")

    # device path: Pallas adv_gather (interpret) + count-metadata hist build
    table = jnp.asarray(aug["zscore"].table)
    jcodes = jnp.asarray(codes)
    adv_gather(table, jcodes).block_until_ready()
    us = time_call(lambda: adv_gather(table, jcodes).block_until_ready(),
                   repeats=3)
    emit("table6/apply_gather_pallas_interp", us, f"N={N}")
    hist(jcodes, d.cardinality).block_until_ready()
    us = time_call(lambda: hist(jcodes, d.cardinality).block_until_ready(),
                   repeats=3)
    emit("table6/count_metadata_build_pallas", us, f"K={d.cardinality}")

    _serve_comparison()


if __name__ == "__main__":
    run()
