"""Render dry-run/roofline results into EXPERIMENTS.md (replaces the
RESULTS-PLACEHOLDER-* markers)."""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.roofline import table, load_cells  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def dryrun_section() -> str:
    lines = ["### Compile matrix (status × mesh)", "",
             "| arch | shape | single-pod (256) | multi-pod (512) | "
             "peak GB/dev | fits 16GB |", "|---|---|---|---|---|---|"]
    singles = {(c["arch"], c["shape"]): c for c in load_cells("single")}
    multis = {(c["arch"], c["shape"]): c for c in load_cells("multi")}
    for key in sorted(singles):
        s, m = singles[key], multis.get(key, {})
        st_s, st_m = s.get("status"), m.get("status", "—")
        peak = s.get("raw", {}).get("memory", {}).get("peak_bytes")
        peak_s = f"{peak/1e9:.1f}" if peak else "—"
        fits = ("yes" if peak and peak <= 16e9 else
                "no†" if peak else "—")
        lines.append(f"| {key[0]} | {key[1]} | {st_s} | {st_m} | {peak_s} | "
                     f"{fits} |")
    n_ok = sum(1 for c in singles.values() if c["status"] == "ok")
    n_ok_m = sum(1 for c in multis.values() if c["status"] == "ok")
    lines += ["",
              f"Single-pod: {n_ok} compiled ok + "
              f"{len(singles)-n_ok} skipped(long_500k/full-attention); "
              f"multi-pod: {n_ok_m} ok + {len(multis)-n_ok_m} skipped. "
              "Zero errors.",
              "",
              "† = exceeds 16 GB under XLA:CPU buffer assignment, which "
              "legalizes bf16 matmuls to f32 (≈2x on transient weight "
              "gathers); see §Roofline notes for the analytic TPU budget."]
    return "\n".join(lines)


def roofline_section() -> str:
    rows = table()
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | roofline frac | useful (6ND/HLO) | peak GB | "
             "one-line next-step |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    NEXT = {
        "memory": "fuse/shrink HBM traffic (remat policy, dtype, layout)",
        "collective": "reshard to cut per-layer gathers (see §Perf)",
        "compute": "at roofline — increase per-chip work or stop",
    }
    for r in sorted(rows, key=lambda r: (r["shape"], r["arch"])):
        dom = r["dominant"].replace("_s", "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {dom} | "
            f"{r['roofline_frac']:.2f} | {r['useful_ratio']:.2f} | "
            f"{r['peak_gb']:.1f} | {NEXT[dom]} |")
    skipped = [c for c in load_cells() if c["status"].startswith("skipped")]
    for c in skipped:
        lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — | — "
                     f"| — | {c['status']} |")
    return "\n".join(lines)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("**RESULTS-PLACEHOLDER-DRYRUN**", dryrun_section())
    text = text.replace("**RESULTS-PLACEHOLDER-ROOFLINE**", roofline_section())
    open(path, "w").write(text)
    print("EXPERIMENTS.md rendered")


if __name__ == "__main__":
    main()
