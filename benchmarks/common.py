"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived).

Smoke mode (``run.py --smoke``): modules size their workloads through
``scaled(full, smoke)`` so CI can run the whole suite in seconds. Every
``emit`` is also collected into ``RECORDS`` so ``run.py`` can dump a
``BENCH_*.json`` artifact for the perf trajectory.
"""
from __future__ import annotations

import time
from typing import Callable

SMOKE = False
RECORDS: list[dict] = []


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def scaled(full: int, smoke: int) -> int:
    """Workload size: tiny shapes in smoke mode, paper shapes otherwise."""
    return smoke if SMOKE else full


def time_call(fn: Callable, *args, repeats: int = 5, warmup: int = 2,
              **kwargs) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args, **kwargs)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    RECORDS.append({"name": name, "us_per_call": round(us, 1),
                    "derived": derived})
    print(line, flush=True)
    return line
