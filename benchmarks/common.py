"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived).

Smoke mode (``run.py --smoke``): modules size their workloads through
``scaled(full, smoke)`` so CI can run the whole suite in seconds. Every
``emit`` is also collected into ``RECORDS`` so ``run.py`` can dump a
``BENCH_*.json`` artifact for the perf trajectory (``benchmarks/compare.py``
gates CI on it).

Timing uses ``time.perf_counter_ns`` with an adaptive inner loop: sub-
microsecond calls (dictionary-domain ops on tiny smoke shapes) are batched
until one repeat spans ``MIN_REPEAT_NS``, so records are nonzero and
comparable across runs instead of collapsing to 0.0 at clock resolution.
"""
from __future__ import annotations

import time
from typing import Callable

SMOKE = False
RECORDS: list[dict] = []

# one timed repeat must span at least this long for a stable median; the
# probe call decides how many inner calls that takes
MIN_REPEAT_NS = 200_000
MAX_INNER = 10_000


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def scaled(full: int, smoke: int) -> int:
    """Workload size: tiny shapes in smoke mode, paper shapes otherwise."""
    return smoke if SMOKE else full


def time_call(fn: Callable, *args, repeats: int = 5, warmup: int = 2,
              **kwargs) -> float:
    """Median wall time per call in microseconds (ns clock, adaptive loop)."""
    for _ in range(warmup):
        fn(*args, **kwargs)
    t0 = time.perf_counter_ns()          # probe: sizes the inner loop
    fn(*args, **kwargs)
    probe_ns = max(time.perf_counter_ns() - t0, 1)
    inner = max(1, min(MAX_INNER, MIN_REPEAT_NS // probe_ns))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter_ns()
        for _ in range(inner):
            fn(*args, **kwargs)
        times.append((time.perf_counter_ns() - t0) / inner)
    times.sort()
    return times[len(times) // 2] / 1e3


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.3f},{derived}"
    RECORDS.append({"name": name, "us_per_call": round(us, 3),
                    "derived": derived})
    print(line, flush=True)
    return line
