"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived).

Smoke mode (``run.py --smoke``): modules size their workloads through
``scaled(full, smoke)`` so CI can run the whole suite in seconds. Every
``emit`` is also collected into ``RECORDS`` so ``run.py`` can dump a
``BENCH_*.json`` artifact for the perf trajectory (``benchmarks/compare.py``
gates CI on it).

Timing uses ``time.perf_counter_ns`` with an adaptive inner loop: sub-
microsecond calls (dictionary-domain ops on tiny smoke shapes) are batched
until one repeat spans ``MIN_REPEAT_NS``, so records are nonzero and
comparable across runs instead of collapsing to 0.0 at clock resolution.

Estimates are BEST-of-N (N >= ``MIN_REPEATS``), not medians: scheduler
noise, GC pauses and cache-cold runs only ever ADD time, so the minimum is
the low-noise estimate of the code's true cost — and the one the CI perf
gate (``benchmarks/compare.py``) can compare across runs without tripping
on a single slow repeat.
"""
from __future__ import annotations

import gc
import time
from typing import Callable

SMOKE = False
RECORDS: list[dict] = []

# one timed repeat must span at least this long for a stable best-of; the
# probe call decides how many inner calls that takes
MIN_REPEAT_NS = 200_000
MAX_INNER = 10_000
# best-of-N needs enough repeats that at least one dodges the scheduler
MIN_REPEATS = 5


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def scaled(full: int, smoke: int) -> int:
    """Workload size: tiny shapes in smoke mode, paper shapes otherwise."""
    return smoke if SMOKE else full


def time_call(fn: Callable, *args, repeats: int = MIN_REPEATS,
              warmup: int = 2, **kwargs) -> float:
    """Best-of-N wall time per call in microseconds (ns clock, adaptive
    loop). ``repeats`` is clamped up to ``MIN_REPEATS`` so a single noisy
    run can never be the reported number."""
    repeats = max(repeats, MIN_REPEATS)
    for _ in range(warmup):
        fn(*args, **kwargs)
    t0 = time.perf_counter_ns()          # probe: sizes the inner loop
    fn(*args, **kwargs)
    probe_ns = max(time.perf_counter_ns() - t0, 1)
    inner = max(1, min(MAX_INNER, MIN_REPEAT_NS // probe_ns))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter_ns()
        for _ in range(inner):
            fn(*args, **kwargs)
        best = min(best, (time.perf_counter_ns() - t0) / inner)
    return best / 1e3


def interleaved_best(loops: list[Callable[[], None]],
                     repeats: int = MIN_REPEATS) -> list[float]:
    """Best-of-N for SEVERAL loops with round-robin repeats.

    Comparative serving benchmarks gate on the RATIO between contenders;
    timing each loop's repeats back-to-back lets slow phases (thermal
    throttle, background load, allocator state drift) land entirely on one
    contender and swing the ratio run to run. Interleaving spreads any
    slow phase across all contenders, so each one's best-of-N is drawn
    from the same conditions.
    """
    repeats = max(repeats, MIN_REPEATS)
    bests = [float("inf")] * len(loops)
    for _ in range(repeats):
        for i, loop in enumerate(loops):
            gc.collect()
            t0 = time.perf_counter()
            loop()
            bests[i] = min(bests[i], time.perf_counter() - t0)
    return bests


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.3f},{derived}"
    RECORDS.append({"name": name, "us_per_call": round(us, 3),
                    "derived": derived})
    print(line, flush=True)
    return line
