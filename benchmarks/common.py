"""Benchmark utilities: timing + CSV emission (name,us_per_call,derived)."""
from __future__ import annotations

import time
from typing import Callable


def time_call(fn: Callable, *args, repeats: int = 5, warmup: int = 2,
              **kwargs) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args, **kwargs)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
