"""Benchmark harness — one module per paper table/figure.

Output format: ``name,us_per_call,derived`` CSV lines, plus a JSON dump of
all records (``BENCH_full.json`` / ``BENCH_smoke.json``) for CI artifacts.

  table2  bits-to-encode + compression ratios          (paper Table 2, §5.1)
  table3  count-metadata stats vs scans                (paper §6.2)
  table4/5  ADV featurization vs recompute             (paper §6.3)
  table6  featurization catalog build/apply            (paper §6.1)
  serve   seed loop vs pump FeatureService vs packed
          range/random coalesced serving               (serving trajectory)
  query   predicate pushdown: on-device scan+compact+serve
          vs host filter-then-gather                   (paper §5/§6)
  fig1/2  end-to-end pipeline: traditional vs ADV      (paper Figs 1-2)
  roofline  dry-run derived terms (if results present) (EXPERIMENTS.md)

``--smoke`` shrinks every workload to tiny shapes (seconds, not minutes) so
CI can gate on the full module sweep every push.
"""
from __future__ import annotations

import argparse
import gc
import json
import platform
import sys
import traceback

from benchmarks import common


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="record dump path (default BENCH_<mode>.json)")
    args = ap.parse_args(argv)
    common.set_smoke(args.smoke)
    mode = "smoke" if args.smoke else "full"
    out_path = args.json or f"BENCH_{mode}.json"

    print("name,us_per_call,derived")
    from benchmarks import (bench_compression, bench_count_stats, bench_adv,
                            bench_featurize, bench_query, bench_pipeline)
    mods = [bench_compression, bench_count_stats, bench_adv,
            bench_featurize, bench_query, bench_pipeline]
    try:
        from benchmarks import roofline
        mods.append(roofline)
    except ImportError:
        pass
    failures = 0
    for mod in mods:
        gc.collect()       # don't let one module's garbage time the next
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"# FAILED {mod.__name__}", file=sys.stderr)
            traceback.print_exc()
    with open(out_path, "w") as fh:
        json.dump({"mode": mode, "python": platform.python_version(),
                   "platform": platform.platform(),
                   "failed_modules": failures,
                   "records": common.RECORDS}, fh, indent=1)
    print(f"# wrote {len(common.RECORDS)} records to {out_path}",
          file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
