"""Benchmark harness — one module per paper table/figure.

Output format: ``name,us_per_call,derived`` CSV lines.

  table2  bits-to-encode + compression ratios          (paper Table 2, §5.1)
  table3  count-metadata stats vs scans                (paper §6.2)
  table4/5  ADV featurization vs recompute             (paper §6.3)
  table6  featurization catalog build/apply            (paper §6.1)
  fig1/2  end-to-end pipeline: traditional vs ADV      (paper Figs 1-2)
  roofline  dry-run derived terms (if results present) (EXPERIMENTS.md)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (bench_compression, bench_count_stats, bench_adv,
                            bench_featurize, bench_pipeline)
    mods = [bench_compression, bench_count_stats, bench_adv,
            bench_featurize, bench_pipeline]
    try:
        from benchmarks import roofline
        mods.append(roofline)
    except ImportError:
        pass
    failures = 0
    for mod in mods:
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"# FAILED {mod.__name__}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
