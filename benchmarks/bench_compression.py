"""Paper Table 2 + §5.1 claims: bits-to-encode, dictionary compression
ratios (2x-30x claim), RLE on sorted data, CSV-vs-binary inflation (§6.1.1).
"""
from __future__ import annotations

import numpy as np

from repro.columnar import Column, bits_needed
from repro.columnar.bitpack import pack_bits, packed_nbytes
from benchmarks.common import time_call, emit, scaled

TABLE2 = [
    ("binary_gender", 2), ("season", 4), ("marital_status", 5),
    ("months", 12), ("us_states", 50), ("age_years", 150),
    ("countries", 195), ("day_of_year", 366), ("us_area_code", 999),
    ("us_zip", 99_999), ("unique_512k", 524_288),
]

STATES = np.array([f"State_{i:02d}" for i in range(50)])


def run() -> None:
    N = scaled(1 << 19, 1 << 12)       # one IMCU (paper: 512K rows)
    rng = np.random.default_rng(0)
    # Table 2: bits to encode (timed: sub-us calls need the adaptive ns loop)
    for name, card in TABLE2:
        us = time_call(bits_needed, card, repeats=5)
        emit(f"table2/{name}", us,
             f"cardinality={card};bits={bits_needed(card)}")

    # dictionary compression ratio on a string state column (paper §5.1)
    data = STATES[rng.integers(0, 50, N)]
    col = Column.from_data(data, use_rle=False)
    us = time_call(lambda: Column.from_data(data, use_rle=False), repeats=3)
    emit("compress/states_string", us,
         f"ratio={col.compression_ratio:.1f}x;bits={col.dictionary.bits}")

    # int64 timestamps -> day-of-year codes
    days = rng.integers(0, 366, N)
    col = Column.from_data(days, use_rle=False)
    emit("compress/day_of_year_int64", 0.0,
         f"ratio={col.compression_ratio:.1f}x;bits={col.dictionary.bits}")

    # RLE on sorted data (§5.2)
    sorted_days = np.sort(days)
    col_rle = Column.from_data(sorted_days, use_rle=True)
    col_no = Column.from_data(sorted_days, use_rle=False)
    emit("compress/rle_sorted", 0.0,
         f"rle_bytes={col_rle.packed_nbytes};"
         f"packed_bytes={col_no.packed_nbytes};"
         f"gain={col_no.packed_nbytes/max(col_rle.packed_nbytes,1):.1f}x")

    # §6.1.1: CSV float inflation (up to 7x claim — full-precision repr hits
    # the paper's 14-char bound; 6-sig-digit export is the compact case)
    floats = rng.standard_normal(N).astype(np.float32)
    csv6 = sum(len(f"{x:.6g}") + 1 for x in floats[:4096]) / 4096 * N
    csv_full = sum(len(np.format_float_positional(x, unique=True)) + 1
                   for x in floats[:4096]) / 4096 * N
    emit("compress/csv_vs_binary_f32", 0.0,
         f"csv6={csv6/1e6:.1f}MB;csv_full={csv_full/1e6:.1f}MB;"
         f"binary={floats.nbytes/1e6:.1f}MB;"
         f"inflation6={csv6/floats.nbytes:.1f}x;"
         f"inflation_full={csv_full/floats.nbytes:.1f}x")

    # bit-pack throughput
    codes = rng.integers(0, 50, N)
    us = time_call(pack_bits, codes, 6, repeats=3)
    emit("compress/pack_bits_6b", us,
         f"MBps={packed_nbytes(N,6)/us:.0f}")


if __name__ == "__main__":
    run()
