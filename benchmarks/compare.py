"""CI perf gate: diff a fresh BENCH_*.json against the committed baseline.

Usage:
    python benchmarks/compare.py BASELINE.json FRESH.json \
        --gate serve/feature_service_prefetch2 [--gate NAME ...] \
        --max-regress 0.20 [--normalize-by serve/seed_batch_loop]

Prints a delta table for every record present in both files and exits
nonzero if any gated record's ``us_per_call`` regressed by more than
``--max-regress`` (relative). Gated records missing from either file fail
the gate outright — a silently dropped benchmark must not pass CI.

``--normalize-by NAME`` divides each gated time by the SAME run's NAME
time before comparing, so a baseline recorded on one machine gates a fresh
run on different hardware: absolute wall-clock cancels out and only the
code's relative cost vs the reference workload is compared.

``--require RECORD:KEY<OP>VALUE`` (repeatable) asserts on a metric the
FRESH run's record carries in its ``derived`` string (``key=value;...``),
e.g. ``--require "serve/feature_service_chaos:availability>=1.0"`` — the
chaos gate: a run that lost a ticket fails CI regardless of its timing.
Ops: ``>=``, ``<=``, ``>``, ``<``, ``=``/``==``.

Gated serving records are produced with interleaved best-of-N timing
(``benchmarks/common.interleaved_best``), so a single slow repeat or a
machine-speed drift mid-run cannot be the gated number — the gate compares
low-noise minima, not one-shot medians.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

_REQUIRE_RE = re.compile(
    r"^(?P<name>[^:]+):(?P<key>[A-Za-z0-9_.]+)"
    r"(?P<op>>=|<=|==|=|>|<)(?P<value>-?[0-9.]+)x?$")
_OPS = {">=": lambda a, b: a >= b, "<=": lambda a, b: a <= b,
        ">": lambda a, b: a > b, "<": lambda a, b: a < b,
        "=": lambda a, b: a == b, "==": lambda a, b: a == b}


def load_records(path: str) -> dict[str, dict]:
    with open(path) as fh:
        doc = json.load(fh)
    return {r["name"]: r for r in doc.get("records", [])}


def derived_metric(rec: dict, key: str) -> float | None:
    """Pull ``key`` out of a record's ``key=value;...`` derived string
    (a trailing unit suffix like ``2.00x`` parses as its number)."""
    for part in str(rec.get("derived", "")).split(";"):
        k, _, v = part.partition("=")
        if k.strip() == key:
            m = re.match(r"-?[0-9.]+", v.strip())
            if m:
                return float(m.group(0))
    return None


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_*.json")
    ap.add_argument("fresh", help="freshly produced BENCH_*.json")
    ap.add_argument("--gate", action="append", default=[],
                    metavar="RECORD_NAME",
                    help="record(s) whose regression fails the build "
                         "(default: serve/feature_service_prefetch2)")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="max allowed relative us_per_call increase on "
                         "gated records (default 0.20 = +20%%)")
    ap.add_argument("--normalize-by", default=None, metavar="RECORD_NAME",
                    help="divide gated times by this record's time from the "
                         "same run (cancels machine speed differences)")
    ap.add_argument("--require", action="append", default=[],
                    metavar="RECORD:KEY<OP>VALUE",
                    help="assert a derived metric of a FRESH record, e.g. "
                         "'serve/feature_service_chaos:availability>=1.0'")
    args = ap.parse_args(argv)
    gates = args.gate or ["serve/feature_service_prefetch2"]

    base = load_records(args.baseline)
    fresh = load_records(args.fresh)

    def gated_value(recs: dict[str, dict], name: str) -> float:
        us = recs[name]["us_per_call"]
        if args.normalize_by is None:
            return us
        ref = recs.get(args.normalize_by)
        if ref is None or not ref["us_per_call"]:
            raise SystemExit(f"--normalize-by record {args.normalize_by!r} "
                             "missing or zero")
        return us / ref["us_per_call"]

    print(f"{'record':50s} {'base_us':>12s} {'fresh_us':>12s} {'delta':>8s}")
    for name in sorted(base.keys() & fresh.keys()):
        b, f = base[name]["us_per_call"], fresh[name]["us_per_call"]
        delta = (f - b) / b if b else float("inf") if f else 0.0
        mark = " <- GATE" if name in gates else ""
        print(f"{name:50s} {b:12.3f} {f:12.3f} {delta:+7.1%}{mark}")

    failures = []
    unit = "" if args.normalize_by is None else "x"
    for name in gates:
        if name not in base or name not in fresh:
            failures.append(f"{name}: missing from "
                            f"{'baseline' if name not in base else 'fresh'} "
                            "records")
            continue
        b, f = gated_value(base, name), gated_value(fresh, name)
        if b and (f - b) / b > args.max_regress:
            failures.append(f"{name}: {b:.3f}{unit or 'us'} -> "
                            f"{f:.3f}{unit or 'us'} "
                            f"({(f - b) / b:+.1%} > +{args.max_regress:.0%})")
    for req in args.require:
        m = _REQUIRE_RE.match(req)
        if not m:
            raise SystemExit(f"bad --require spec {req!r} "
                             "(want RECORD:KEY<OP>VALUE)")
        name, key, op = m["name"], m["key"], m["op"]
        rec = fresh.get(name)
        if rec is None:
            failures.append(f"{name}: missing from fresh records "
                            f"(required {key}{op}{m['value']})")
            continue
        got = derived_metric(rec, key)
        if got is None:
            failures.append(f"{name}: derived metric {key!r} not found "
                            f"in {rec.get('derived', '')!r}")
        elif not _OPS[op](got, float(m["value"])):
            failures.append(f"{name}: {key}={got} violates "
                            f"{key}{op}{m['value']}")
        else:
            print(f"require ok: {name}: {key}={got} satisfies "
                  f"{op}{m['value']}")
    if failures:
        for msg in failures:
            print(f"PERF GATE FAILED: {msg}", file=sys.stderr)
        return 1
    print(f"perf gate ok: {', '.join(gates)} within "
          f"+{args.max_regress:.0%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
