"""Query pushdown: 'serve features WHERE ...' as one device pipeline.

Two contenders over the SAME packed plan, interleaved best-of-N per the
PR 3/4 gate methodology (the CI gate compares the same-run ratio, so
machine speed cancels):

- ``query/pushdown_filtered_serve_hostfilter`` — the pre-pushdown path:
  the host compiles the predicate to code space, decodes the referenced
  columns per-IMCU to build the row mask (``predicate_mask_host``), then
  serves the matches through the pre-packed code-ship path (host gathers
  (C, B) int32 codes, ships them, one launch per request, prefetch-2
  retire). Every request round-trips a decoded code stream through host
  memory.
- ``query/pushdown_filtered_serve`` — the pushdown path: the predicate
  scan evaluates dictionary-code terms directly on the resident packed
  word streams (unpack + compare fused, XLA split scan), the selection
  compacts to row indices on device, and those indices feed the packed
  gather — filter and serve never leave the device; only the match count
  (one scalar) and the final feature block cross back.

Requests cycle through a family of ``state IN {..} AND age > cutoff``
predicates with identical compiled shapes (same LUT length, same term
kinds), so the scan compiles once and the timed loops measure steady-state
serving, matching how a deployed filter family behaves.

``query/masked_agg_pushdown`` additionally times the dict-aware masked
aggregate (``agg_where`` mean: masked per-code histogram, K-entry tail)
against the host equivalent (mask + decode + reduce over N rows).
"""
from __future__ import annotations

from collections import deque

import numpy as np
import jax

from repro.columnar import Table
from repro.columnar import query as colquery
from repro.core import FeatureExecutor, FeaturePlan, FeatureSet
from repro.core.pipeline import pad_rows_edge
from benchmarks.common import (MIN_REPEATS, emit, interleaved_best, scaled,
                               time_call)


def _filtered_serve_comparison() -> None:
    rng = np.random.default_rng(23)
    # smoke keeps a serving-scale row count: the pushdown win is the O(n)
    # host decode it deletes, and per-request dispatch overheads (~0.5ms on
    # the forced 4-device CPU mesh) would swamp it at toy shapes
    n = scaled(200_000, 96_000)
    n_req = scaled(40, 10)
    data = {
        "age": rng.integers(18, 91, n),
        "state": rng.integers(0, 51, n),
        "income": rng.integers(20, 250, n) * 1000,
        "device": rng.integers(0, 6, n),
    }
    table = Table.from_data(data)
    fs = (FeatureSet().add("age", "zscore")
          .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
          .add("state", "onehot")
          .add("income", "minmax").add("income", "log")
          .add("device", "onehot"))
    plan = FeaturePlan(table, fs, packed=True)
    ex = FeatureExecutor(plan, prefetch=2)

    # one predicate family, many parameterizations: same LUT length and
    # term kinds -> the scan compiles once, like a deployed filter family
    preds = []
    for _ in range(n_req):
        states = rng.choice(51, 3, replace=False).tolist()
        cutoff = int(rng.integers(50, 76))
        preds.append(colquery.isin("state", states)
                     & colquery.gt("age", cutoff))
    sel = np.mean([colquery.predicate_mask_host(table, p).mean()
                   for p in preds])

    def pushdown_loop():
        for p in preds:
            _, feats = ex.batch_where(p)
            np.asarray(feats)

    def hostfilter_loop():
        inflight = deque()
        for p in preds:
            mask = colquery.predicate_mask_host(table, p)
            rows = np.flatnonzero(mask)
            codes = plan.host_codes(pad_rows_edge(rows, 32))
            inflight.append((rows.size,
                             ex.gather_device(jax.device_put(codes))))
            if len(inflight) >= 2:
                sz, fut = inflight.popleft()
                np.asarray(fut)[:sz]
        while inflight:
            sz, fut = inflight.popleft()
            np.asarray(fut)[:sz]

    loops = [hostfilter_loop, pushdown_loop]
    for loop in loops:
        loop()                                             # compile each
    host_s, push_s = interleaved_best(loops, repeats=2 * MIN_REPEATS)

    matched = int(sum(colquery.predicate_mask_host(table, p).sum()
                      for p in preds))
    emit("query/pushdown_filtered_serve_hostfilter", host_s / n_req * 1e6,
         f"rows_per_s={matched/host_s:.0f};"
         f"path=host_imcu_decode+mask+code_ship;n={n}")
    emit("query/pushdown_filtered_serve", push_s / n_req * 1e6,
         f"rows_per_s={matched/push_s:.0f};"
         f"speedup_vs_hostfilter={host_s/push_s:.2f}x;"
         f"selectivity={sel:.4f};n={n};"
         f"host_bytes_per_req=count_scalar_only")

    # dict-aware masked aggregate: K-entry tail work vs an N-row host pass
    pred = preds[0]
    mask_host = colquery.predicate_mask_host(table, pred)
    age_vals = table["age"].dictionary.values
    age_codes = table["age"].codes()

    def host_agg():
        m = colquery.predicate_mask_host(table, pred)
        return float(age_vals.astype(np.float64)[age_codes[m]].mean())

    ex.agg_where(pred, "age", "mean")                       # compile
    push_us = time_call(lambda: ex.agg_where(pred, "age", "mean"),
                        repeats=MIN_REPEATS)
    host_us = time_call(host_agg, repeats=MIN_REPEATS)
    assert np.isclose(ex.agg_where(pred, "age", "mean"),
                      age_vals.astype(np.float64)[age_codes[mask_host]].mean())
    emit("query/masked_agg_pushdown", push_us,
         f"host_us={host_us:.1f};speedup_vs_host={host_us/push_us:.2f}x;"
         f"k={table['age'].dictionary.cardinality};n={n}")


def run() -> None:
    _filtered_serve_comparison()


if __name__ == "__main__":
    run()
