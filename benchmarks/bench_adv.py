"""Paper §6.3 (Tables 4/5): ADV featurization vs recompute-from-raw.

Two bucketizations of a state column (Table 4) and a multi-ADV age
dictionary (Table 5). The derived columns report the paper's central
quantities: bytes moved on each path and the gather-vs-recompute speedup.
"""
from __future__ import annotations

import numpy as np

from repro.columnar import Dictionary
from repro.columnar.bitpack import packed_nbytes
from repro.core import AugmentedDictionary
from benchmarks.common import time_call, emit, scaled


def run() -> None:
    N = scaled(1 << 19, 1 << 12)
    rng = np.random.default_rng(2)

    # Table 4: state column with region + division bucketizations
    states = np.array([f"State_{i:02d}" for i in range(50)])
    region = {s: float(i % 4) for i, s in enumerate(states)}
    division = {s: float(i % 9) for i, s in enumerate(states)}
    data = states[rng.integers(0, 50, N)]
    d, codes = Dictionary.from_data(data)
    aug = AugmentedDictionary(d)
    aug.add("region", "bucketize_cat", mapping=region)
    aug.add("division", "bucketize_cat", mapping=division)
    us_adv = time_call(aug.featurize_many, ["region", "division"], codes,
                       repeats=5)
    us_rec = time_call(
        lambda: np.stack([aug.featurize_recompute("region", codes)[:, 0],
                          aug.featurize_recompute("division", codes)[:, 0]],
                         axis=1), repeats=3)
    emit("table4/state_2buckets_adv", us_adv,
         f"speedup={us_rec/max(us_adv,0.1):.1f}x")
    emit("table4/state_2buckets_recompute", us_rec, "")
    emit("table4/bytes_moved", 0.0,
         f"adv_codes={packed_nbytes(N, d.bits)};"
         f"recompute_f32={4*2*N};"
         f"reduction={4*2*N/packed_nbytes(N, d.bits):.0f}x")

    # Table 5: age dictionary with decade/float/group + learned buckets
    ages = rng.integers(8, 92, N)
    d2, codes2 = Dictionary.from_data(ages)
    aug2 = AugmentedDictionary(d2)
    aug2.add("decade", "bucketize", boundaries=np.arange(10, 100, 10.0))
    aug2.add("age_fp", "float")
    aug2.add("age_group", "bucketize", boundaries=np.array([4., 13., 17., 22., 65.]))
    aug2.add("q4", "quantile", q=4)
    names = ["decade", "age_fp", "age_group", "q4"]
    us_adv = time_call(aug2.featurize_many, names, codes2, repeats=5)
    us_rec = time_call(
        lambda: [aug2.featurize_recompute(n, codes2) for n in names],
        repeats=3)
    emit("table5/age_4advs_adv", us_adv,
         f"speedup={us_rec/max(us_adv,0.1):.1f}x")
    emit("table5/age_4advs_recompute", us_rec, "")
    emit("table5/bytes_moved", 0.0,
         f"adv_codes={packed_nbytes(N, d2.bits)};"
         f"recompute_f32={4*4*N};"
         f"reduction={4*4*N/packed_nbytes(N, d2.bits):.0f}x")


if __name__ == "__main__":
    run()
