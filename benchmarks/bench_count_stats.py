"""Paper §6.2 (Table 3): count-metadata stats vs full scans.

The dictionary carries per-entry counts, so SUM/AVG/STD/histogram/minmax are
K-cost operations; the baseline decodes and scans N rows. Reported derived
value = speedup and the N/K ratio that explains it.
"""
from __future__ import annotations

import numpy as np

from repro.columnar import Column
from repro.columnar import stats
from benchmarks.common import time_call, emit, scaled


def run() -> None:
    N = scaled(1 << 19, 1 << 12)
    rng = np.random.default_rng(1)
    for card, tag in [(50, "states"), (999, "area_code"), (99_999, "zip")]:
        data = rng.integers(0, card, N)
        col = Column.from_data(data, use_rle=False)
        for op in ("sum", "mean", "std", "histogram", "minmax"):
            fast = getattr(stats, f"{op}_from_dictionary")
            slow = getattr(stats, f"{op}_scan")
            us_fast = time_call(fast, col, repeats=5)
            us_slow = time_call(slow, col, repeats=3)
            emit(f"table3/{tag}/{op}_dict", us_fast,
                 f"speedup={us_slow/max(us_fast,0.1):.0f}x;"
                 f"N/K={N//card}")
            emit(f"table3/{tag}/{op}_scan", us_slow, "")


if __name__ == "__main__":
    run()
