"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json and emits, per (arch × shape) on the single-pod
mesh: the three roofline terms (compute / memory / collective seconds), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS utility ratio, and per-device
peak HBM. Also usable as a library by EXPERIMENTS tooling.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

N_CHIPS_SINGLE = 256


def load_cells(mesh: str = "single", variant: str = "baseline") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS,
                                           f"*__{mesh}__{variant}.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def row(cell: dict) -> dict | None:
    if cell.get("status") != "ok" or "roofline" not in cell:
        return None
    r = cell["roofline"]
    ex = cell["extrapolated"]
    hlo_flops_global = ex["flops"] * cell["n_chips"]
    util = cell["model_flops"] / hlo_flops_global if hlo_flops_global else 0.0
    mem = cell["raw"]["memory"]
    return {
        "arch": cell["arch"], "shape": cell["shape"],
        "variant": cell.get("variant", "baseline"),
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "dominant": r["dominant"],
        "bound_s": r["bound_s"],
        "roofline_frac": r["compute_s"] / r["bound_s"] if r["bound_s"] else 0,
        "model_flops": cell["model_flops"],
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": util,
        "peak_gb": mem.get("peak_bytes", 0) / 1e9,
        "fits_16gb": mem.get("peak_bytes", 1e18) <= 16e9,
    }


def table(mesh: str = "single", variant: str = "baseline") -> list[dict]:
    rows = []
    for cell in load_cells(mesh, variant):
        r = row(cell)
        if r:
            rows.append(r)
    return rows


def run() -> None:
    from benchmarks.common import emit
    for r in table():
        emit(f"roofline/{r['arch']}/{r['shape']}",
             r["bound_s"] * 1e6,
             f"dom={r['dominant'].replace('_s','')};"
             f"compute={r['compute_s']*1e3:.1f}ms;"
             f"memory={r['memory_s']*1e3:.1f}ms;"
             f"collective={r['collective_s']*1e3:.1f}ms;"
             f"frac={r['roofline_frac']:.2f};"
             f"useful={r['useful_ratio']:.2f};"
             f"peak={r['peak_gb']:.1f}GB")
    # skips
    for cell in load_cells():
        if cell.get("status", "").startswith("skipped"):
            emit(f"roofline/{cell['arch']}/{cell['shape']}", 0.0,
                 cell["status"])


if __name__ == "__main__":
    run()
