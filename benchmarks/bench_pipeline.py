"""Paper Figures 1/2: end-to-end featurization-into-training comparison.

Traditional pipeline (Fig 1): decode to row values -> 'CSV export' (text) ->
re-parse -> row-space transforms -> ship f32 features -> train step.
ADV pipeline (Fig 2): ship packed codes -> device gather through resident
ADV tables -> train step. Both feed the same Wide&Deep model; derived
columns report wall time and host->device bytes.
"""
from __future__ import annotations

import io
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.columnar import Table
from repro.core import FeatureSet, FeaturePipeline
from repro.models.widedeep import (WideDeepConfig, init_widedeep,
                                   make_widedeep_train_step)
from benchmarks.common import emit, scaled


def _dataset(rng, N):
    age = rng.integers(18, 90, N)
    state = rng.integers(0, 50, N)
    income = rng.integers(20, 250, N) * 1000
    device = rng.integers(0, 4, N)
    # label correlated with features
    y = ((age > 40).astype(float) * 0.5 +
         (income > 100_000).astype(float) * 0.8 +
         (state % 4 == 0).astype(float) * 0.3 +
         rng.standard_normal(N) * 0.3 > 0.8).astype(np.float32)
    return {"age": age, "state": state, "income": income,
            "device": device}, y


def run() -> None:
    N = scaled(40_000, 4_000)
    BATCH = scaled(1024, 128)
    STEPS = scaled(8, 3)
    rng = np.random.default_rng(4)
    raw, y = _dataset(rng, N)
    table = Table.from_data(raw)
    fs = (FeatureSet()
          .add("age", "zscore")
          .add("age", "bucketize", boundaries=(30.0, 45.0, 65.0))
          .add("income", "minmax")
          .add("income", "log"))
    pipe = FeaturePipeline(table, fs)
    wide_cols = ["state", "device"]
    wd_cfg = WideDeepConfig(
        wide_cards=(50, 4), deep_dim=pipe.out_dim,
        embed_cols=((50, 8),), hidden=(32, 16))
    params = init_widedeep(wd_cfg, jax.random.PRNGKey(0))
    step = make_widedeep_train_step(wd_cfg, lr=0.1)
    codes = {c: table[c].codes() for c in wide_cols}

    # --- ADV path ---
    t0 = time.perf_counter()
    p = params
    for i in range(STEPS):
        idx = rng.integers(0, N, BATCH)
        deep = pipe.batch(idx)                       # device ADV gather
        wide = jnp.stack([jnp.asarray(codes[c][idx]) for c in wide_cols])
        emb = [jnp.asarray(codes["state"][idx])]
        p, loss = step(p, wide, deep, jnp.asarray(y[idx]), emb)
    jax.block_until_ready(loss)
    adv_s = time.perf_counter() - t0
    adv_bytes = STEPS * (pipe.bytes_moved_adv(BATCH) + 2 * BATCH + BATCH)
    emit("fig2/adv_pipeline_8steps", adv_s * 1e6,
         f"loss={float(loss):.4f};host2dev_bytes={adv_bytes}")

    # --- traditional path: decode -> CSV text -> parse -> row transforms ---
    t0 = time.perf_counter()
    p = params
    for i in range(STEPS):
        idx = rng.integers(0, N, BATCH)
        rows = {c: table[c].decode()[idx] for c in
                ("age", "income", "state", "device")}
        buf = io.StringIO()
        for j in range(BATCH):                       # CSV materialization
            buf.write(f"{rows['age'][j]},{rows['income'][j]},"
                      f"{rows['state'][j]},{rows['device'][j]}\n")
        buf.seek(0)
        parsed = np.loadtxt(buf, delimiter=",", dtype=np.float64)
        age, income = parsed[:, 0], parsed[:, 1]
        a_all = table["age"].decode().astype(np.float64)
        i_all = table["income"].decode().astype(np.float64)
        deep_np = np.stack([
            (age - a_all.mean()) / a_all.std(),
            np.searchsorted([30., 45., 65.], age, side="right"),
            (income - i_all.min()) / (i_all.max() - i_all.min()),
            np.log1p(income),
        ], axis=1).astype(np.float32)
        deep = jnp.asarray(deep_np)                  # ship f32 features
        wide = jnp.stack([jnp.asarray(parsed[:, 2].astype(np.int32)),
                          jnp.asarray(parsed[:, 3].astype(np.int32))])
        emb = [jnp.asarray(parsed[:, 2].astype(np.int32))]
        p, loss = step(p, wide, deep, jnp.asarray(y[idx]), emb)
    jax.block_until_ready(loss)
    trad_s = time.perf_counter() - t0
    trad_bytes = STEPS * (4 * BATCH * pipe.out_dim + 4 * 2 * BATCH + 4 * BATCH)
    emit("fig1/traditional_pipeline_8steps", trad_s * 1e6,
         f"loss={float(loss):.4f};host2dev_bytes={trad_bytes}")
    emit("fig2/end_to_end", 0.0,
         f"speedup={trad_s/adv_s:.1f}x;"
         f"bytes_reduction={trad_bytes/adv_bytes:.1f}x")


if __name__ == "__main__":
    run()
