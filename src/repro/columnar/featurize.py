"""Featurization catalog (paper §6.1 / Table 6) in the *dictionary domain*.

Every transform here maps a dictionary's K values to K feature values (shape
``(K,)`` or ``(K, F)``, float32). Applying a transform to the N-row column is
then a gather of the K-row result through the code stream — that gather is the
ADV fast path (paper §6.3) and is what ``repro.kernels.adv_gather`` executes on
device. The functions are deliberately pure numpy-over-dictionary so they can
be (a) precomputed once into ADVs and (b) used as recompute-baselines in
benchmarks.
"""
from __future__ import annotations

import numpy as np

from repro.columnar.dictionary import Dictionary


# -- §6.1.1 numeric type conversion -------------------------------------------
def to_float(d: Dictionary) -> np.ndarray:
    """Float cast of dictionary values ('Age FP' ADV in paper Table 5)."""
    d._require_numeric("to_float")
    return d.values.astype(np.float32)


# -- §6.1.2 normalization ------------------------------------------------------
# Scale constants come from count metadata (§6.2) — no row scan.
def minmax_scale(d: Dictionary) -> np.ndarray:
    v = to_float(d)
    lo, hi = float(v.min()), float(v.max())
    span = (hi - lo) or 1.0
    return ((v - lo) / span).astype(np.float32)


def mean_normalize(d: Dictionary) -> np.ndarray:
    v = to_float(d)
    lo, hi = float(v.min()), float(v.max())
    span = (hi - lo) or 1.0
    return ((v - d.mean()) / span).astype(np.float32)


def zscore(d: Dictionary) -> np.ndarray:
    v = to_float(d)
    sd = d.std() or 1.0
    return ((v - d.mean()) / sd).astype(np.float32)


def log_scale(d: Dictionary) -> np.ndarray:
    v = to_float(d)
    if (v < 0).any():
        raise ValueError("log_scale requires non-negative values")
    return np.log1p(v).astype(np.float32)


# -- §6.1.3 one-hot -------------------------------------------------------------
def onehot(d: Dictionary, max_cardinality: int = 4096) -> np.ndarray:
    """(K, K) one-hot rows; stored as an ADV only for low-cardinality columns."""
    k = d.cardinality
    if k > max_cardinality:
        raise ValueError(f"one-hot of cardinality {k} > {max_cardinality}; "
                         "use embedding or hash buckets (paper §6.1.5/§6.1.4)")
    return np.eye(k, dtype=np.float32)


# -- §6.1.4 binarizer / quantile / hash buckets / bucketization -----------------
def binarize(d: Dictionary, threshold: float) -> np.ndarray:
    return (to_float(d) > threshold).astype(np.float32)


def quantile_bucket(d: Dictionary, q: int) -> np.ndarray:
    """Bucket index per dictionary value using count-metadata quantile edges."""
    edges = d.quantile_edges(q)
    return np.searchsorted(edges, to_float(d), side="right").astype(np.float32)


def hash_bucket(d: Dictionary, n_buckets: int, salt: int = 0x9E3779B9) -> np.ndarray:
    """Deterministic modulo hash of dictionary values (paper §6.1.4)."""
    if d.is_numeric():
        h = d.values.astype(np.uint64)
        with np.errstate(over="ignore"):
            h = h * np.uint64(0x9E3779B97F4A7C15) + np.uint64(salt)
        h = np.bitwise_xor(h, h >> np.uint64(31)).astype(np.int64)
        h = np.abs(h)
    else:
        h = np.array([hash((salt, str(v))) for v in d.values.tolist()],
                     dtype=np.int64)
    return (np.abs(h) % n_buckets).astype(np.float32)


def bucketize(d: Dictionary, boundaries: np.ndarray) -> np.ndarray:
    """Non-linear bucketization with explicit boundary vector (paper Table 6)."""
    b = np.asarray(boundaries, dtype=np.float64)
    if (np.diff(b) <= 0).any():
        raise ValueError("boundaries must be strictly increasing")
    return np.searchsorted(b, to_float(d), side="right").astype(np.float32)


def bucketize_categorical(d: Dictionary, mapping: dict, default: float = 0.0) -> np.ndarray:
    """Categorical bucketization, e.g. state -> census region (paper Table 4)."""
    return np.array([float(mapping.get(v, default)) for v in d.values.tolist()],
                    dtype=np.float32)


# -- §6.1.5 embeddings -----------------------------------------------------------
def embedding_init(d: Dictionary, dim: int, seed: int = 0) -> np.ndarray:
    """(K, dim) learned-ADV initializer; training updates it, feedback.py
    writes the trained table back into the dictionary (paper §7)."""
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((d.cardinality, dim)) /
            np.sqrt(dim)).astype(np.float32)


# -- row-space application (the gather the ADV path replaces with a kernel) ------
def apply_feature(feature_table: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """Gather dictionary-domain features to row space: out[i] = table[codes[i]]."""
    return np.asarray(feature_table)[np.asarray(codes)]


def onehot_rows(codes: np.ndarray, cardinality: int) -> np.ndarray:
    """Materialized row-space one-hot (recompute baseline for benchmarks)."""
    out = np.zeros((np.asarray(codes).size, cardinality), dtype=np.float32)
    out[np.arange(out.shape[0]), np.asarray(codes)] = 1.0
    return out
