"""Code-domain relational ops (paper §5/§6: filters, joins, group-bys run on
small integer codes; values are only decoded at the query tail).

These give the framework the SQL-ish surface the paper assumes data scientists
use for featurization, while demonstrating the columnar win: every operator
below works on int32 codes + dictionary metadata.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.columnar.column import Column
from repro.columnar.dictionary import Dictionary
from repro.columnar.table import Table


# -- predicates -----------------------------------------------------------------
def codes_matching(d: Dictionary, pred: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
    """Evaluate a value-space predicate over the K dictionary values ONCE,
    returning the matching code set. Row filtering is then `isin` on codes."""
    mask = pred(d.values)
    return np.flatnonzero(mask).astype(np.int32)


def filter_mask(col: Column, pred: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
    """Row mask for a value predicate, via dictionary + IMCU min/max pruning."""
    match = codes_matching(col.dictionary, pred)
    if match.size == 0:
        return np.zeros(col.n_rows, dtype=bool)
    if match.size == col.dictionary.cardinality:
        return np.ones(col.n_rows, dtype=bool)
    lut = np.zeros(col.dictionary.cardinality, dtype=bool)
    lut[match] = True
    mask = np.zeros(col.n_rows, dtype=bool)
    live = set(col.prune_imcus(match))
    start = 0
    codes = None
    for i, imcu in enumerate(col._imcus):
        if i in live:
            if codes is None:
                codes = col.codes()          # decode once, lazily
            mask[start:start + imcu.n] = lut[codes[start:start + imcu.n]]
        start += imcu.n
    return mask


def filter_table(t: Table, column: str,
                 pred: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
    return filter_mask(t[column], pred)


# -- group-by aggregation ----------------------------------------------------------
def groupby_count(col: Column) -> tuple[np.ndarray, np.ndarray]:
    """GROUP BY col COUNT(*) — pure dictionary metadata, zero row access (§6.2)."""
    d = col.dictionary
    return d.values, d.counts.copy()


def groupby_agg(key: Column, value: Column, agg: str = "sum",
                mask: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """GROUP BY key AGG(value) over codes; one bincount, no value decode until tail."""
    kd, vd = key.dictionary, value.dictionary
    kc, vc = key.codes(), value.codes()
    if mask is not None:
        kc, vc = kc[mask], vc[mask]
    vals = vd.values.astype(np.float64)[vc]     # decode value column at tail
    if agg == "sum":
        out = np.bincount(kc, weights=vals, minlength=kd.cardinality)
    elif agg == "mean":
        s = np.bincount(kc, weights=vals, minlength=kd.cardinality)
        n = np.bincount(kc, minlength=kd.cardinality)
        out = s / np.maximum(n, 1)
    elif agg == "count":
        out = np.bincount(kc, minlength=kd.cardinality).astype(np.float64)
    else:
        raise ValueError(f"unknown agg {agg!r}")
    return kd.values, out


# -- join -------------------------------------------------------------------------
def join_codes(left: Column, right: Column) -> tuple[np.ndarray, np.ndarray]:
    """Inner equi-join on dictionary-encoded key columns.

    Builds a code-translation LUT between the two dictionaries (K_l × lookup),
    then joins in code space — the paper's 'simple calculations on small
    integers' join path. Returns (left_row_idx, right_row_idx).
    """
    ld, rd = left.dictionary, right.dictionary
    # translate: left code -> right code (or -1)
    r_index = {v: i for i, v in enumerate(rd.values.tolist())}
    trans = np.array([r_index.get(v, -1) for v in ld.values.tolist()],
                     dtype=np.int64)
    lc = left.codes()
    rc = right.codes()
    lr = trans[lc]                               # right-code per left row
    # bucket right rows by code
    order = np.argsort(rc, kind="stable")
    sorted_rc = rc[order]
    starts = np.searchsorted(sorted_rc, np.arange(rd.cardinality), side="left")
    ends = np.searchsorted(sorted_rc, np.arange(rd.cardinality), side="right")
    li, ri = [], []
    for i in np.flatnonzero(lr >= 0):
        code = lr[i]
        rows = order[starts[code]:ends[code]]
        if rows.size:
            li.append(np.full(rows.size, i, dtype=np.int64))
            ri.append(rows)
    if not li:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(li), np.concatenate(ri)
