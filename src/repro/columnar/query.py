"""Code-domain relational ops (paper §5/§6: filters, joins, group-bys run on
small integer codes; values are only decoded at the query tail).

These give the framework the SQL-ish surface the paper assumes data scientists
use for featurization, while demonstrating the columnar win: every operator
below works on int32 codes + dictionary metadata.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.columnar.column import Column
from repro.columnar.dictionary import Dictionary
from repro.columnar.table import Table


# -- predicates -----------------------------------------------------------------
def codes_matching(d: Dictionary, pred: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
    """Evaluate a value-space predicate over the K dictionary values ONCE,
    returning the matching code set. Row filtering is then `isin` on codes."""
    mask = pred(d.values)
    return np.flatnonzero(mask).astype(np.int32)


def filter_mask(col: Column, pred: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
    """Row mask for a value predicate, via dictionary + IMCU min/max pruning."""
    match = codes_matching(col.dictionary, pred)
    return _mask_from_codes(col, match)


def _mask_from_codes(col: Column, match: np.ndarray) -> np.ndarray:
    """Row mask for a matching-code set, decoding only the live IMCUs."""
    if match.size == 0:
        return np.zeros(col.n_rows, dtype=bool)
    if match.size == col.dictionary.cardinality:
        return np.ones(col.n_rows, dtype=bool)
    lut = np.zeros(col.dictionary.cardinality, dtype=bool)
    lut[match] = True
    mask = np.zeros(col.n_rows, dtype=bool)
    live = set(col.prune_imcus(match))
    for i, (start, stop) in enumerate(col.imcu_bounds()):
        if i in live:
            mask[start:stop] = lut[col.imcu_codes(i)]
    return mask


def filter_table(t: Table, column: str,
                 pred: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
    return filter_mask(t[column], pred)


# -- predicate AST + code-set compiler (device pushdown front end) ---------------
class Predicate:
    """Composable value-space predicate over named columns.

    Leaves are :class:`ColumnPred` (a column name + a vectorized value
    function evaluated over the K dictionary entries); ``&`` / ``|`` build a
    flat AND / OR across columns — the combinator shape the predicate-scan
    kernel evaluates in one pass. Mixing the two requires explicit nesting
    the kernel doesn't model, so it raises.
    """

    def __and__(self, other: "Predicate") -> "Predicate":
        return _combine("and", self, other)

    def __or__(self, other: "Predicate") -> "Predicate":
        return _combine("or", self, other)


@dataclass(frozen=True)
class ColumnPred(Predicate):
    column: str
    fn: Callable[[np.ndarray], np.ndarray]
    label: str = ""

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return self.label or f"where({self.column!r})"


@dataclass(frozen=True)
class CompositePred(Predicate):
    op: str                      # "and" | "or"
    parts: tuple

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f" {self.op} ".join(repr(p) for p in self.parts)


def _combine(op: str, a: Predicate, b: Predicate) -> CompositePred:
    parts: list[Predicate] = []
    for p in (a, b):
        if isinstance(p, CompositePred):
            if p.op != op:
                raise ValueError("predicates mix AND and OR; the scan "
                                 "kernel evaluates one flat combinator")
            parts.extend(p.parts)
        elif isinstance(p, ColumnPred):
            parts.append(p)
        else:
            raise TypeError(f"not a predicate: {p!r}")
    return CompositePred(op, tuple(parts))


def where(column: str, fn: Callable[[np.ndarray], np.ndarray],
          label: str = "") -> ColumnPred:
    """Leaf predicate: ``fn`` is evaluated over the column's K dictionary
    values (never the N rows), exactly like :func:`codes_matching`."""
    return ColumnPred(column, fn, label or f"where({column!r})")


def eq(column: str, value) -> ColumnPred:
    return ColumnPred(column, lambda v: v == value, f"{column} == {value!r}")


def isin(column: str, values) -> ColumnPred:
    vals = list(values)
    return ColumnPred(column, lambda v: np.isin(v, vals),
                      f"{column} IN {vals!r}")


def between(column: str, lo, hi) -> ColumnPred:
    """Inclusive value range [lo, hi]."""
    return ColumnPred(column, lambda v: (v >= lo) & (v <= hi),
                      f"{lo!r} <= {column} <= {hi!r}")


def gt(column: str, value) -> ColumnPred:
    return ColumnPred(column, lambda v: v > value, f"{column} > {value!r}")


def ge(column: str, value) -> ColumnPred:
    return ColumnPred(column, lambda v: v >= value, f"{column} >= {value!r}")


def lt(column: str, value) -> ColumnPred:
    return ColumnPred(column, lambda v: v < value, f"{column} < {value!r}")


def le(column: str, value) -> ColumnPred:
    return ColumnPred(column, lambda v: v <= value, f"{column} <= {value!r}")


@dataclass(frozen=True)
class CompiledTerm:
    """One column's predicate lowered to code space.

    ``kind`` 0 is the contiguous range [lo, hi] (two device compares; an
    empty match compiles to hi < lo), kind 1 an arbitrary set probed through
    a K-entry LUT. ``match`` keeps the raw matching-code set for IMCU
    pruning and host-side evaluation.
    """
    column: str
    kind: int
    lo: int = 0
    hi: int = -1
    lut: np.ndarray | None = None
    match: np.ndarray | None = None


@dataclass(frozen=True)
class CompiledPredicate:
    terms: tuple
    combine: str                 # "and" | "or"


def compile_predicate(pred: Predicate,
                      dictionaries: dict[str, Dictionary]) -> CompiledPredicate:
    """Lower a predicate AST to code-space terms: each leaf's value function
    runs ONCE over its column's K dictionary entries (via
    :func:`codes_matching`), and the matching code set is classified as a
    contiguous range (equality, ranges on sorted dictionaries) or a K-entry
    LUT (IN-sets, ranges over load-order codes). Device-evaluable as-is by
    the predicate-scan kernel."""
    if isinstance(pred, ColumnPred):
        leaves, combine = (pred,), "and"
    elif isinstance(pred, CompositePred):
        leaves, combine = pred.parts, pred.op
    else:
        raise TypeError(f"not a predicate: {pred!r}")
    terms = []
    for leaf in leaves:
        d = dictionaries.get(leaf.column)
        if d is None:
            raise KeyError(f"predicate column {leaf.column!r} not in plan "
                           f"({sorted(dictionaries)})")
        match = codes_matching(d, leaf.fn)
        k = d.cardinality
        if match.size == 0:
            terms.append(CompiledTerm(leaf.column, 0, lo=0, hi=-1,
                                      match=match))
        elif match.size == k or \
                int(match[-1]) - int(match[0]) + 1 == match.size:
            terms.append(CompiledTerm(leaf.column, 0, lo=int(match[0]),
                                      hi=int(match[-1]), match=match))
        else:
            lut = np.zeros(k, np.int32)
            lut[match] = 1
            terms.append(CompiledTerm(leaf.column, 1, lut=lut, match=match))
    return CompiledPredicate(tuple(terms), combine)


def predicate_mask_host(t: Table, pred: Predicate) -> np.ndarray:
    """Host reference for a compiled predicate: per-term IMCU-pruned masks
    combined with the predicate's combinator. The baseline the device
    pushdown path is benchmarked (and tested bit-exact) against."""
    cp = compile_predicate(pred, {c: t[c].dictionary for c in t.columns})
    acc = None
    for term in cp.terms:
        m = _mask_from_codes(t[term.column], term.match)
        if acc is None:
            acc = m
        else:
            acc = (acc & m) if cp.combine == "and" else (acc | m)
    return acc


# -- group-by aggregation ----------------------------------------------------------
def groupby_count(col: Column) -> tuple[np.ndarray, np.ndarray]:
    """GROUP BY col COUNT(*) — pure dictionary metadata, zero row access (§6.2)."""
    d = col.dictionary
    return d.values, d.counts.copy()


def groupby_agg(key: Column, value: Column, agg: str = "sum",
                mask: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray]:
    """GROUP BY key AGG(value) over codes; one bincount, no value decode until tail."""
    kd, vd = key.dictionary, value.dictionary
    kc, vc = key.codes(), value.codes()
    if mask is not None:
        kc, vc = kc[mask], vc[mask]
    vals = vd.values.astype(np.float64)[vc]     # decode value column at tail
    if agg == "sum":
        out = np.bincount(kc, weights=vals, minlength=kd.cardinality)
    elif agg == "mean":
        s = np.bincount(kc, weights=vals, minlength=kd.cardinality)
        n = np.bincount(kc, minlength=kd.cardinality)
        out = s / np.maximum(n, 1)
    elif agg == "count":
        out = np.bincount(kc, minlength=kd.cardinality).astype(np.float64)
    else:
        raise ValueError(f"unknown agg {agg!r}")
    return kd.values, out


# -- join -------------------------------------------------------------------------
def join_codes(left: Column, right: Column) -> tuple[np.ndarray, np.ndarray]:
    """Inner equi-join on dictionary-encoded key columns.

    Builds a code-translation LUT between the two dictionaries (K_l × lookup),
    then joins in code space — the paper's 'simple calculations on small
    integers' join path. Returns (left_row_idx, right_row_idx).
    """
    ld, rd = left.dictionary, right.dictionary
    # translate: left code -> right code (or -1)
    r_index = {v: i for i, v in enumerate(rd.values.tolist())}
    trans = np.array([r_index.get(v, -1) for v in ld.values.tolist()],
                     dtype=np.int64)
    lc = left.codes()
    rc = right.codes()
    lr = trans[lc]                               # right-code per left row
    # bucket right rows by code
    order = np.argsort(rc, kind="stable")
    sorted_rc = rc[order]
    starts = np.searchsorted(sorted_rc, np.arange(rd.cardinality), side="left")
    ends = np.searchsorted(sorted_rc, np.arange(rd.cardinality), side="right")
    # expand matches without a per-row Python loop: each joining left row i
    # contributes cnt[lr[i]] output pairs, laid out by repeat + running offset
    li_idx = np.flatnonzero(lr >= 0)
    codes = lr[li_idx]
    cnt = ends[codes] - starts[codes]            # matches per joining left row
    total = int(cnt.sum())
    if total == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    li = np.repeat(li_idx, cnt)
    out_starts = np.concatenate(([0], np.cumsum(cnt)[:-1]))
    within = np.arange(total, dtype=np.int64) - np.repeat(out_starts, cnt)
    ri = order[np.repeat(starts[codes], cnt) + within]
    return li.astype(np.int64), ri.astype(np.int64)
