"""Bit-packing of dictionary codes (paper §5.1).

Codes with cardinality K need ``ceil(log2(K))`` bits each (Table 2 of the
paper). We pack b-bit codes into little-endian uint32 words, with fields
allowed to straddle word boundaries — the same consecutive bit-packed layout
the paper scans with SIMD/DAX. Host-side packing uses numpy; device-side
unpacking has a Pallas kernel (``repro.kernels.bitunpack``) whose oracle is
:func:`unpack_bits_jnp`.
"""
from __future__ import annotations

import math

import numpy as np
import jax.numpy as jnp

WORD_BITS = 32


def bits_needed(cardinality: int) -> int:
    """Bits to encode ``cardinality`` distinct values (paper Table 2)."""
    if cardinality < 1:
        raise ValueError("cardinality must be >= 1")
    if cardinality == 1:
        return 1
    return int(math.ceil(math.log2(cardinality)))


def pack_bits(codes: np.ndarray, bits: int) -> np.ndarray:
    """Pack ``codes`` (non-negative ints < 2**bits) into a uint32 word stream.

    Fields are little-endian within and across words and may straddle word
    boundaries, giving the paper's fully-consecutive layout.
    """
    if not (1 <= bits <= WORD_BITS):
        raise ValueError(f"bits must be in [1, {WORD_BITS}], got {bits}")
    codes = np.asarray(codes, dtype=np.uint64)
    if codes.ndim != 1:
        raise ValueError("codes must be 1-D")
    if codes.size and int(codes.max()) >= (1 << bits):
        raise ValueError(f"code {int(codes.max())} does not fit in {bits} bits")
    n = codes.size
    total_bits = n * bits
    n_words = (total_bits + WORD_BITS - 1) // WORD_BITS
    # Accumulate into uint64 words then fold carries; vectorized two-word split.
    out = np.zeros(n_words + 1, dtype=np.uint64)
    bit_pos = np.arange(n, dtype=np.uint64) * np.uint64(bits)
    word_idx = (bit_pos // WORD_BITS).astype(np.int64)
    bit_off = (bit_pos % WORD_BITS).astype(np.uint64)
    lo = (codes << bit_off) & np.uint64(0xFFFFFFFF)
    hi = codes >> (np.uint64(WORD_BITS) - bit_off)  # bit_off==0 -> shift 32 ok on uint64
    np.bitwise_or.at(out, word_idx, lo)
    np.bitwise_or.at(out, word_idx + 1, hi)
    return out[:n_words].astype(np.uint32)


def unpack_bits(words: np.ndarray, bits: int, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`; returns int32 codes (host/numpy path)."""
    words = np.asarray(words, dtype=np.uint64)
    bit_pos = np.arange(n, dtype=np.uint64) * np.uint64(bits)
    word_idx = (bit_pos // WORD_BITS).astype(np.int64)
    bit_off = (bit_pos % WORD_BITS).astype(np.uint64)
    padded = np.concatenate([words, np.zeros(1, dtype=np.uint64)])
    lo = padded[word_idx] >> bit_off
    hi = padded[word_idx + 1] << (np.uint64(WORD_BITS) - bit_off)
    mask = np.uint64((1 << bits) - 1)
    vals = np.where(bit_off == 0, lo & mask, (lo | hi) & mask)
    return vals.astype(np.int32)


def unpack_bits_jnp(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Pure-jnp oracle for the device-side unpack (see kernels/bitunpack).

    ``words`` is uint32; returns int32 codes of length ``n``.
    """
    w = words.astype(jnp.uint32)
    idx = jnp.arange(n, dtype=jnp.uint32)
    bit_pos = idx * jnp.uint32(bits)
    word_idx = (bit_pos // WORD_BITS).astype(jnp.int32)
    bit_off = bit_pos % WORD_BITS
    padded = jnp.concatenate([w, jnp.zeros((1,), jnp.uint32)])
    lo = padded[word_idx] >> bit_off
    # uint32 shift by 32 is undefined; mask the shift and zero the result instead.
    shift_hi = (jnp.uint32(WORD_BITS) - bit_off) & jnp.uint32(31)
    hi_raw = padded[word_idx + 1] << shift_hi
    hi = jnp.where(bit_off == 0, jnp.uint32(0), hi_raw)
    mask = jnp.uint32((1 << bits) - 1)
    return ((lo | hi) & mask).astype(jnp.int32)


def packed_gather(words: np.ndarray, bits: int, rows: np.ndarray) -> np.ndarray:
    """Gather codes for arbitrary ``rows`` from divisor-width packed words.

    For device widths (bits | 32) fields never straddle words, so row ``r``
    is subfield ``r % s`` of word ``r // s`` — one vectorized word gather +
    shift/mask, touching O(len(rows)) words instead of unpacking the stream.
    """
    if 32 % bits:
        raise ValueError(f"packed_gather needs bits | 32, got {bits}")
    s = 32 // bits
    rows = np.asarray(rows, dtype=np.int64)
    w = np.asarray(words, dtype=np.uint32)[rows // s]
    fields = w >> ((rows % s).astype(np.uint32) * np.uint32(bits))
    if bits < 32:
        fields = fields & np.uint32((1 << bits) - 1)
    return fields.astype(np.int32)


def packed_nbytes(n: int, bits: int) -> int:
    """Bytes used by n codes packed at ``bits`` bits each."""
    return 4 * ((n * bits + WORD_BITS - 1) // WORD_BITS)
