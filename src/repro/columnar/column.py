"""A dictionary-encoded column stored as bit-packed IMCUs (paper §5.1).

Mirrors Oracle In-Memory Compression Units: the code stream is chunked into
IMCUs of up to 2**19 rows; each IMCU is bit-packed at the column's dictionary
width and optionally RLE'd when profitable. Per-IMCU min/max code metadata
supports predicate pruning without touching the packed words.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.columnar.bitpack import pack_bits, unpack_bits, packed_nbytes
from repro.columnar.dictionary import Dictionary
from repro.columnar.rle import rle_encode, rle_decode, rle_nbytes
from repro.kernels.bitunpack.kernel import tpu_width

IMCU_ROWS = 1 << 19  # 512K rows, paper §5.1


@dataclass
class _IMCU:
    n: int
    packed: np.ndarray | None          # uint32 words, or None if RLE-stored
    rle: tuple[np.ndarray, np.ndarray] | None
    code_min: int
    code_max: int
    # device views: words repacked ONCE at a TPU width (bits | 32), keyed by
    # that width — what the packed fast path ships instead of int32 codes
    device_views: dict[int, np.ndarray] = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        if self.rle is not None:
            return 4 * (self.rle[0].size + self.rle[1].size)
        return int(self.packed.nbytes)

    def device_words(self, bits: int, db: int) -> np.ndarray:
        """This IMCU's packed slice at device width ``db`` (bits | 32).

        Repacked once and cached; when the storage width already divides 32
        the stored words ARE the device view (zero-copy — fields never
        straddle words, so exact and device layouts coincide).
        """
        view = self.device_views.get(db)
        if view is None:
            if self.rle is not None:
                view = pack_bits(rle_decode(*self.rle), db)
            elif bits == db:
                view = self.packed                 # zero-copy: layouts agree
            else:
                view = pack_bits(unpack_bits(self.packed, bits, self.n), db)
            self.device_views[db] = view
        return view


class Column:
    """Dictionary-encoded, bit-packed column."""

    def __init__(self, dictionary: Dictionary, codes: np.ndarray,
                 use_rle: bool = True, imcu_rows: int = IMCU_ROWS):
        self.dictionary = dictionary
        self.n_rows = int(np.asarray(codes).size)
        self.imcu_rows = imcu_rows
        self._imcus: list[_IMCU] = []
        codes = np.asarray(codes, dtype=np.int32)
        bits = dictionary.bits
        for start in range(0, self.n_rows, imcu_rows):
            chunk = codes[start:start + imcu_rows]
            cmin, cmax = (int(chunk.min()), int(chunk.max())) if chunk.size else (0, 0)
            imcu = _IMCU(n=chunk.size, packed=None, rle=None,
                         code_min=cmin, code_max=cmax)
            if use_rle:
                vals, lens = rle_encode(chunk)
                if rle_nbytes(vals, lens, bits) < packed_nbytes(chunk.size, bits):
                    imcu.rle = (vals, lens)
            if imcu.rle is None:
                imcu.packed = pack_bits(chunk, bits)
            self._imcus.append(imcu)

    @classmethod
    def from_data(cls, data: np.ndarray, name: str = "col",
                  sort_values: bool = False, use_rle: bool = True,
                  imcu_rows: int = IMCU_ROWS) -> "Column":
        d, codes = Dictionary.from_data(data, name=name, sort_values=sort_values)
        return cls(d, codes, use_rle=use_rle, imcu_rows=imcu_rows)

    # -- access ---------------------------------------------------------------
    @property
    def n_imcus(self) -> int:
        return len(self._imcus)

    def imcu_bounds(self) -> list[tuple[int, int]]:
        """Row range [start, stop) of each IMCU."""
        bounds, start = [], 0
        for imcu in self._imcus:
            bounds.append((start, start + imcu.n))
            start += imcu.n
        return bounds

    def imcu_codes(self, i: int) -> np.ndarray:
        """Decompress a single IMCU's code stream (partition-local access).

        Lets per-IMCU feature plans touch only their own partition instead of
        materializing the full N-row stream.
        """
        imcu = self._imcus[i]
        if imcu.rle is not None:
            return rle_decode(*imcu.rle)
        return unpack_bits(imcu.packed, self.dictionary.bits, imcu.n)

    def codes(self) -> np.ndarray:
        """Materialize the int32 code stream (decompress all IMCUs)."""
        parts = [self.imcu_codes(i) for i in range(len(self._imcus))]
        return np.concatenate(parts) if parts else np.zeros(0, np.int32)

    # -- device views (packed fast path) ----------------------------------------
    def imcu_device_words(self, i: int, db: int | None = None) -> np.ndarray:
        """One IMCU's packed words at the TPU width, without int32 codes.

        Cached on the IMCU, so per-IMCU shard plans and full-column plans
        share the same repacked buffers.
        """
        db = tpu_width(self.dictionary.bits) if db is None else db
        return self._imcus[i].device_words(self.dictionary.bits, db)

    def device_words(self, db: int | None = None) -> tuple[np.ndarray, int]:
        """Whole-column device-width word stream; returns (words, db).

        Per-IMCU views concatenate word-exactly when every interior IMCU's
        row count is a multiple of 32/db (fields at divisor widths never
        straddle words); otherwise the column is repacked in one pass.
        """
        db = tpu_width(self.dictionary.bits) if db is None else db
        s = 32 // db
        if not self._imcus:
            return np.zeros(0, np.uint32), db
        if all(m.n % s == 0 for m in self._imcus[:-1]):
            return np.concatenate(
                [self.imcu_device_words(i, db)
                 for i in range(len(self._imcus))]), db
        return pack_bits(self.codes(), db), db

    def decode(self) -> np.ndarray:
        """Materialize original values (the expensive thing the paper avoids)."""
        return self.dictionary.decode(self.codes())

    # -- storage accounting (paper Table 2 / §5 claims) ------------------------
    @property
    def packed_nbytes(self) -> int:
        return sum(i.nbytes for i in self._imcus)

    @property
    def dictionary_nbytes(self) -> int:
        v = self.dictionary.values
        if v.dtype == object:
            data = sum(len(str(x)) for x in v.tolist())
        else:
            data = v.nbytes
        return int(data + self.dictionary.counts.nbytes)

    @property
    def total_nbytes(self) -> int:
        return self.packed_nbytes + self.dictionary_nbytes

    def raw_nbytes(self, assume_csv: bool = False) -> int:
        """Size of the unencoded column (binary, or CSV text per paper §6.1.1)."""
        v = self.dictionary.values
        if assume_csv:
            per_row = np.zeros(self.dictionary.cardinality, dtype=np.int64)
            for i, x in enumerate(v.tolist()):
                per_row[i] = len(str(x)) + 1  # value chars + delimiter
            return int(np.dot(per_row, self.dictionary.counts))
        if v.dtype == object:
            lens = np.array([len(str(x)) for x in v.tolist()], dtype=np.int64)
            return int(np.dot(lens, self.dictionary.counts))
        return int(v.dtype.itemsize) * self.n_rows

    @property
    def compression_ratio(self) -> float:
        return self.raw_nbytes() / max(self.total_nbytes, 1)

    # -- predicate pruning ------------------------------------------------------
    def prune_imcus(self, code_set: np.ndarray) -> list[int]:
        """IMCU indices that might contain any code in ``code_set`` (min/max prune)."""
        code_set = np.asarray(code_set)
        lo, hi = int(code_set.min()), int(code_set.max())
        return [i for i, m in enumerate(self._imcus)
                if not (m.code_max < lo or m.code_min > hi)]
