"""Column dictionary with min/max and count metadata (paper §5.1, §6.2).

Each distinct column value gets an integer *encoding* (code). Encodings are
internal to the store and need not follow the value ordering (paper Table 1/5
note) — we support both load-order and sorted assignment. The dictionary
carries:

- ``values``: code -> original value (numpy array, any dtype incl. object/str)
- ``counts``: code -> number of occurrences (paper §6.2) — lets sums / means /
  stds / histograms / min-max scaling constants be computed from K dictionary
  entries instead of N rows
- ``vmin/vmax``: column min/max metadata used for predicate pruning
- ADV columns are attached by :class:`repro.core.adv.AugmentedDictionary`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.columnar.bitpack import bits_needed


@dataclass
class Dictionary:
    values: np.ndarray          # code -> value, length K
    counts: np.ndarray          # code -> count, int64, length K
    name: str = "col"
    sorted_codes: bool = False  # True if codes follow value order

    def __post_init__(self) -> None:
        self.values = np.asarray(self.values)
        self.counts = np.asarray(self.counts, dtype=np.int64)
        if self.values.shape[0] != self.counts.shape[0]:
            raise ValueError("values/counts length mismatch")
        self._index: dict[Any, int] | None = None
        # bumped on any insert/delete — count-derived statistics (mean, std,
        # quantiles) are only valid for a fixed version, so ADV maintenance
        # uses it to spot stale count-sensitive tables
        self.version = 0

    # -- construction -------------------------------------------------------
    @classmethod
    def from_data(cls, data: np.ndarray, name: str = "col",
                  sort_values: bool = False) -> tuple["Dictionary", np.ndarray]:
        """Build a dictionary from raw column data; returns (dict, codes).

        ``sort_values=False`` assigns codes in first-appearance (load) order,
        matching the paper's note that encodings are internal and unordered.
        """
        data = np.asarray(data)
        if sort_values:
            values, codes, counts = np.unique(data, return_inverse=True,
                                              return_counts=True)
        else:
            values, first_idx, codes, counts = np.unique(
                data, return_index=True, return_inverse=True, return_counts=True)
            order = np.argsort(first_idx)          # load order of first appearance
            rank = np.empty_like(order)
            rank[order] = np.arange(order.size)
            values = values[order]
            counts = counts[order]
            codes = rank[codes]
        return cls(values=values, counts=counts, name=name,
                   sorted_codes=sort_values), codes.astype(np.int32)

    # -- basic metadata ------------------------------------------------------
    @property
    def cardinality(self) -> int:
        return int(self.values.shape[0])

    @property
    def bits(self) -> int:
        return bits_needed(self.cardinality)

    @property
    def n_rows(self) -> int:
        return int(self.counts.sum())

    @property
    def vmin(self) -> Any:
        return self.values.min()

    @property
    def vmax(self) -> Any:
        return self.values.max()

    def is_numeric(self) -> bool:
        return np.issubdtype(self.values.dtype, np.number)

    # -- lookup --------------------------------------------------------------
    def code_of(self, value: Any) -> int:
        if self._index is None:
            self._index = {v: i for i, v in enumerate(self.values.tolist())}
        return self._index[value]

    def decode(self, codes: np.ndarray) -> np.ndarray:
        return self.values[np.asarray(codes)]

    # -- count-metadata statistics (paper §6.2) -------------------------------
    # All of these touch K dictionary entries, never the N-row code stream.
    def count_total(self) -> int:
        return self.n_rows

    def sum(self) -> float:
        self._require_numeric("sum")
        return float(np.dot(self.values.astype(np.float64), self.counts))

    def mean(self) -> float:
        return self.sum() / self.n_rows

    def var(self) -> float:
        self._require_numeric("var")
        v = self.values.astype(np.float64)
        mu = self.mean()
        return float(np.dot((v - mu) ** 2, self.counts) / self.n_rows)

    def std(self) -> float:
        return float(np.sqrt(self.var()))

    def histogram(self) -> tuple[np.ndarray, np.ndarray]:
        """(values, counts) — the dictionary IS the histogram (paper §6.2)."""
        return self.values, self.counts

    def quantile_edges(self, q: int) -> np.ndarray:
        """q-quantile edges from counts (no data scan). Numeric columns only."""
        self._require_numeric("quantile_edges")
        order = np.argsort(self.values)
        v = self.values[order].astype(np.float64)
        c = self.counts[order]
        cdf = np.cumsum(c) / self.n_rows
        targets = np.arange(1, q) / q
        idx = np.searchsorted(cdf, targets, side="left")
        return v[np.clip(idx, 0, v.size - 1)]

    # -- maintenance (inserts/updates/deletes, paper §6.3 last ¶) -------------
    def add_rows(self, data: np.ndarray) -> np.ndarray:
        """Insert new rows; extends the dictionary as needed. Returns codes."""
        data = np.asarray(data)
        if self._index is None:
            self._index = {v: i for i, v in enumerate(self.values.tolist())}
        codes = np.empty(data.shape[0], dtype=np.int32)
        new_vals: list[Any] = []
        for i, v in enumerate(data.tolist()):
            code = self._index.get(v)
            if code is None:
                code = self.cardinality + len(new_vals)
                self._index[v] = code
                new_vals.append(v)
            codes[i] = code
        if new_vals:
            self.values = np.concatenate(
                [self.values, np.asarray(new_vals, dtype=self.values.dtype)])
            self.counts = np.concatenate(
                [self.counts, np.zeros(len(new_vals), dtype=np.int64)])
            self.sorted_codes = False
        np.add.at(self.counts, codes, 1)
        self.version += 1
        return codes

    def remove_rows(self, codes: np.ndarray) -> None:
        np.subtract.at(self.counts, np.asarray(codes), 1)
        if (self.counts < 0).any():
            raise ValueError("count underflow: removing rows not present")
        self.version += 1

    def _require_numeric(self, op: str) -> None:
        if not self.is_numeric():
            raise TypeError(f"{op} requires a numeric dictionary "
                            f"(column {self.name!r} is {self.values.dtype})")
