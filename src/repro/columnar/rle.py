"""Run-length encoding on dictionary codes (paper §5.2).

RLE stacks on top of dictionary encoding and shines on sorted/semi-sorted
columns. Decode is variable-rate and sequential, so per DESIGN.md it is a
host-side storage codec (numpy); a cumsum-based jnp decode is provided for
block-aligned device use.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def rle_encode(codes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return (values, run_lengths), both int32/int64-safe numpy arrays."""
    codes = np.asarray(codes)
    if codes.ndim != 1:
        raise ValueError("codes must be 1-D")
    n = codes.size
    if n == 0:
        return codes[:0].astype(np.int32), np.zeros(0, dtype=np.int64)
    change = np.empty(n, dtype=bool)
    change[0] = True
    np.not_equal(codes[1:], codes[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    values = codes[starts].astype(np.int32)
    lengths = np.diff(np.append(starts, n)).astype(np.int64)
    return values, lengths


def rle_decode(values: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    return np.repeat(np.asarray(values), np.asarray(lengths)).astype(np.int32)


def rle_decode_jnp(values: jnp.ndarray, lengths: jnp.ndarray, n: int) -> jnp.ndarray:
    """Device decode for a fixed output length ``n`` (cumsum + searchsorted)."""
    ends = jnp.cumsum(lengths)
    pos = jnp.arange(n)
    run = jnp.searchsorted(ends, pos, side="right")
    run = jnp.clip(run, 0, values.shape[0] - 1)
    return values[run].astype(jnp.int32)


def rle_nbytes(values: np.ndarray, lengths: np.ndarray, value_bits: int) -> int:
    """Storage estimate: value_bits per value + run lengths at their
    ACTUAL dtype width (int64 lengths cost 8 B/run, not a flattering 4)."""
    n_runs = int(np.asarray(values).size)
    lengths = np.asarray(lengths)
    return (n_runs * value_bits + 7) // 8 + lengths.dtype.itemsize * n_runs
