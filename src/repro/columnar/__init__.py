"""Columnar storage substrate (paper §5): dictionary encoding, bit-packing,
RLE, count metadata, and code-domain relational ops.

The layout mirrors an in-memory columnar VLDB: a ``Table`` holds ``Column``s;
each column is dictionary-encoded into small integer *codes* stored bit-packed
per IMCU (in-memory compression unit); the ``Dictionary`` carries min/max and
per-entry counts (paper §6.2) and hosts Augmented Dictionary Values (ADVs,
paper §6.3) managed by :mod:`repro.core.adv`.
"""
from repro.columnar.bitpack import bits_needed, pack_bits, unpack_bits
from repro.columnar.rle import rle_encode, rle_decode
from repro.columnar.dictionary import Dictionary
from repro.columnar.column import Column, IMCU_ROWS
from repro.columnar.table import Table

__all__ = [
    "bits_needed", "pack_bits", "unpack_bits",
    "rle_encode", "rle_decode",
    "Dictionary", "Column", "Table", "IMCU_ROWS",
]
