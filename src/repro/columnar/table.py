"""A columnar table: named Columns + row count (paper §5/§6 substrate)."""
from __future__ import annotations

from typing import Iterable, Mapping

import numpy as np

from repro.columnar.column import Column


class Table:
    def __init__(self, columns: Mapping[str, Column]):
        self.columns: dict[str, Column] = dict(columns)
        lengths = {c.n_rows for c in self.columns.values()}
        if len(lengths) > 1:
            raise ValueError(f"ragged table: row counts {lengths}")
        self.n_rows = lengths.pop() if lengths else 0

    @classmethod
    def from_data(cls, data: Mapping[str, np.ndarray], sort_values: bool = False,
                  use_rle: bool = True, imcu_rows: int | None = None) -> "Table":
        kw = {} if imcu_rows is None else {"imcu_rows": imcu_rows}
        return cls({name: Column.from_data(np.asarray(arr), name=name,
                                           sort_values=sort_values,
                                           use_rle=use_rle, **kw)
                    for name, arr in data.items()})

    def __getitem__(self, name: str) -> Column:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    @property
    def names(self) -> list[str]:
        return list(self.columns)

    def select(self, names: Iterable[str]) -> "Table":
        """Columnar projection — only the named columns are touched (paper §5)."""
        return Table({n: self.columns[n] for n in names})

    @property
    def total_nbytes(self) -> int:
        return sum(c.total_nbytes for c in self.columns.values())

    def raw_nbytes(self, assume_csv: bool = False) -> int:
        return sum(c.raw_nbytes(assume_csv=assume_csv)
                   for c in self.columns.values())

    def summary(self) -> str:
        lines = [f"Table[{self.n_rows} rows, {len(self.columns)} cols, "
                 f"{self.total_nbytes}B packed vs {self.raw_nbytes()}B raw]"]
        for n, c in self.columns.items():
            d = c.dictionary
            lines.append(
                f"  {n}: K={d.cardinality} bits={d.bits} "
                f"packed={c.packed_nbytes}B dict={c.dictionary_nbytes}B "
                f"ratio={c.compression_ratio:.1f}x")
        return "\n".join(lines)
