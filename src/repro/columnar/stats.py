"""Count-metadata accelerated statistics (paper §6.2) + scan baselines.

Each ``*_from_dictionary`` touches K dictionary entries; each ``*_scan``
baseline decodes and scans all N rows. Benchmarks compare the two to quantify
the paper's 'no scan required' claim.
"""
from __future__ import annotations

import numpy as np

from repro.columnar.column import Column


# -- dictionary-path (K-cost) ------------------------------------------------
def sum_from_dictionary(col: Column) -> float:
    return col.dictionary.sum()


def mean_from_dictionary(col: Column) -> float:
    return col.dictionary.mean()


def std_from_dictionary(col: Column) -> float:
    return col.dictionary.std()


def histogram_from_dictionary(col: Column) -> tuple[np.ndarray, np.ndarray]:
    return col.dictionary.histogram()


def minmax_from_dictionary(col: Column) -> tuple[float, float]:
    d = col.dictionary
    return float(d.vmin), float(d.vmax)


# -- scan baselines (N-cost; what the paper's technique avoids) -----------------
def sum_scan(col: Column) -> float:
    return float(col.decode().astype(np.float64).sum())


def mean_scan(col: Column) -> float:
    return float(col.decode().astype(np.float64).mean())


def std_scan(col: Column) -> float:
    return float(col.decode().astype(np.float64).std())


def histogram_scan(col: Column) -> tuple[np.ndarray, np.ndarray]:
    vals, counts = np.unique(col.decode(), return_counts=True)
    return vals, counts


def minmax_scan(col: Column) -> tuple[float, float]:
    v = col.decode().astype(np.float64)
    return float(v.min()), float(v.max())
