"""Sharding rules: param / batch / serve-state PartitionSpecs per mesh.

Divisibility-aware: every rule checks the dim size against the mesh axis and
falls back to replication when it does not divide (e.g. qwen2's 28 heads on a
16-way model axis shard the fused H·hd dim instead). The paper's dictionaries
(embedding = learned ADV, vocab head) are row/column-sharded over 'model' —
dictionary sharding at scale, DESIGN.md §4.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Data-parallel axes: ('pod','data') on multi-pod, ('data',) otherwise."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _div(size: int, n: int) -> bool:
    return n > 0 and size % n == 0


def _shard_if(mesh: Mesh, size: int, axis: str):
    return axis if _div(size, _axis_size(mesh, axis)) else None


def _batch_spec_axis(mesh: Mesh, b: int):
    """Largest prefix of the DP axes that divides the batch."""
    axes = batch_axes(mesh)
    total = int(np.prod([_axis_size(mesh, a) for a in axes]))
    if _div(b, total):
        return axes
    for a in axes:                       # try single axes
        if _div(b, _axis_size(mesh, a)):
            return (a,)
    return None


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------
def param_pspecs(cfg: ModelConfig, params_tree, mesh: Mesh,
                 fsdp: bool | None = None):
    """PartitionSpec tree matching params_tree (works on ShapeDtypeStructs).

    ``fsdp``: additionally shard every large param over 'data' (ZeRO-3 /
    FSDP — GSPMD inserts the per-layer weight all-gather). Auto-enabled when
    bf16 params exceed ~6 GB/device under model-axis sharding alone (the
    400B-class MoE cells cannot exist on chip otherwise).
    """
    m = _axis_size(mesh, "model")
    d_ax = _axis_size(mesh, "data")
    if fsdp is None:
        fsdp = cfg.force_fsdp or cfg.param_count() * 2 / max(m, 1) > 6e9
    if cfg.pure_dp:
        # ZeRO-3 over 'model': params live sharded, gathered per layer;
        # batch takes every mesh axis (see batch_pspecs)
        def dp_rule(path, leaf):
            shape = leaf.shape
            if int(np.prod(shape)) < (1 << 20):
                return P(*([None] * len(shape)))
            entries = [None] * len(shape)
            best, best_size = -1, 0
            for i, sz in enumerate(shape):
                if sz % m == 0 and sz > best_size:
                    best, best_size = i, sz
            if best >= 0:
                entries[best] = "model"
            return P(*entries)
        return jax.tree_util.tree_map_with_path(dp_rule, params_tree)

    def fsdp_extend(spec: P, shape) -> P:
        if not fsdp or d_ax <= 1 or int(np.prod(shape)) < (1 << 20):
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        best, best_size = -1, 0
        for i, (e, sz) in enumerate(zip(entries, shape)):
            if e is None and sz % d_ax == 0 and sz > best_size:
                best, best_size = i, sz
        if best >= 0:
            entries[best] = "data"
        return P(*entries)

    COLUMN = {"wq", "wk", "wv", "wu", "wg", "w_up", "w_in", "w", "head",
              "bq", "bk", "bv", "conv_w"}
    ROW = {"wo", "wd", "w_down", "w_o_ssm", "w_bc", "w_dt", "wif"}
    EXPERT = {"we_gate", "we_up", "we_down"}
    REPLICATED = {"ln", "ln1", "ln2", "ln_x", "ln_heads", "final_norm",
                  "enc_norm", "norm_attn", "norm_ssm", "router", "a_log",
                  "b", "r", "vis_proj", "enc_proj"}

    def rule(path, leaf) -> P:
        leaf_name = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
        shape = leaf.shape
        nd = len(shape)

        def last():
            return P(*([None] * (nd - 1)), _shard_if(mesh, shape[-1], "model"))

        def at(i):
            spec = [None] * nd
            spec[i] = _shard_if(mesh, shape[i], "model")
            return P(*spec)

        if leaf_name == "embed":
            return fsdp_extend(at(0), shape)          # vocab rows = dictionary
        if leaf_name in REPLICATED:
            return P(*([None] * nd))
        if leaf_name in EXPERT:
            return fsdp_extend(at(1), shape)          # expert parallelism
        if leaf_name in COLUMN:
            return fsdp_extend(last(), shape)         # column parallel
        if leaf_name in ROW:
            return fsdp_extend(at(-2), shape)         # row parallel
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params_tree)


# ---------------------------------------------------------------------------
# batch rules
# ---------------------------------------------------------------------------
def batch_pspecs(cfg: ModelConfig, batch_tree, mesh: Mesh):
    def rule(path, leaf) -> P:
        shape = leaf.shape
        if cfg.pure_dp:
            axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.shape)
            total = int(np.prod([_axis_size(mesh, a) for a in axes]))
            ba = axes if _div(shape[0], total) else \
                _batch_spec_axis(mesh, shape[0])
        else:
            ba = _batch_spec_axis(mesh, shape[0])
        rest = [None] * (len(shape) - 1)
        return P(ba, *rest)
    return jax.tree_util.tree_map_with_path(rule, batch_tree)


# ---------------------------------------------------------------------------
# serve-state rules
# ---------------------------------------------------------------------------
def state_pspecs(cfg: ModelConfig, state_tree, mesh: Mesh):
    """Caches: batch over DP axes; cache length / state dims over 'model'.

    Leading dim of every block cache is the scan-group axis (never sharded);
    second is batch. Attention cache (G,B,T,KV,hd) shards T over 'model'
    (sequence-sharded decode attention); recurrent states shard their widest
    state dim.
    """
    def rule(path, leaf) -> P:
        name = ".".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in path)
        shape = leaf.shape
        if name == "pos":
            return P()
        if name == "memory":                     # (B, S_enc, D)
            ba = _batch_spec_axis(mesh, shape[0])
            return P(ba, _shard_if(mesh, shape[1], "model"), None)
        nd = len(shape)
        if nd >= 3:
            ba = _batch_spec_axis(mesh, shape[1])
            spec: list[Any] = [None, ba] + [None] * (nd - 2)
            if name.endswith(".k") or name.endswith(".v") or \
                    name.endswith(".ks") or name.endswith(".vs"):
                spec[2] = _shard_if(mesh, shape[2], "model")   # cache length T
            elif "state" in name:
                # (G,B,H,dk,dv): shard dk, else dv
                if _shard_if(mesh, shape[3], "model"):
                    spec[3] = "model"
                elif nd > 4 and _shard_if(mesh, shape[4], "model"):
                    spec[4] = "model"
            elif "conv" in name:
                spec[3] = _shard_if(mesh, shape[3], "model")   # d_inner
            elif name.endswith(".h") or name.endswith(".c"):
                spec[3] = _shard_if(mesh, shape[3], "model")   # head dim
            return P(*spec)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, state_tree)


def to_shardings(mesh: Mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# feature-serving rules (per-IMCU resident word-stream shards)
# ---------------------------------------------------------------------------
def serve_mesh(devices=None) -> Mesh:
    """1-D ('shard',) mesh over the serving devices.

    The serving analogue of the training meshes above: each mesh device
    holds the resident word streams (and replicated ADV tables) of the IMCU
    shards assigned to it, so featurization launches run where the columnar
    data lives — compute moves to the shard, not shard bytes to one device.
    """
    devices = list(devices) if devices is not None else jax.devices()
    if not devices:
        raise ValueError("no devices to build a serve mesh over")
    return Mesh(np.array(devices), ("shard",))


def serve_devices(n_shards: int, devices=None) -> list:
    """Owning device for each of ``n_shards`` IMCU shards, round-robin.

    Round-robin (not blocked) assignment keeps a streaming-append workload
    balanced: fresh IMCUs land on successive devices instead of piling onto
    the last one. With fewer devices than shards, multiple shards share a
    device (their resident streams stay distinct; only placement coincides
    — the divisibility-aware fallback the param rules above use too).
    """
    if n_shards < 1:
        raise ValueError(f"need at least one shard, got {n_shards}")
    devices = list(devices) if devices is not None else jax.devices()
    if not devices:
        raise ValueError("no devices to place shards on")
    return [devices[i % len(devices)] for i in range(n_shards)]


def surviving_devices(devices, lost=frozenset()) -> list:
    """The serve pool minus devices declared DEAD (``lost`` holds
    ``id(device)`` keys from the service's DeviceHealth tracker).

    Unlike :func:`replica_device`'s ``unhealthy`` set — streams behind an
    open breaker, avoided but usable when cornered — a lost device is
    gone: it must never be picked, so an empty survivor list is returned
    as-is and the caller decides the fallback (feature serving degrades
    to host gathers until hardware returns)."""
    devices = list(devices) if devices is not None else jax.devices()
    return [d for d in devices if id(d) not in lost]


class DeviceBudget:
    """Per-device HBM byte ledger for tiered shard residency.

    Tracks the bytes of resident packed word streams charged to each device
    (keyed ``id(device)``, like every load map in this module) against an
    optional uniform per-device budget. ``budget_bytes=None`` disables the
    cap — every ``fits`` succeeds and the ledger is pure accounting. The
    replicated ADV tables are deliberately NOT charged: they are K-row
    constants shared by every stream on the device, while the budget
    governs what scales with table rows (the word streams).
    """

    def __init__(self, budget_bytes: int | None = None):
        if budget_bytes is not None and budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0, got {budget_bytes}")
        self.budget_bytes = budget_bytes
        self._bytes: dict[int, int] = {}

    def bytes(self, dev_id: int) -> int:
        return self._bytes.get(dev_id, 0)

    def charge(self, dev_id: int, n: int) -> None:
        self._bytes[dev_id] = self._bytes.get(dev_id, 0) + int(n)

    def release(self, dev_id: int, n: int) -> None:
        left = self._bytes.get(dev_id, 0) - int(n)
        if left < 0:
            raise ValueError(
                f"release of {n}B underflows device {dev_id} "
                f"({self._bytes.get(dev_id, 0)}B charged)")
        if left:
            self._bytes[dev_id] = left
        else:
            self._bytes.pop(dev_id, None)

    def fits(self, dev_id: int, n: int) -> bool:
        return (self.budget_bytes is None
                or self.bytes(dev_id) + int(n) <= self.budget_bytes)

    def headroom(self, dev_id: int) -> int | None:
        if self.budget_bytes is None:
            return None
        return self.budget_bytes - self.bytes(dev_id)

    def over_budget(self) -> dict[int, int]:
        """Devices currently above the cap -> bytes over (empty if uncapped)."""
        if self.budget_bytes is None:
            return {}
        return {d: b - self.budget_bytes for d, b in self._bytes.items()
                if b > self.budget_bytes}


def replica_device(devices, load: dict[int, int] | None = None,
                   exclude=frozenset(), unhealthy=frozenset()):
    """Placement rule for an ADAPTIVE stream (shard replica or fresh tail
    shard): the least-loaded device in the pool, counting resident launch
    streams (``load`` maps ``id(device)`` -> streams, missing = 0).

    ``exclude`` (ids) names devices that already hold a stream of the SAME
    shard — a replica there adds capacity on paper but shares the physical
    queue, so they only win ties when every pool device is excluded.
    ``unhealthy`` (ids) names devices whose streams are currently failing
    (open circuit breakers): a FAILOVER replica placed there would inherit
    the fault, so they are avoided with the same only-when-cornered
    fallback. Deterministic: ties break on pool order, so placement (and
    tests) are reproducible for a given load picture.
    """
    devices = list(devices) if devices is not None else jax.devices()
    if not devices:
        raise ValueError("no devices to place a replica on")
    load = load or {}
    pool = ([d for d in devices
             if id(d) not in exclude and id(d) not in unhealthy]
            or [d for d in devices if id(d) not in exclude]
            or devices)
    return min(pool, key=lambda d: load.get(id(d), 0))
