"""GPipe-style pipeline parallelism over a 'stage' mesh axis (opt-in).

For fleets beyond 2 pods the data×model mesh runs out of useful width; this
module adds a third option: layer groups sharded over a 'stage' axis with
microbatch streaming. Implemented with shard_map + collective_permute (the
jax-native rendering of the send/recv pipeline schedule) — compute of stage i
on microbatch j overlaps the (i-1 -> i) activation transfer of microbatch
j+1 because XLA schedules the ppermute asynchronously.

Schedule: forward-only GPipe loop with S + M - 1 ticks (S stages, M
microbatches). Bubble fraction = (S-1)/(S+M-1), reported by
``bubble_fraction`` so configs can size M.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + n_microbatches - 1)


def pipelined_forward(stage_fn, mesh: Mesh, axis: str = "stage"):
    """Build a pipelined forward: (stage_params, x_microbatched) -> y.

    stage_fn(params_slice, x) -> y : one stage's computation.
    stage_params: pytree with leading dim = n_stages (sharded over ``axis``).
    x: (M, mb, ...) microbatched input, replicated across stages; stage 0
    feeds microbatch j at tick j; outputs emerge from the last stage.
    """
    n_stages = mesh.shape[axis]

    def per_stage(params, x):
        # inside shard_map: params has leading dim 1 (this stage's slice)
        p_local = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index(axis)
        m = x.shape[0]
        ticks = m + n_stages - 1
        buf = jnp.zeros_like(x[0])
        outputs = jnp.zeros_like(x)

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (if in range); others take the
            # ppermuted activation from the previous stage
            feed = jnp.where(t < m, t, m - 1)
            x_in = jnp.where(stage_id == 0, x[feed], buf)
            y = stage_fn(p_local, x_in)
            # last stage writes its output at slot t - (S-1)
            out_slot = t - (n_stages - 1)
            do_write = (stage_id == n_stages - 1) & (out_slot >= 0)
            outputs = jax.lax.cond(
                do_write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_slot, 0), 0),
                lambda o: o, outputs)
            # shift activations to the next stage
            buf = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            return (buf, outputs), None

        (_, outputs), _ = jax.lax.scan(tick, (buf, outputs),
                                       jnp.arange(ticks))
        # all stages hold zeros except the last; reduce to broadcast result
        return jax.lax.psum(outputs, axis) if n_stages > 1 else outputs

    in_specs = (P(axis), P())           # params sharded by stage, x replicated
    out_specs = P()
    return shard_map(per_stage, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)
