"""Activation-sharding context: lets pure model code emit GSPMD sharding
constraints without threading a Mesh through every signature.

The residual stream between scanned layer groups is the largest liveness in
training (the scan carry stack: L × (B,S,D)); constraining it to
P(batch, 'model', None) — sequence parallelism — shrinks that term by the
model-axis width and converts per-layer TP all-reduces into
reduce-scatter/all-gather pairs (Megatron-SP). Enabled by the dry-run and
the distributed trainer; a no-op when no mesh is active (CPU tests).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE: dict = {"mesh": None}


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh]):
    prev = _STATE["mesh"]
    _STATE["mesh"] = mesh
    try:
        yield
    finally:
        _STATE["mesh"] = prev


def current_mesh() -> Optional[Mesh]:
    return _STATE["mesh"]


def _batch_axes_for(mesh: Mesh, b: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    import numpy as np
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and b % total == 0:
        return axes
    for a in axes:
        if b % mesh.shape[a] == 0:
            return (a,)
    return None


def constrain_last(x):
    """Shard the LAST dim over 'model' when divisible (GLA value/state
    tensors); batch dim over DP axes when 3+D. No-op without a mesh."""
    mesh = _STATE["mesh"]
    if mesh is None or x.ndim < 2:
        return x
    m = mesh.shape.get("model", 1)
    last = "model" if (m > 1 and x.shape[-1] % m == 0) else None
    if last is None:
        return x
    ba = _batch_axes_for(mesh, x.shape[0]) if x.ndim >= 3 else None
    spec = [ba] + [None] * (x.ndim - 2) + [last]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_residual(x, prefer: str = "seq"):
    """Residual-stream constraint on (B, S, D); no-op without an active mesh.

    prefer="seq":     P(batch, 'model', None) — Megatron-SP for attention
                      stacks (full-S ops re-gather per layer).
    prefer="channel": P(batch, None, 'model') — for SSM/hybrid stacks whose
                      chunked recurrence is sequential in S; sharding S would
                      force GSPMD to replicate the whole recurrence (the
                      xlstm 60GB failure mode), channels shard cleanly.
    """
    mesh = _STATE["mesh"]
    if mesh is None or x.ndim != 3:
        return x
    b, s, d = x.shape
    m = mesh.shape.get("model", 1)
    if prefer == "dp":
        import numpy as np
        axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
        total = int(np.prod([mesh.shape[a] for a in axes]))
        ba = axes if b % total == 0 else _batch_axes_for(mesh, b)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(ba, None, None)))
    ba = _batch_axes_for(mesh, b)
    if prefer == "channel":
        da = "model" if (m > 1 and d % m == 0) else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(ba, None, da)))
    sa = "model" if (m > 1 and s > 1 and s % m == 0) else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(ba, sa, None)))
