"""Version-compat shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``
along the way. This wrapper accepts the new-style ``check_vma`` name and
translates to whatever the installed jax understands.
"""
from __future__ import annotations

import inspect

try:                                    # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if check_vma is not None:
        if "check_vma" in _PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map(f, **kwargs)
