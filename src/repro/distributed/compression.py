"""Dictionary-quantized gradient compression for cross-pod reduction.

The paper's core move — encode values as small-integer codes against a
compact scale dictionary, operate on codes, decode at the edge — applied to
the slowest link in a multi-pod fleet: the inter-pod all-reduce. Gradients
are block-quantized to int8 (per-256-block f32 scale dictionary), psum'd in
code space is invalid (codes aren't linear), so the scheme is:
quantize -> all-to-all-free exchange via psum of dequantized int8-casts
with per-shard scales -> decode; with error feedback so the quantization
residual re-enters the next step's gradient (Seide et al. 1-bit SGD lineage).

Bytes on the pod link: 1 byte/param + 4/256 scale bytes ≈ 4x less than f32,
2x less than bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(x: jnp.ndarray):
    """x -> (int8 codes, f32 per-block scales). Shape-preserving."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    q = jnp.round(fp / jnp.maximum(scale, 1e-12))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, n: int):
    fp = q.astype(jnp.float32) * scale[:, None]
    return fp.reshape(-1)[:n].reshape(shape)


def compress_decompress(x: jnp.ndarray):
    """Round-trip (for error-feedback residual computation)."""
    q, s = quantize(x)
    return dequantize(q, s, x.shape, x.size)


def psum_compressed(tree, axis_name: str, error_buf=None):
    """Quantized psum over ``axis_name`` with error feedback.

    Must be called inside shard_map/pmap context where ``axis_name`` is bound.
    Returns (reduced_tree, new_error_buf). The int8 codes are what cross the
    pod link; the psum itself runs on the dequantized representation (XLA
    all-reduces the 1-byte-information payload; scales are psum'd separately
    as the 'dictionary' exchange).
    """
    if error_buf is None:
        error_buf = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                 tree)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize(g32)
        # exchange codes+scales: reduce the decoded payload across the axis
        local_dec = dequantize(q, s, g32.shape, g32.size)
        new_e = g32 - local_dec                       # error feedback
        reduced = jax.lax.psum(local_dec, axis_name)
        return reduced.astype(g.dtype), new_e

    flat, treedef = jax.tree_util.tree_flatten(tree)
    flat_e = treedef.flatten_up_to(error_buf)
    outs = [one(g, e) for g, e in zip(flat, flat_e)]
    red = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return red, err


def compression_ratio(tree) -> float:
    """Payload bytes f32 / payload bytes int8+scales."""
    n = sum(x.size for x in jax.tree_util.tree_leaves(tree))
    f32 = 4 * n
    comp = n + 4 * ((n + BLOCK - 1) // BLOCK)
    return f32 / comp
