"""Distribution layer: sharding rules, compressed collectives, pipeline
parallelism. pjit/GSPMD does the partitioning; this package decides WHAT
to shard where (DESIGN.md §4)."""
