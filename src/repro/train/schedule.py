"""LR schedules: linear-warmup cosine, and WSD (warmup-stable-decay —
MiniCPM's signature schedule, arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                     (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> Stable (constant) -> Decay (exponential tail).

    MiniCPM: stable phase at peak LR for (1 - decay_frac) of training, then a
    fast decay to final_frac * peak over the last decay_frac fraction.
    """
    step = jnp.asarray(step, jnp.float32)
    decay_start = total * (1.0 - decay_frac)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - decay_start) /
                 jnp.maximum(total - decay_start, 1), 0.0, 1.0)
    decay = peak_lr * (final_frac ** t)
    lr = jnp.where(step < warmup, warm,
                   jnp.where(step < decay_start, peak_lr, decay))
    return lr


def constant(step, *, peak_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak_lr)


SCHEDULES = {"cosine": warmup_cosine, "wsd": wsd, "constant": constant}
