"""Training runtime: optimizers, LR schedules, checkpointing, fault
tolerance, and the distributed trainer."""
