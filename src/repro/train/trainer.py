"""Distributed trainer: pjit'd train step, schedules, checkpoint/restart,
straggler detection — the loop a fleet would actually run.

make_train_step builds the jitted (params, opt_state, batch, step) ->
(params, opt_state, metrics) function with GSPMD shardings from
distributed.sharding; Trainer owns the loop, fault handling, and the
analytics-cycle hook (feedback of the trained embedding into the token
dictionary, paper §7).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd
from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import checkpoint as ckpt_lib
from repro.train.fault import StragglerDetector, FaultLog
from repro.train.optimizer import OptConfig, init_opt_state, apply_updates
from repro.train.schedule import SCHEDULES


@dataclass
class TrainConfig:
    steps: int = 100
    warmup: int = 10
    schedule: str = "cosine"
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = ""
    keep_ckpts: int = 3
    donate: bool = True


def make_train_step(cfg: ModelConfig, opt: OptConfig, train: TrainConfig,
                    mesh=None, batch_specs=None):
    """Returns (step_fn, shardings) — step_fn is jitted (pjit when mesh)."""
    sched = partial(SCHEDULES[train.schedule], peak_lr=opt.lr,
                    warmup=train.warmup, total=train.steps)

    def step_fn(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.train_loss(cfg, p, batch), has_aux=True)(params)
        lr = sched(step)
        params, opt_state = apply_updates(opt, grads, opt_state, params, lr)
        metrics = dict(metrics, loss=loss, lr=lr)
        return params, opt_state, metrics

    if mesh is None:
        donate = (0, 1) if train.donate else ()
        return jax.jit(step_fn, donate_argnums=donate), None

    p_specs = shd.param_pspecs(cfg, lm.param_specs(cfg), mesh)
    p_shard = shd.to_shardings(mesh, p_specs)
    opt_shape = jax.eval_shape(
        lambda: init_opt_state(opt, lm.param_specs(cfg)))
    opt_shard = shd.to_shardings(mesh, _opt_pspecs(cfg, opt_shape, mesh))
    b_shard = (shd.to_shardings(mesh, batch_specs)
               if batch_specs is not None else None)
    step_jit = jax.jit(
        step_fn,
        in_shardings=(p_shard, opt_shard, b_shard, None),
        out_shardings=(p_shard, opt_shard, None),
        donate_argnums=(0, 1) if train.donate else (),
    )
    return step_jit, {"params": p_shard, "opt": opt_shard, "batch": b_shard}


def _opt_pspecs(cfg: ModelConfig, opt_shape, mesh):
    """Optimizer-state PartitionSpecs.

    - adamw moments mirror the param specs PLUS a 'data' axis on the largest
      unsharded dim (ZeRO-1: optimizer states sharded over data parallelism;
      GSPMD derives the reduce-scatter/all-gather pair around the update);
    - adamw8 quantized bundles ({'q': param-shaped int8, 'scale': per-row
      f32}) inherit the ZeRO-extended param spec directly (the per-row
      layout is what makes them sharding-preserving);
    - adafactor factored stats are tiny -> replicated.
    """
    from jax.sharding import PartitionSpec as P
    p_shapes = lm.param_specs(cfg)
    p_specs = shd.param_pspecs(cfg, p_shapes, mesh)
    data = mesh.shape.get("data", 1)

    def zero1_extend(spec, leaf):
        if data <= 1 or any(e == "data" or (isinstance(e, tuple) and
                                            "data" in e) for e in spec):
            return spec              # FSDP params already carry 'data'
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        best, best_size = -1, 0
        for i, (e, sz) in enumerate(zip(entries, leaf.shape)):
            if e is None and sz % data == 0 and sz > best_size:
                best, best_size = i, sz
        if best >= 0:
            entries[best] = "data"
        return P(*entries)

    moment_specs = jax.tree.map(zero1_extend, p_specs, p_shapes,
                                is_leaf=lambda x: isinstance(x, P))

    is_qbundle = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}

    def qbundle_spec(spec, leaf):
        """q inherits the (ZeRO-extended) param spec; scale drops the last
        axis entry (it has one value per row)."""
        ext = zero1_extend(spec, leaf)
        entries = list(ext) + [None] * (len(leaf.shape) - len(ext))
        return {"q": P(*entries), "scale": P(*entries[:-1])}

    spec_leaves, spec_tree = jax.tree_util.tree_flatten(
        p_specs, is_leaf=lambda x: isinstance(x, P))
    shape_leaves = spec_tree.flatten_up_to(p_shapes)

    out = {"step": P()}
    for key, sub in opt_shape.items():
        if key == "step":
            continue
        if key in ("m", "v"):
            sub_leaves = spec_tree.flatten_up_to(sub)
            built = []
            for sp, sh, sl in zip(spec_leaves, shape_leaves, sub_leaves):
                if is_qbundle(sl):
                    built.append(qbundle_spec(sp, sh))
                else:
                    built.append(zero1_extend(sp, sh))
            out[key] = jax.tree_util.tree_unflatten(spec_tree, built)
        elif key == "f":
            out[key] = jax.tree.map(lambda l: P(*([None] * l.ndim)), sub)
        else:
            out[key] = jax.tree.map(lambda _: P(), sub)
    return out


@dataclass
class Trainer:
    cfg: ModelConfig
    opt: OptConfig
    train: TrainConfig
    mesh: Any = None
    fault_log: FaultLog = field(default_factory=FaultLog)

    def fit(self, params, data_iter: Iterator[dict], *,
            resume: bool = True) -> tuple[Any, list[dict]]:
        step_fn, _ = make_train_step(self.cfg, self.opt, self.train,
                                     mesh=self.mesh)
        opt_state = init_opt_state(self.opt, params)
        start = 0
        saver = None
        if self.train.ckpt_dir:
            saver = ckpt_lib.AsyncCheckpointer(self.train.ckpt_dir,
                                               keep=self.train.keep_ckpts)
            if resume:
                got = ckpt_lib.restore_latest(
                    self.train.ckpt_dir,
                    {"params": params, "opt": opt_state})
                if got[0] is not None:
                    start, tree, _ = got
                    params, opt_state = tree["params"], tree["opt"]
                    self.fault_log.record(start, "restart",
                                          f"resumed from step {start}")
        detector = StragglerDetector()
        history: list[dict] = []
        for step in range(start, self.train.steps):
            batch = next(data_iter)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if detector.observe(step, dt):
                self.fault_log.record(step, "straggler", f"{dt:.3f}s")
            if step % self.train.log_every == 0 or step == self.train.steps - 1:
                history.append({"step": step,
                                "loss": float(metrics["loss"]),
                                "ce": float(metrics["ce"]),
                                "lr": float(metrics["lr"]),
                                "dt": dt})
            if saver and self.train.ckpt_every and \
                    (step + 1) % self.train.ckpt_every == 0:
                saver.save_async(step + 1, {"params": params,
                                            "opt": opt_state})
        if saver:
            saver.save_async(self.train.steps, {"params": params,
                                                "opt": opt_state})
            saver.wait()
        return params, history
