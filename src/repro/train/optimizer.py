"""Optimizers: AdamW, Adafactor, and AdamW8 (block-quantized int8 states).

AdamW8 is the paper's dictionary-encoding idea applied to optimizer state:
moments are stored as int8 codes plus a per-row f32 scale 'dictionary',
cutting optimizer HBM from 8 to ~2.01 bytes/param — what lets the 400B
llama4 cell fit 16 GB/chip v5e (EXPERIMENTS.md §Dry-run). The second moment
is kept in the sqrt domain so int8 resolution applies directly to the
update denominator. Quantization error is absorbed by re-quantizing after
each update (m/v are smooth EMAs).

Adafactor keeps only factored second moments for ≥2-D params.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adamw8 | adafactor
    lr: float = 3e-4             # peak LR (schedule scales it)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


# ---------------------------------------------------------------------------
# int8 moment quantization (the 'state dictionary')
# ---------------------------------------------------------------------------
# Per-ROW scales (max|x| over the last dim): the int8 code tensor keeps the
# exact param shape, so it inherits the param's GSPMD sharding with zero
# resharding (a flat 256-block layout would need a sharding-breaking reshape
# and an all-gather per step). Small leaves (norm scales, biases) stay f32.
QUANT_MIN_SIZE = 65536


def quantize_blockwise(x: jnp.ndarray):
    x = x.astype(jnp.float32)
    if x.ndim < 2 or x.size < QUANT_MIN_SIZE:
        return x                               # plain f32 moment
    scale = jnp.max(jnp.abs(x), axis=-1) / 127.0
    q = jnp.round(x / jnp.maximum(scale[..., None], 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_blockwise(d, shape=None, n=None) -> jnp.ndarray:
    if isinstance(d, dict):
        return d["q"].astype(jnp.float32) * \
            jnp.maximum(d["scale"], 1e-12)[..., None]
    return d


# ---------------------------------------------------------------------------
# grad utils
# ---------------------------------------------------------------------------
def global_norm(tree) -> jnp.ndarray:
    # accumulate in f32 WITHOUT materializing f32 copies of bf16 grads
    return jnp.sqrt(sum(jnp.sum(jnp.square(g), dtype=jnp.float32)
                        for g in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * factor.astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def _adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def _adamw_update(cfg: OptConfig, grads, state, params, lr):
    b1, b2 = cfg.b1, cfg.b2
    step = state["step"] + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * update.astype(jnp.float32)).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# AdamW8 (quantized states)
# ---------------------------------------------------------------------------
def _adamw8_init(params):
    qzeros = lambda p: quantize_blockwise(jnp.zeros(p.shape, jnp.float32))
    return {"m": jax.tree.map(qzeros, params),
            "v": jax.tree.map(qzeros, params)}


_IS_QDICT = lambda x: isinstance(x, dict) and "q" in x and "scale" in x


def _adamw8_update(cfg: OptConfig, grads, state, params, lr):
    b1, b2 = cfg.b1, cfg.b2
    step = state["step"] + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd_one(g, mq, vq, p):
        g = g.astype(jnp.float32)
        quantized = isinstance(vq, dict)
        m = b1 * dequantize_blockwise(mq) + (1 - b1) * g
        # v is stored in the sqrt domain when quantized: int8 resolution then
        # applies to the rsqrt denominator directly (plain-domain int8 zeroes
        # small v and blows up updates).
        v_prev = dequantize_blockwise(vq)
        if quantized:
            v_prev = v_prev ** 2
        v = b2 * v_prev + (1 - b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p - lr * update).astype(p.dtype)
        new_v = quantize_blockwise(jnp.sqrt(v)) if quantized else v
        return new_p, quantize_blockwise(m), new_v

    def upd(g, mq, vq, p):
        # layer-stacked params: lax.map over the stack axis so only one
        # group's f32 dequantized moments are live at a time (the stacked
        # expert tensors would otherwise dominate peak HBM).
        if p.ndim >= 3 and p.shape[0] > 1 and isinstance(vq, dict):
            return jax.lax.map(lambda a: upd_one(*a), (g, mq, vq, p))
        return upd_one(g, mq, vq, p)

    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_m = treedef.flatten_up_to(state["m"])
    leaves_v = treedef.flatten_up_to(state["v"])
    leaves_p = jax.tree_util.tree_leaves(params)
    outs = [upd(g, m, v, p) for g, m, v, p in
            zip(leaves_g, leaves_m, leaves_v, leaves_p)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------
def _adafactor_init(params):
    def st(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(st, params)}


def _adafactor_update(cfg: OptConfig, grads, state, params, lr):
    step = state["step"] + 1
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(g, s, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = decay * s["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * s["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :] /
                     jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None],
                                 1e-30))
            update = g / jnp.maximum(jnp.sqrt(denom), 1e-30)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = decay * s["v"] + (1 - decay) * g2
            update = g / jnp.maximum(jnp.sqrt(v), 1e-30)
            new_s = {"v": v}
        # relative-scale clipping (Adafactor d=1)
        rms = jnp.sqrt(jnp.mean(update ** 2))
        update = update / jnp.maximum(1.0, rms)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * update).astype(p.dtype), new_s

    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_s = treedef.flatten_up_to(state["f"])
    leaves_p = jax.tree_util.tree_leaves(params)
    outs = [upd(g, s, p) for g, s, p in zip(leaves_g, leaves_s, leaves_p)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_f = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_params, {"f": new_f, "step": step}


# ---------------------------------------------------------------------------
# public surface
# ---------------------------------------------------------------------------
_INITS = {"adamw": _adamw_init, "adamw8": _adamw8_init,
          "adafactor": _adafactor_init}
_UPDATES = {"adamw": _adamw_update, "adamw8": _adamw8_update,
            "adafactor": _adafactor_update}


def init_opt_state(cfg: OptConfig, params):
    state = _INITS[cfg.name](params)
    state["step"] = jnp.asarray(0, jnp.int32)
    return state


def apply_updates(cfg: OptConfig, grads, state, params, lr):
    """Returns (new_params, new_state). ``lr`` is the scheduled LR scalar."""
    if cfg.clip_norm > 0:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
    return _UPDATES[cfg.name](cfg, grads, state, params, lr)


def state_bytes_per_param(cfg: OptConfig) -> float:
    return {"adamw": 8.0, "adamw8": 2.01, "adafactor": 0.02}[cfg.name]
