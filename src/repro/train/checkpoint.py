"""Step-granular checkpointing: sharded-tree -> per-host npz + JSON manifest.

Fault-tolerance contract (DESIGN.md §4):
- atomic: write to ``step_<n>.tmp/`` then rename — a crash mid-write never
  corrupts the latest checkpoint;
- async: ``save_async`` snapshots to host memory (device_get) on the caller
  thread, then writes on a background thread so the train loop keeps going;
- restart: ``restore_latest`` finds the newest complete step; resharding onto
  a different mesh is just device_put with new shardings (elastic re-mesh).

At multi-host scale each process writes ``arrays_p<process_index>.npz`` with
its addressable shards; this container is single-process so p0 holds all.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Blocking save. Returns the final checkpoint path."""
    names, leaves, _ = _flatten_with_names(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    pidx = jax.process_index()
    np.savez(os.path.join(tmp, f"arrays_p{pidx}.npz"),
             **{str(i): a for i, a in enumerate(host_leaves)})
    manifest = {"step": step, "names": names,
                "n_processes": jax.process_count(),
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot on caller thread; write on a daemon thread; one in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

        def _write():
            tmp = os.path.join(self.ckpt_dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.ckpt_dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays_p0.npz"),
                     **{str(i): a for i, a in enumerate(host_leaves)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "names": names,
                           "extra": extra or {}}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(latest_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                out.append(int(d[5:]))
    return sorted(out)


def restore(ckpt_dir: str, step: int, tree_like: Any,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like`` (values are ignored).
    ``shardings``: optional matching tree of NamedShardings for device_put —
    this is the elastic-re-mesh path (same arrays, new mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays_p0.npz"))
    names, leaves, treedef = _flatten_with_names(tree_like)
    if names != manifest["names"]:
        raise ValueError("checkpoint tree structure mismatch: "
                         f"{set(names) ^ set(manifest['names'])}")
    arrays = [data[str(i)] for i in range(len(names))]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrays), manifest["extra"]


def restore_latest(ckpt_dir: str, tree_like: Any,
                   shardings: Any | None = None):
    steps = latest_steps(ckpt_dir)
    if not steps:
        return None, None, None
    tree, extra = restore(ckpt_dir, steps[-1], tree_like, shardings)
    return steps[-1], tree, extra
