"""Fault tolerance: straggler detection + elastic re-mesh planning.

At 1000+ nodes the two dominant failure modes are slow hosts (stragglers —
tail-latency amplification under synchronous SPMD) and lost hosts (requiring
a smaller mesh + reshard-from-checkpoint). Both mechanisms here are pure
host-side logic so they are unit-testable without hardware; the trainer wires
them into the step loop, and checkpoint.restore(shardings=new_mesh) performs
the actual elastic reshard.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


class StragglerDetector:
    """EWMA step-time tracker; flags outlier steps/hosts.

    On real fleets the per-host step time arrives via heartbeats; here the
    single-process trainer feeds its own step times (and tests feed synthetic
    fleets). Mitigation policy is up to the caller (re-mesh, evict, re-route
    data) — detection must be cheap and robust to warmup.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.mean: float | None = None
        self.var: float = 0.0
        self.n = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return False
        is_outlier = False
        if self.n > self.warmup:
            sd = math.sqrt(self.var) if self.var > 0 else self.mean * 0.1
            if dt > self.mean + self.threshold * sd and dt > 1.2 * self.mean:
                is_outlier = True
                self.flagged.append((step, dt))
        if not is_outlier:          # don't pollute the EWMA with outliers
            delta = dt - self.mean
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta ** 2)
        return is_outlier

    @property
    def straggler_fraction(self) -> float:
        return len(self.flagged) / max(self.n, 1)

    def hedge_cutoff(self, factor: float, floor: float) -> float:
        """Latency past which a BACKUP attempt should launch (the
        speculative-duplicate idiom: past ``factor`` x the EWMA mean a
        step is probably straggling, so racing a duplicate on healthy
        hardware beats waiting it out). ``floor`` bounds the cutoff from
        below so warmup noise (or an untrained mean) never hedges
        healthy-latency work; before any observation the floor IS the
        cutoff."""
        return max(floor, factor * (self.mean or 0.0))


@dataclass
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    n_devices: int


def plan_elastic_mesh(n_available: int, *, model_parallel: int,
                      multi_pod: bool = False,
                      pod_size: int = 256) -> MeshPlan:
    """Largest (pod ×) data × model mesh that fits the surviving devices.

    Invariants: 'model' stays fixed (param sharding must not change — only
    data parallelism shrinks, so reshard-from-checkpoint touches batch
    sharding only); data axis is the largest divisor that fits.
    """
    if n_available < model_parallel:
        raise ValueError(f"need >= {model_parallel} devices for the model "
                         f"axis, have {n_available}")
    if multi_pod and n_available >= 2 * pod_size:
        pods = n_available // pod_size
        data = pod_size // model_parallel
        return MeshPlan((pods, data, model_parallel),
                        ("pod", "data", "model"),
                        pods * data * model_parallel)
    data = n_available // model_parallel
    return MeshPlan((data, model_parallel), ("data", "model"),
                    data * model_parallel)


@dataclass
class FaultEvent:
    step: int
    kind: str                    # 'straggler' | 'device_loss' | 'restart'
    detail: str = ""


@dataclass
class FaultLog:
    events: list[FaultEvent] = field(default_factory=list)

    def record(self, step: int, kind: str, detail: str = ""):
        self.events.append(FaultEvent(step, kind, detail))

    def summary(self) -> dict:
        out: dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out
