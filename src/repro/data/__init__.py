"""Data pipeline: dictionary-encoded, bit-packed token storage (the paper's
columnar substrate feeding the LM trainer)."""
from repro.data.tokenstore import TokenStore
from repro.data.synthetic import synthetic_corpus
from repro.data.loader import token_batches

__all__ = ["TokenStore", "synthetic_corpus", "token_batches"]
