"""Batch loader: TokenStore -> (tokens, labels) minibatches.

Deterministic, restart-safe (seeded per step — resuming at step k replays
the exact batch k would have seen, a fault-tolerance requirement), with
next-token labels and optional stub frontends for vlm/audio archs.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np
import jax.numpy as jnp

from repro.data.tokenstore import TokenStore
from repro.models.config import ModelConfig


def token_batches(store: TokenStore, cfg: ModelConfig, *, batch: int,
                  seq: int, seed: int = 0, start_step: int = 0
                  ) -> Iterator[dict]:
    span = seq + 1
    max_start = store.n - span
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        starts = rng.integers(0, max_start, size=batch)
        windows = np.stack([store.get_span(s, span) for s in starts])
        out = {"tokens": jnp.asarray(windows[:, :-1], jnp.int32),
               "labels": jnp.asarray(windows[:, 1:], jnp.int32)}
        if cfg.family == "vlm":
            out["patch_embeds"] = jnp.asarray(
                rng.standard_normal((batch, cfg.n_patches,
                                     cfg.frontend_dim)), jnp.float32)
            # patch positions carry no next-token signal
            out["labels"] = out["labels"].at[:, :cfg.n_patches].set(-1)
        if cfg.family == "audio":
            out["frames"] = jnp.asarray(
                rng.standard_normal((batch, seq, cfg.frontend_dim)),
                jnp.float32)
        yield out
        step += 1
