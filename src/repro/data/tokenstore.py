"""TokenStore: the LM corpus as a columnar, dictionary-encoded column.

The token-id vocabulary IS the dictionary (codes = ids); the store keeps the
stream bit-packed at ceil(log2(V)) bits (paper §5.1), counts per token
(paper §6.2 — instant unigram stats for data curation), and ships batches to
the device as packed words + on-device bitunpack — the paper's minimal-data-
movement path applied to pretraining data.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.columnar.bitpack import bits_needed, pack_bits, packed_nbytes
from repro.kernels.bitunpack import bitunpack, repack_for_device, tpu_width


class TokenStore:
    def __init__(self, tokens: np.ndarray, vocab: int,
                 device_unpack: bool = False):
        tokens = np.asarray(tokens, dtype=np.int64)
        if tokens.ndim != 1:
            raise ValueError("tokens must be a flat stream")
        if tokens.size and tokens.max() >= vocab:
            raise ValueError("token id out of vocab range")
        self.vocab = vocab
        self.n = tokens.size
        self.bits = bits_needed(vocab)
        self.device_unpack = device_unpack
        # count metadata (paper §6.2)
        self.counts = np.bincount(tokens, minlength=vocab).astype(np.int64)
        if device_unpack:
            self.words, self.device_bits = repack_for_device(tokens, self.bits)
            self.tokens = None
        else:
            self.words = pack_bits(tokens, self.bits)
            self.device_bits = self.bits
            self.tokens = tokens.astype(np.int32)

    # -- §6.2 count-metadata stats over the corpus ---------------------------
    def unigram_probs(self) -> np.ndarray:
        return self.counts / max(self.n, 1)

    def entropy_bits(self) -> float:
        p = self.unigram_probs()
        p = p[p > 0]
        return float(-(p * np.log2(p)).sum())

    @property
    def packed_nbytes(self) -> int:
        return int(self.words.nbytes)

    @property
    def raw_nbytes(self) -> int:
        return 4 * self.n                     # int32 ids

    def get_span(self, start: int, length: int) -> np.ndarray:
        """Host path: decode a token span (used by the loader)."""
        if self.tokens is not None:
            return self.tokens[start:start + length]
        from repro.columnar.bitpack import unpack_bits
        # decode only the covering word range
        s = 32 // self.device_bits
        w0 = start // s
        w1 = (start + length + s - 1) // s
        local = unpack_bits(self.words[w0:w1], self.device_bits,
                            (w1 - w0) * s)
        return local[start - w0 * s: start - w0 * s + length]
