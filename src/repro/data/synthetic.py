"""Synthetic corpora with LM-like statistics (Zipf unigram + short-range
structure) for examples, benchmarks, and the end-to-end trainer."""
from __future__ import annotations

import numpy as np


def synthetic_corpus(n_tokens: int, vocab: int, seed: int = 0,
                     zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-distributed ids with a Markov-ish repetition structure so the
    model has something learnable (repeats + local bigram patterns)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** -zipf_a
    probs /= probs.sum()
    base = rng.choice(vocab, size=n_tokens, p=probs)
    # inject learnable bigrams: token t follows (t*7+3) % vocab 30% of time
    follow = (base * 7 + 3) % vocab
    mask = rng.random(n_tokens) < 0.3
    out = base.copy()
    out[1:][mask[1:]] = follow[:-1][mask[1:]]
    return out.astype(np.int64)
