"""ModelConfig: one dataclass covering every assigned architecture family."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # layer i is MoE iff i % moe_every == moe_every-1
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0      # 0 = full causal
    n_full_attn: int = 0         # hybrid: # of layers that stay full-attention

    # --- ssm / xlstm / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2          # d_inner = ssm_expand * d_model
    conv_width: int = 4
    slstm_group: int = 0         # xlstm: group = (slstm_group-1) mLSTM + 1 sLSTM
    qk_dim_ratio: float = 0.5    # xlstm mLSTM: dk = ratio * dv

    # --- mlp ---
    mlp_style: str = "swiglu"    # swiglu (3 mats) | gelu (2 mats)

    # --- embeddings / head ---
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256

    # --- enc-dec / frontends (vlm, audio) ---
    enc_layers: int = 0
    frontend: str = "none"       # none | vision | audio
    frontend_dim: int = 0        # stub embedding dim fed by input_specs
    n_patches: int = 0           # vlm: patches prepended to the sequence

    # --- numerics ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: str = "layer"         # none | layer | dots
    scan_unroll: bool = False    # unroll the layer scan (dry-run cost probes)
    loss_chunk: int = 1024       # seq-chunked checkpointed CE (0 = full logits)
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (dictionary-quantized)
    grad_accum: int = 1          # microbatches per step (activation liveness)
    force_fsdp: bool = False     # FSDP-shard params regardless of size
    pure_dp: bool = False        # use the model axis as extra data parallelism
                                 # (ZeRO-3 weight sharding, no TP) — right call
                                 # for <2B-param models where TP-16 drowns in
                                 # per-layer activation collectives
    notes: str = ""

    # ----- derived -----
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def is_moe_layer(self, i: int) -> bool:
        return (self.n_experts > 0 and
                i % self.moe_every == self.moe_every - 1)

    @property
    def n_moe_layers(self) -> int:
        return sum(self.is_moe_layer(i) for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
        n_mats = 3 if self.mlp_style == "swiglu" else 2
        mlp = n_mats * d * f
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "moe":
            moe = self.n_moe_layers * (self.n_experts * 3 * d * f + d * self.n_experts)
            dense = (self.n_layers - self.n_moe_layers) * mlp
            shared = self.n_layers * mlp if self.shared_expert else 0
            return emb + self.n_layers * attn + moe + dense + shared
        if self.family == "ssm":
            di = self.d_inner
            dk = int(di * self.qk_dim_ratio)
            mlstm = d * (2 * dk + 2 * di) + di * d + 3 * di  # q,k,v,up(+gates),out
            return emb + self.n_layers * mlstm
        if self.family == "hybrid":
            di = self.d_inner
            ssm = d * (di + 2 * self.n_heads * self.ssm_state + di) + di * d
            return emb + self.n_layers * (attn + ssm + mlp)
        n_dec = self.n_layers
        n_enc = self.enc_layers
        cross = 2 * d * self.n_kv * hd + d * self.n_heads * hd + self.n_heads * hd * d
        return emb + n_dec * (attn + mlp) + n_enc * (attn + mlp) + \
            (n_dec * cross if n_enc else 0)

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
        mlp = 3 * d * f
        per_moe = self.top_k * 3 * d * f + d * self.n_experts + \
            (mlp if self.shared_expert else 0)
        act = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            act += attn + (per_moe if self.is_moe_layer(i) else mlp)
        return act
