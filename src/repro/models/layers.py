"""Shared primitives: norms, RoPE, SwiGLU MLP, initializers.

All layers are pure functions over explicit param pytrees. Stacked-layer
params carry a leading L dimension and are consumed by ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# -- norms ----------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


# -- RoPE -----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, d_head); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                           # (dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    angles = angles[..., :, None, :]                        # (..., S, 1, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLP ------------------------------------------------------------------------
def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# -- initializers -----------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)
