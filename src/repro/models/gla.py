"""Chunked gated linear attention — the shared recurrence core for mLSTM
(xLSTM) and the SSD/Mamba heads in Hymba (DESIGN.md §5).

State per head is an outer-product memory  S_t = a_t · S_{t-1} + k_t v_tᵀ
(a_t ∈ (0,1] per step), read as  o_t = qᵀ S_t.  The chunkwise form turns the
recurrence into MXU matmuls: within a chunk an (C×C) decay-masked attention,
across chunks a scanned state update — O(S·C) instead of O(S²), constant
state for decode.

mLSTM's normalizer n_t = a_t n_{t-1} + k_t is carried as a SEPARATE (B,H,DK)
state (not an appended value column): dv stays a clean power of two so the
value/state tensors shard over the model axis (P(batch,None,None,'model')),
which is what makes the xlstm cells fit (EXPERIMENTS §Perf). Inputs stay in
their compute dtype (bf16); only decay/normalizer/state accumulate in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp



def chunked_gla(q, k, v, log_a, *, chunk: int = 256, normalizer: bool = False):
    """q,k: (B,S,H,DK); v: (B,S,H,DV); log_a: (B,S,H) in (-inf, 0].

    Returns (out (B,S,H,DV), final_state (B,H,DK,DV)) and, with
    ``normalizer=True``, additionally (n_out (B,S,H), n_state (B,H,DK)).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    nc = s // chunk
    qc = q.reshape(b, nc, chunk, h, dk)
    kc = k.reshape(b, nc, chunk, h, dk)
    vc = v.reshape(b, nc, chunk, h, dv)
    la = log_a.reshape(b, nc, chunk, h).astype(jnp.float32)

    def body(carry, inp):
        state, nstate = carry                   # (B,H,DK,DV) f32, (B,H,DK)
        qi, ki, vi, lai = inp                   # (B,C,H,*) chunk i
        cum = jnp.cumsum(lai, axis=1)           # (B,C,H) decay to chunk start
        total = cum[:, -1:, :]                  # (B,1,H)
        dec_q = jnp.exp(cum)
        q_dec = qi * dec_q[..., None].astype(qi.dtype)
        # inter-chunk: o_inter[t] = (q_t * a^{cum_t}) @ S_prev
        o_inter = jnp.einsum("bchk,bhkv->bchv", q_dec, state,
                             preferred_element_type=jnp.float32)
        # intra-chunk: scores[t,u] = q_t·k_u * a^{cum_t - cum_u}, u <= t
        scores = jnp.einsum("bchk,buhk->bhcu", qi, ki,
                            preferred_element_type=jnp.float32)
        dec = cum[:, :, None, :] - cum[:, None, :, :]        # (B,C,U,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(causal[None, :, :, None], jnp.exp(dec), 0.0)
        scores = scores * w.transpose(0, 3, 1, 2)
        o_intra = jnp.einsum("bhcu,buhv->bchv", scores.astype(vi.dtype), vi,
                             preferred_element_type=jnp.float32)
        # state update: S = a^{total} S + sum_u a^{total-cum_u} k_u v_uᵀ
        dec_k = jnp.exp(total - cum)
        k_dec = ki * dec_k[..., None].astype(ki.dtype)
        s_new = state * jnp.exp(total).transpose(0, 2, 1)[..., None] + \
            jnp.einsum("buhk,buhv->bhkv", k_dec, vi,
                       preferred_element_type=jnp.float32)
        out_i = (o_inter + o_intra)
        if not normalizer:
            return (s_new, nstate), (out_i, jnp.zeros((b, chunk, h),
                                                      jnp.float32))
        # normalizer shares scores/decay: n_t = q_t·(running sum of decayed k)
        n_inter = jnp.einsum("bchk,bhk->bch", q_dec.astype(jnp.float32),
                             nstate)
        n_intra = scores.sum(axis=-1).transpose(0, 2, 1)     # (B,C,H)
        n_new = nstate * jnp.exp(total).transpose(0, 2, 1) + \
            jnp.einsum("buhk->bhk", k_dec.astype(jnp.float32))
        return (s_new, n_new), (out_i, n_inter + n_intra)

    state0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    inputs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
              vc.transpose(1, 0, 2, 3, 4), la.transpose(1, 0, 2, 3))
    (final, n_final), (outs, n_outs) = jax.lax.scan(body, (state0, n0),
                                                    inputs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv).astype(q.dtype)
    if not normalizer:
        return out, final
    n_out = n_outs.transpose(1, 0, 2, 3).reshape(b, s, h)
    return out, final, n_out, n_final


def gla_step(state, q, k, v, log_a, nstate=None):
    """Single decode step. state (B,H,DK,DV); q,k (B,H,DK); v (B,H,DV);
    log_a (B,H). Returns (new_state, out) or, with nstate given,
    (new_state, out, new_nstate, n_out (B,H))."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    s_new = state * a + jnp.einsum("bhk,bhv->bhkv",
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    out = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), s_new)
    if nstate is None:
        return s_new, out.astype(q.dtype)
    n_new = nstate * a[..., 0] + k.astype(jnp.float32)
    n_out = jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n_new)
    return s_new, out.astype(q.dtype), n_new, n_out


def gla_ref(q, k, v, log_a):
    """Sequential oracle (step-by-step) for tests."""
    b, s, h, dk = q.shape

    def body(state, t):
        s_new, o = gla_step(state, q[:, t], k[:, t], v[:, t], log_a[:, t])
        return s_new, o

    state0 = jnp.zeros((b, h, dk, v.shape[-1]), jnp.float32)
    final, outs = jax.lax.scan(body, state0, jnp.arange(s))
    return outs.transpose(1, 0, 2, 3), final
