"""Flash attention (pure JAX, custom VJP): O(S) memory in training.

The naive blockwise scan is numerically identical but lets autodiff stack
per-chunk probabilities as f32 scan residuals — O(S·T) per layer, which is
what blows the HBM budget at 4k/32k sequence lengths. Here the forward saves
only (out, m, l) per query; the backward recomputes each chunk's
probabilities from (q, k, m, l) and accumulates dq/dk/dv chunk-by-chunk —
the standard FlashAttention-2 dataflow expressed with lax.scan so the HLO
stays compact under the layer scan.

GQA layout: q (B,S,KV,G,dh) [pre-scaled], k/v (B,T,KV,dh).
Masking inputs are ARRAYS (traced-safe for decode pos, per-layer windows):
  q_pos (S,) f32 absolute query positions,
  kbias (T,) f32 additive key bias (0 valid / -1e30 beyond kv_len),
  window f32 scalar (<=0 -> full causal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, k_pos, window, kbias):
    keep = q_pos[:, None] >= k_pos[None, :]
    w = jnp.where(window > 0, window, jnp.float32(1e18))
    keep &= (q_pos[:, None] - k_pos[None, :]) < w
    return jnp.where(keep, 0.0, NEG_INF) + kbias[None, :]


def _fwd_scan(qg, k, v, q_pos, kbias, window, kv_chunk):
    b, s, kvh, g, dh = qg.shape
    t = k.shape[1]
    n_chunks = t // kv_chunk

    def body(carry, idx):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, idx * kv_chunk, kv_chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * kv_chunk, kv_chunk, 1)
        kb = jax.lax.dynamic_slice_in_dim(kbias, idx * kv_chunk, kv_chunk, 0)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, ks,
                            preferred_element_type=jnp.float32)
        k_pos = (idx * kv_chunk + jnp.arange(kv_chunk)).astype(jnp.float32)
        scores = scores + _mask(q_pos, k_pos, window, kb)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(jnp.minimum(m - m_new, 0.0))
        p = jnp.exp(scores - m_new[..., None])
        p = jnp.where(scores <= NEG_INF / 2, 0.0, p)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgsc,bckd->bkgsd", p.astype(v.dtype), vs)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4), m, l     # -> (B,S,KV,G,dh)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def flash_attention(qg, k, v, q_pos, kbias, window, kv_chunk):
    """qg (B,S,KV,G,dh) pre-scaled; k, v (B,T,KV,dh). Returns (B,S,KV,G,dh)."""
    out, _, _ = _fwd_scan(qg, k, v, q_pos, kbias, window, kv_chunk)
    return out.astype(qg.dtype)


def _flash_fwd(qg, k, v, q_pos, kbias, window, kv_chunk):
    out, m, l = _fwd_scan(qg, k, v, q_pos, kbias, window, kv_chunk)
    return out.astype(qg.dtype), (qg, k, v, q_pos, kbias, window, out, m, l)


def _flash_bwd(kv_chunk, res, dout):
    qg, k, v, q_pos, kbias, window, out, m, l = res
    b, s, kvh, g, dh = qg.shape
    t = k.shape[1]
    n_chunks = t // kv_chunk
    l_safe = jnp.maximum(l, 1e-30)
    dout32 = dout.astype(jnp.float32)
    # delta[b,k,g,s] = sum_d dout * out   (FlashAttention-2 trick)
    delta = jnp.einsum("bskgd,bskgd->bkgs", dout32, out.astype(jnp.float32))

    def body(dq_acc, idx):
        ks = jax.lax.dynamic_slice_in_dim(k, idx * kv_chunk, kv_chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * kv_chunk, kv_chunk, 1)
        kb = jax.lax.dynamic_slice_in_dim(kbias, idx * kv_chunk, kv_chunk, 0)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, ks,
                            preferred_element_type=jnp.float32)
        k_pos = (idx * kv_chunk + jnp.arange(kv_chunk)).astype(jnp.float32)
        scores = scores + _mask(q_pos, k_pos, window, kb)
        p = jnp.exp(scores - m[..., None]) / l_safe[..., None]
        p = jnp.where(scores <= NEG_INF / 2, 0.0, p)
        dv_c = jnp.einsum("bkgst,bskgd->btkd", p, dout32)
        dp = jnp.einsum("bskgd,btkd->bkgst", dout32, vs.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        dq_c = jnp.einsum("bkgst,btkd->bskgd", ds, ks.astype(jnp.float32))
        dk_c = jnp.einsum("bkgst,bskgd->btkd", ds, qg.astype(jnp.float32))
        return dq_acc + dq_c, (dk_c, dv_c)

    dq0 = jnp.zeros((b, s, kvh, g, dh), jnp.float32)
    dq, (dk_chunks, dv_chunks) = jax.lax.scan(body, dq0, jnp.arange(n_chunks))
    dk = dk_chunks.transpose(1, 0, 2, 3, 4).reshape(b, t, kvh, dh)
    dv = dv_chunks.transpose(1, 0, 2, 3, 4).reshape(b, t, kvh, dh)
    return (dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(q_pos), jnp.zeros_like(kbias),
            jnp.zeros_like(window))


flash_attention.defvjp(_flash_fwd, _flash_bwd)
