"""Block definitions + initializers for every architecture family.

Layers are organized as a repeating *pattern* of block kinds (e.g. llama4:
``['dense', 'moe']`` × 24 groups; xLSTM: ``['mlstm']*7 + ['slstm']`` × 6).
Params for each pattern position are stacked over groups and consumed with
``lax.scan`` for compact HLO. Per-layer non-trained metadata (e.g. Hymba's
per-layer attention window) rides in a parallel ``meta`` pytree.

Each kind implements:
  init_<kind>(cfg, key, n)          -> stacked params dict
  apply_<kind>(cfg, p, meta, x, *, cache, pos, causal) -> (x, new_cache, aux)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import attention
from repro.models.config import ModelConfig
from repro.models.gla import chunked_gla, gla_step
from repro.models.moe import moe_ff

HUGE_WINDOW = 1 << 30


def _pick_chunk(s: int, target: int = 256) -> int:
    """Largest GLA chunk ≤ target that divides s."""
    if s <= target:
        return s
    if s % target == 0:
        return target
    import math
    return math.gcd(s, target)


# =====================================================================
# pattern
# =====================================================================
def block_pattern(cfg: ModelConfig) -> list[str]:
    if cfg.family == "moe":
        if cfg.moe_every <= 1:
            return ["moe"]
        return ["dense"] * (cfg.moe_every - 1) + ["moe"]
    if cfg.family in ("dense", "vlm"):
        return ["dense"]
    if cfg.family == "ssm":
        if cfg.slstm_group > 1:
            return ["mlstm"] * (cfg.slstm_group - 1) + ["slstm"]
        return ["mlstm"]
    if cfg.family == "hybrid":
        return ["hymba"]
    if cfg.family == "audio":
        return ["xdec"]            # decoder stack; encoder handled separately
    raise ValueError(cfg.family)


def n_groups(cfg: ModelConfig) -> int:
    pat = block_pattern(cfg)
    if cfg.n_layers % len(pat):
        raise ValueError(f"{cfg.name}: n_layers {cfg.n_layers} not divisible "
                         f"by pattern {pat}")
    return cfg.n_layers // len(pat)


# =====================================================================
# attention sub-module (shared by dense/moe/hymba/xdec/enc)
# =====================================================================
def _attn_init(cfg: ModelConfig, key, n: int, dt, prefix_kv: int | None = None):
    hd = cfg.head_dim
    kv = prefix_kv if prefix_kv is not None else cfg.n_kv
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (n, cfg.d_model, cfg.n_heads * hd), dt),
        "wk": L.dense_init(ks[1], (n, cfg.d_model, kv * hd), dt),
        "wv": L.dense_init(ks[2], (n, cfg.d_model, kv * hd), dt),
        "wo": L.dense_init(ks[3], (n, cfg.n_heads * hd, cfg.d_model), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n, cfg.n_heads * hd), dt)
        p["bk"] = jnp.zeros((n, kv * hd), dt)
        p["bv"] = jnp.zeros((n, kv * hd), dt)
    return p


def _attn_apply(cfg: ModelConfig, p, x, *, cache, pos, window, causal=True,
                rope: bool = True, kv_src: jnp.ndarray | None = None):
    """x (B,S,D). cache: None or dict(k,v) with (B,T,KV,hd). kv_src: cross-attn
    source (memory) — when given, k/v come from it and cache is precomputed."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    if kv_src is None:
        src = x
    else:
        src = kv_src
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    kvh = k.shape[-1] // hd
    k = k.reshape(b, -1, kvh, hd)
    v = v.reshape(b, -1, kvh, hd)
    if rope:
        q_pos = pos + jnp.arange(s)
        q = L.apply_rope(q, q_pos[None, :], cfg.rope_theta)
        if kv_src is None:
            k = L.apply_rope(k, q_pos[None, :], cfg.rope_theta)

    kv_len = None
    if cache is not None and kv_src is None:
        if "ks" in cache:        # int8 dictionary-quantized cache
            kq, ks_new = _kv_quantize(k)
            vq, vs_new = _kv_quantize(v)
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, pos,
                                                     axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, pos,
                                                     axis=1)
            cks = jax.lax.dynamic_update_slice_in_dim(cache["ks"], ks_new,
                                                      pos, axis=1)
            cvs = jax.lax.dynamic_update_slice_in_dim(cache["vs"], vs_new,
                                                      pos, axis=1)
            cache = {"k": ck, "v": cv, "ks": cks, "vs": cvs}
            k = _kv_dequantize(ck, cks, x.dtype)
            v = _kv_dequantize(cv, cvs, x.dtype)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
            cache = {"k": ck, "v": cv}
            k, v = ck, cv
        kv_len = pos + s
    if not causal:
        # bidirectional encoder: mask nothing (window off, q>=k off)
        out = _bidir_attention(q, k, v)
    else:
        out = attention(q, k, v, q_offset=pos if kv_src is None else 0,
                        window=window, kv_len=kv_len)
    out = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    return out, cache


def _kv_quantize(x):
    """(B,S,KV,hd) -> int8 codes + per-(token,head) f32 scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    q = jnp.round(xf / jnp.maximum(scale[..., None], 1e-12)).astype(jnp.int8)
    return q, scale


def _kv_dequantize(q, scale, dt):
    return (q.astype(jnp.float32) *
            jnp.maximum(scale, 1e-12)[..., None]).astype(dt)


def _bidir_attention(q, k, v, kv_chunk: int = 1024):
    """Non-causal attention. Large T goes through flash with q_pos pinned to
    T (the causal predicate becomes all-true), keeping O(S) memory for the
    32k encoder shapes; small T takes the direct path."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    t = k.shape[1]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh) * (dh ** -0.5)
    if t > kv_chunk and t % kv_chunk == 0:
        from repro.models.flash import flash_attention
        q_pos = jnp.full((s,), float(t), jnp.float32)
        kbias = jnp.zeros((t,), jnp.float32)
        out = flash_attention(qg, k, v, q_pos, kbias, jnp.float32(0),
                              kv_chunk)
        return out.reshape(b, s, h, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dh)


def _mlp_init(cfg: ModelConfig, key, n: int, dt):
    ks = jax.random.split(key, 3)
    p = {"wu": L.dense_init(ks[1], (n, cfg.d_model, cfg.d_ff), dt),
         "wd": L.dense_init(ks[2], (n, cfg.d_ff, cfg.d_model), dt)}
    if cfg.mlp_style == "swiglu":
        p["wg"] = L.dense_init(ks[0], (n, cfg.d_model, cfg.d_ff), dt)
    return p


def _mlp_apply(p, x):
    if "wg" in p:
        return L.swiglu(x, p["wg"], p["wu"], p["wd"])
    return (jax.nn.gelu(x @ p["wu"]) @ p["wd"])


# =====================================================================
# dense transformer block
# =====================================================================
def init_dense(cfg: ModelConfig, key, n: int):
    dt = L.dtype_of(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((n, cfg.d_model), dt),
            "ln2": jnp.ones((n, cfg.d_model), dt),
            "attn": _attn_init(cfg, k1, n, dt),
            "mlp": _mlp_init(cfg, k2, n, dt)}


def apply_dense(cfg: ModelConfig, p, meta, x, *, cache, pos, causal=True):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    window = meta.get("window", cfg.sliding_window or 0)
    attn_out, cache = _attn_apply(cfg, p["attn"], h, cache=cache, pos=pos,
                                  window=window, causal=causal)
    x = x + attn_out
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _mlp_apply(p["mlp"], h)
    return x, cache, (0.0, 0.0)


# =====================================================================
# MoE block
# =====================================================================
def init_moe(cfg: ModelConfig, key, n: int):
    dt = L.dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {"ln1": jnp.ones((n, d), dt), "ln2": jnp.ones((n, d), dt),
         "attn": _attn_init(cfg, ks[0], n, dt),
         "router": L.dense_init(ks[1], (n, d, e), jnp.float32),
         "we_gate": L.dense_init(ks[2], (n, e, d, f), dt),
         "we_up": L.dense_init(ks[3], (n, e, d, f), dt),
         "we_down": L.dense_init(ks[4], (n, e, f, d), dt)}
    if cfg.shared_expert:
        p["shared"] = _mlp_init(cfg, ks[5], n, dt)
    return p


def apply_moe(cfg: ModelConfig, p, meta, x, *, cache, pos, causal=True):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, cache = _attn_apply(cfg, p["attn"], h, cache=cache, pos=pos,
                                  window=cfg.sliding_window or 0)
    x = x + attn_out
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    moe_out, aux, z = moe_ff(h, p["router"], p["we_gate"], p["we_up"],
                             p["we_down"], top_k=cfg.top_k,
                             cap_factor=cfg.capacity_factor)
    if "shared" in p:
        moe_out = moe_out + _mlp_apply(p["shared"], h)
    x = x + moe_out
    return x, cache, (aux, z)


# =====================================================================
# mLSTM block (xLSTM) — chunked GLA core with normalizer column
# =====================================================================
def init_mlstm(cfg: ModelConfig, key, n: int):
    dt = L.dtype_of(cfg.dtype)
    di = cfg.d_inner
    dk = int(di * cfg.qk_dim_ratio)
    ks = jax.random.split(key, 6)
    return {"ln": jnp.ones((n, cfg.d_model), dt),
            "w_up": L.dense_init(ks[0], (n, cfg.d_model, 2 * di), dt),
            "conv_w": L.dense_init(ks[1], (n, cfg.conv_width, di), dt,
                                   scale=0.5),
            "wq": L.dense_init(ks[2], (n, di, dk), dt),
            "wk": L.dense_init(ks[3], (n, di, dk), dt),
            "wif": L.dense_init(ks[4], (n, di, 2 * cfg.n_heads), jnp.float32),
            "w_down": L.dense_init(ks[5], (n, di, cfg.d_model), dt),
            "ln_heads": jnp.ones((n, di), dt)}


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x (B,S,C), w (W,C). state: (B,W-1,C) history
    for decode. Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return jax.nn.silu(y), new_state


def apply_mlstm(cfg: ModelConfig, p, meta, x, *, cache, pos, causal=True):
    b, s, _ = x.shape
    h_heads = cfg.n_heads
    di = cfg.d_inner
    dk_t = p["wq"].shape[-1]
    dkh = dk_t // h_heads
    dvh = di // h_heads
    hin = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = hin @ p["w_up"]
    xi, z = jnp.split(up, 2, axis=-1)                 # (B,S,di) each
    conv_state = cache.get("conv") if cache else None
    xc, new_conv = _causal_conv(xi, p["conv_w"], conv_state)
    q = (xc @ p["wq"]).reshape(b, s, h_heads, dkh)
    k = (xc @ p["wk"]).reshape(b, s, h_heads, dkh) / (dkh ** 0.5)
    v = xi.reshape(b, s, h_heads, dvh)
    gates = xi @ p["wif"]                             # (B,S,2H) f32
    i_gate = jax.nn.sigmoid(gates[..., :h_heads])
    log_f = jax.nn.log_sigmoid(gates[..., h_heads:])
    k = k * i_gate[..., None].astype(k.dtype)
    # normalizer is a separate (B,H,DK) state (gla.py) so dv stays a power
    # of two and the value/state tensors shard over 'model'
    if cache is None:
        out, _, n_out, _ = chunked_gla(q, k, v, log_f, chunk=_pick_chunk(s),
                                       normalizer=True)
        new_state = None
    elif s == 1:
        new_state, out1, n_new, n_out = gla_step(
            cache["state"], q[:, 0], k[:, 0], v[:, 0], log_f[:, 0],
            nstate=cache["nstate"])
        out, n_out = out1[:, None], n_out[:, None]
        new_state = (new_state, n_new)
    else:  # prefill with state capture
        out, st, n_out, n_st = chunked_gla(q, k, v, log_f,
                                           chunk=_pick_chunk(s),
                                           normalizer=True)
        new_state = (st, n_st)
    hsv = out / jnp.maximum(jnp.abs(n_out), 1.0)[..., None].astype(out.dtype)
    hsv = hsv.reshape(b, s, di)
    hsv = L.rms_norm(hsv, p["ln_heads"], cfg.norm_eps) * jax.nn.silu(z)
    x = x + hsv @ p["w_down"]
    new_cache = None
    if cache is not None:
        st, n_st = new_state
        new_cache = {"state": st, "nstate": n_st, "conv": new_conv}
    return x, new_cache, (0.0, 0.0)


# =====================================================================
# sLSTM block (xLSTM) — strictly sequential scan, block-diagonal recurrence
# =====================================================================
def init_slstm(cfg: ModelConfig, key, n: int):
    dt = L.dtype_of(cfg.dtype)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {"ln": jnp.ones((n, d), dt),
            "w": L.dense_init(ks[0], (n, d, 4 * d), jnp.float32),
            "r": L.dense_init(ks[1], (n, h, dh, 4 * dh), jnp.float32),
            "b": jnp.zeros((n, 4 * d), jnp.float32),
            "w_down": L.dense_init(ks[2], (n, d, d), dt)}


def apply_slstm(cfg: ModelConfig, p, meta, x, *, cache, pos, causal=True):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    hin = L.rms_norm(x, p["ln"], cfg.norm_eps)
    pre = (hin.astype(jnp.float32) @ p["w"] + p["b"])   # (B,S,4D)
    pre = pre.reshape(b, s, h, 4 * dh)

    def step(carry, pre_t):
        h_prev, c_prev = carry                          # (B,H,dh) each
        rec = jnp.einsum("bhd,hdk->bhk", h_prev, p["r"])
        gates = pre_t + rec                             # (B,H,4dh)
        i, f, zg, o = jnp.split(gates, 4, axis=-1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
        c = f * c_prev + i * jnp.tanh(zg)
        h_new = o * jnp.tanh(c)
        return (h_new, c), h_new

    if cache is None:
        init = (jnp.zeros((b, h, dh), jnp.float32),
                jnp.zeros((b, h, dh), jnp.float32))
        (_, _), outs = jax.lax.scan(step, init, pre.transpose(1, 0, 2, 3))
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
        new_cache = None
    elif s == 1:
        (h_new, c_new), out = step((cache["h"], cache["c"]), pre[:, 0])
        out = out.reshape(b, 1, d)
        new_cache = {"h": h_new, "c": c_new}
    else:  # prefill with state capture
        (h_new, c_new), outs = jax.lax.scan(step, (cache["h"], cache["c"]),
                                            pre.transpose(1, 0, 2, 3))
        out = outs.transpose(1, 0, 2, 3).reshape(b, s, d)
        new_cache = {"h": h_new, "c": c_new}
    x = x + (out.astype(x.dtype) @ p["w_down"])
    return x, new_cache, (0.0, 0.0)


# =====================================================================
# Hymba block: parallel attention + SSD(Mamba-2 style) heads
# =====================================================================
def init_hymba(cfg: ModelConfig, key, n: int):
    dt = L.dtype_of(cfg.dtype)
    d, di, ds, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {"ln1": jnp.ones((n, d), dt), "ln2": jnp.ones((n, d), dt),
            "attn": _attn_init(cfg, ks[0], n, dt),
            "w_in": L.dense_init(ks[1], (n, d, 2 * di), dt),
            "conv_w": L.dense_init(ks[2], (n, cfg.conv_width, di), dt,
                                   scale=0.5),
            "w_bc": L.dense_init(ks[3], (n, di, 2 * h * ds), dt),
            "w_dt": L.dense_init(ks[4], (n, di, h), jnp.float32),
            "a_log": jnp.zeros((n, h), jnp.float32),
            "norm_attn": jnp.ones((n, d), dt),
            "norm_ssm": jnp.ones((n, d), dt),
            "w_o_ssm": L.dense_init(ks[5], (n, di, d), dt),
            "mlp": _mlp_init(cfg, ks[6], n, dt)}


def apply_hymba(cfg: ModelConfig, p, meta, x, *, cache, pos, causal=True):
    b, s, d = x.shape
    h = cfg.n_heads
    di, ds = cfg.d_inner, cfg.ssm_state
    dvh = di // h
    hin = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    window = meta.get("window", cfg.sliding_window or 0)

    # ---- attention path ----
    attn_cache = cache.get("attn") if cache else None
    attn_out, new_attn_cache = _attn_apply(cfg, p["attn"], hin,
                                           cache=attn_cache, pos=pos,
                                           window=window)
    # ---- SSD path ----
    up = hin @ p["w_in"]
    xs, z = jnp.split(up, 2, axis=-1)                  # (B,S,di)
    conv_state = cache.get("conv") if cache else None
    xc, new_conv = _causal_conv(xs, p["conv_w"], conv_state)
    bc = xc @ p["w_bc"]
    bmat, cmat = jnp.split(bc.reshape(b, s, h, 2 * ds), 2, axis=-1)
    dt_raw = (xc @ p["w_dt"]).astype(jnp.float32)      # (B,S,H)
    dt_pos = jax.nn.softplus(dt_raw)
    log_a = -dt_pos * jnp.exp(p["a_log"])[None, None, :]
    v = (xs.reshape(b, s, h, dvh) *
         dt_pos[..., None].astype(xs.dtype))
    if cache is None:
        ssm_out, _ = chunked_gla(cmat, bmat, v, log_a, chunk=_pick_chunk(s))
        new_state = None
    elif s == 1:
        new_state, out1 = gla_step(cache["state"], cmat[:, 0], bmat[:, 0],
                                   v[:, 0], log_a[:, 0])
        ssm_out = out1[:, None]
    else:  # prefill with state capture
        ssm_out, new_state = chunked_gla(cmat, bmat, v, log_a,
                                         chunk=_pick_chunk(s))
    ssm_out = (ssm_out.reshape(b, s, di) * jax.nn.silu(z)) @ p["w_o_ssm"]
    # ---- fuse (mean of per-path norms, Hymba §3) ----
    fused = 0.5 * (L.rms_norm(attn_out, p["norm_attn"], cfg.norm_eps) +
                   L.rms_norm(ssm_out, p["norm_ssm"], cfg.norm_eps))
    x = x + fused
    hmid = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _mlp_apply(p["mlp"], hmid)
    new_cache = None
    if cache is not None:
        new_cache = {"attn": new_attn_cache, "conv": new_conv,
                     "state": new_state}
    return x, new_cache, (0.0, 0.0)


# =====================================================================
# encoder block + enc-dec decoder block (audio)
# =====================================================================
def init_enc(cfg: ModelConfig, key, n: int):
    return init_dense(cfg, key, n)


def apply_enc(cfg: ModelConfig, p, meta, x, *, cache=None, pos=0,
              causal=False):
    return apply_dense(cfg, p, meta, x, cache=None, pos=pos, causal=False)


def init_xdec(cfg: ModelConfig, key, n: int):
    dt = L.dtype_of(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.ones((n, cfg.d_model), dt),
            "ln_x": jnp.ones((n, cfg.d_model), dt),
            "ln2": jnp.ones((n, cfg.d_model), dt),
            "attn": _attn_init(cfg, k1, n, dt),
            "xattn": _attn_init(cfg, k2, n, dt),
            "mlp": _mlp_init(cfg, k3, n, dt)}


def apply_xdec(cfg: ModelConfig, p, meta, x, *, cache, pos, causal=True,
               memory=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    self_cache = cache.get("self") if cache else None
    attn_out, new_self = _attn_apply(cfg, p["attn"], h, cache=self_cache,
                                     pos=pos, window=0)
    x = x + attn_out
    h = L.rms_norm(x, p["ln_x"], cfg.norm_eps)
    xattn_out, _ = _attn_apply(cfg, p["xattn"], h, cache=None, pos=pos,
                               window=0, causal=False, rope=False,
                               kv_src=memory)
    x = x + xattn_out
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _mlp_apply(p["mlp"], h)
    new_cache = {"self": new_self} if cache is not None else None
    return x, new_cache, (0.0, 0.0)


INIT = {"dense": init_dense, "moe": init_moe, "mlstm": init_mlstm,
        "slstm": init_slstm, "hymba": init_hymba, "xdec": init_xdec,
        "enc": init_enc}
APPLY = {"dense": apply_dense, "moe": apply_moe, "mlstm": apply_mlstm,
         "slstm": apply_slstm, "hymba": apply_hymba, "xdec": apply_xdec,
         "enc": apply_enc}
