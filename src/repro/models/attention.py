"""GQA attention with RoPE: blockwise (flash-style online softmax) training
path and KV-cache decode path.

The blockwise path scans KV chunks with a running (max, denom, acc) carry so
the (S, T) score matrix is never materialized in HBM — required for the 32k
prefill shapes and the long-context cells (DESIGN.md §5). Pure JAX (the paper
has no attention-kernel contribution; XLA handles the matmuls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _scores_mask(q_pos, k_pos, window):
    """(S, T) additive mask: causal + optional sliding window.

    ``window`` may be a static int or a traced scalar (per-layer windows in
    hybrid stacks); <= 0 means full attention.
    """
    keep = q_pos[:, None] >= k_pos[None, :]
    w = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), 1 << 30)
    keep &= (q_pos[:, None] - k_pos[None, :]) < w
    return jnp.where(keep, 0.0, NEG_INF)


def _gqa_scores(q, k):
    """q (B,S,KV,G,dh), k (B,T,KV,dh) -> (B,KV,G,S,T) f32."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k,
                      preferred_element_type=jnp.float32)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              q_offset, window: int = 0, kv_len: int | None = None,
              kv_chunk: int = 1024) -> jnp.ndarray:
    """Causal GQA attention.

    q: (B, S, H, dh); k, v: (B, T, KV, dh); q_offset: scalar — absolute
    position of q[0] (queries attend to keys at absolute positions).
    kv_len: number of valid cache entries (decode; keys beyond are masked).
    Returns (B, S, H, dh).
    """
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh) * (dh ** -0.5)
    q_pos = q_offset + jnp.arange(s)

    if s == 1 or t <= kv_chunk:
        # direct path: scores are small (decode or short context)
        scores = _gqa_scores(qg, k)                      # (B,KV,G,S,T)
        k_pos = jnp.arange(t)
        mask = _scores_mask(q_pos, k_pos, window)
        if kv_len is not None:
            mask = mask + jnp.where(k_pos[None, :] < kv_len, 0.0, NEG_INF)
        scores = scores + mask
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
        return out.reshape(b, s, h, dh)

    # flash path: O(S) memory via custom VJP (models/flash.py)
    assert t % kv_chunk == 0, f"kv len {t} % chunk {kv_chunk}"
    from repro.models.flash import flash_attention
    q_pos_f = q_pos.astype(jnp.float32)
    if kv_len is not None:
        kbias = jnp.where(jnp.arange(t) < kv_len, 0.0, NEG_INF
                          ).astype(jnp.float32)
    else:
        kbias = jnp.zeros((t,), jnp.float32)
    window_f = jnp.asarray(window, jnp.float32)
    out = flash_attention(qg, k, v, q_pos_f, kbias, window_f, kv_chunk)
    return out.reshape(b, s, h, dh)
