"""LM assembly: embedding (the token dictionary's learned ADV) -> block
stacks (lax.scan over groups) -> head -> loss / decode.

Public surface:
  init_params(cfg, key)                  real arrays (smoke tests, examples)
  param_specs(cfg)                       ShapeDtypeStructs via eval_shape
  forward(cfg, params, batch, caches)    logits, aux, new_caches
  train_loss(cfg, params, batch)         scalar loss + metrics
  init_serve_state(cfg, B, max_len, ...) zeroed caches pytree
  decode_step(cfg, params, state, tok)   one-token serve step
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.blocks import (APPLY, INIT, block_pattern, n_groups,
                                 _attn_init, _pick_chunk)
from repro.models.config import ModelConfig
from repro.distributed.context import constrain_residual

NEG_INF = -1e30


# =====================================================================
# meta (per-layer non-trained data, scanned alongside params)
# =====================================================================
def build_meta(cfg: ModelConfig) -> list[dict]:
    """One dict per pattern position; arrays have leading n_groups dim."""
    pat = block_pattern(cfg)
    g = n_groups(cfg)
    metas: list[dict] = []
    for j, kind in enumerate(pat):
        m: dict = {}
        if cfg.family == "hybrid":
            # Hymba: first / middle / last layers keep full attention
            full = {0, cfg.n_layers // 2, cfg.n_layers - 1}
            layer_ids = np.array([gi * len(pat) + j for gi in range(g)])
            window = np.where(np.isin(layer_ids, list(full)), 0,
                              cfg.sliding_window)
            m["window"] = jnp.asarray(window, jnp.int32)
        metas.append(m)
    return metas


# =====================================================================
# params
# =====================================================================
def init_params(cfg: ModelConfig, key) -> dict:
    dt = L.dtype_of(cfg.dtype)
    g = n_groups(cfg)
    pat = block_pattern(cfg)
    keys = jax.random.split(key, len(pat) + 4)
    params: dict = {
        "embed": L.embed_init(keys[0], (cfg.padded_vocab, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "blocks": [INIT[kind](cfg, keys[1 + j], g)
                   for j, kind in enumerate(pat)],
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(keys[len(pat) + 1],
                                      (cfg.d_model, cfg.padded_vocab), dt)
    if cfg.family == "vlm":
        params["vis_proj"] = L.dense_init(keys[len(pat) + 2],
                                          (cfg.frontend_dim, cfg.d_model), dt)
    if cfg.family == "audio":
        k_enc = keys[len(pat) + 2]
        params["enc_proj"] = L.dense_init(k_enc, (cfg.frontend_dim,
                                                  cfg.d_model), dt)
        params["enc_blocks"] = INIT["enc"](cfg, keys[len(pat) + 3],
                                           cfg.enc_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
    return params


def param_specs(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_count(params) -> int:
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(params))


# =====================================================================
# embedding — the ADV path (paper §6.3): token code -> learned feature row
# =====================================================================
def embed_tokens(cfg: ModelConfig, table: jnp.ndarray,
                 tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


# =====================================================================
# block stack (scan over groups)
# =====================================================================
def _group_fn(cfg: ModelConfig, pat, training: bool):
    def fn(x, group_params, group_meta, group_caches, pos, memory):
        aux_t, z_t = 0.0, 0.0
        new_caches = []
        for j, kind in enumerate(pat):
            kw = {}
            if kind == "xdec":
                kw["memory"] = memory
            x, nc, (a, z) = APPLY[kind](cfg, group_params[j], group_meta[j],
                                        x, cache=group_caches[j], pos=pos,
                                        **kw)
            new_caches.append(nc)
            aux_t = aux_t + a
            z_t = z_t + z
        return x, new_caches, aux_t, z_t
    return fn


def run_stack(cfg: ModelConfig, params_blocks, metas, x, *, caches=None,
              pos=0, memory=None, training=True, pattern=None):
    pat = pattern if pattern is not None else block_pattern(cfg)
    fn = _group_fn(cfg, pat, training)

    prefer = ("dp" if cfg.pure_dp else
              "channel" if cfg.family == "ssm" else "seq")
    x = constrain_residual(x, prefer)
    if caches is None:
        def body2(carry, xs):
            xc, aux, z = carry
            gp, gm = xs
            xc, _, a, zz = fn(xc, gp, gm, [None] * len(pat), pos, memory)
            xc = constrain_residual(xc, prefer)
            return (xc, aux + a, z + zz), None
        if cfg.remat == "layer":
            body2 = jax.checkpoint(body2)
        elif cfg.remat == "dots":
            body2 = jax.checkpoint(
                body2, policy=jax.checkpoint_policies.
                dots_with_no_batch_dims_saveable)
        (x, aux, z), _ = jax.lax.scan(body2, (x, 0.0, 0.0),
                                      (params_blocks, metas),
                                      unroll=cfg.scan_unroll)
        return x, aux, z, None

    def body3(carry, xs):
        xc, aux, z = carry
        gp, gm, gc = xs
        xc, nc, a, zz = fn(xc, gp, gm, gc, pos, memory)
        xc = constrain_residual(xc, prefer)
        return (xc, aux + a, z + zz), nc
    (x, aux, z), new_caches = jax.lax.scan(body3, (x, 0.0, 0.0),
                                           (params_blocks, metas, caches),
                                           unroll=cfg.scan_unroll)
    return x, aux, z, new_caches


# =====================================================================
# forward
# =====================================================================
def _hidden(cfg: ModelConfig, params, batch, caches):
    """Shared trunk: embeddings + frontends + block stacks + final norm.
    Returns (x_final, (aux, z), new_caches)."""
    tokens = batch["tokens"]
    pos = caches["pos"] if caches is not None else 0
    x = embed_tokens(cfg, params["embed"], tokens)

    memory = None
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pp = batch["patch_embeds"].astype(x.dtype) @ params["vis_proj"]
        x = jnp.concatenate([pp, x[:, pp.shape[1]:, :]], axis=1)
    if cfg.family == "audio":
        if caches is not None and "memory" in caches and "frames" not in batch:
            memory = caches["memory"]
        else:
            fr = batch["frames"].astype(x.dtype) @ params["enc_proj"]
            memory, _, _, _ = run_stack(
                cfg, [params["enc_blocks"]], [{}], fr, caches=None,
                pos=0, memory=None, training=caches is None,
                pattern=["enc"])
            memory = L.rms_norm(memory, params["enc_norm"], cfg.norm_eps)

    metas = build_meta(cfg)
    block_caches = caches["blocks"] if caches is not None else None
    x, aux, z, new_block_caches = run_stack(
        cfg, params["blocks"], metas, x, caches=block_caches, pos=pos,
        memory=memory, training=caches is None)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)

    new_caches = None
    if caches is not None:
        new_caches = dict(caches)
        new_caches["blocks"] = new_block_caches
        new_caches["pos"] = pos + tokens.shape[1]
        if cfg.family == "audio" and memory is not None:
            new_caches["memory"] = memory
    return x, (aux, z), new_caches


def forward_hidden(cfg: ModelConfig, params, batch):
    x, auxz, _ = _hidden(cfg, params, batch, None)
    return x, auxz


def forward(cfg: ModelConfig, params, batch, caches=None):
    """batch: dict with 'tokens' (B,S) int32; vlm: + 'patch_embeds'
    (B,P,frontend_dim); audio: + 'frames' (B,S_enc,frontend_dim).
    caches: serve-state dict or None (training).
    Returns (logits (B,S,padded_vocab), (aux, z), new_caches)."""
    x, (aux, z), new_caches = _hidden(cfg, params, batch, caches)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = _mask_pad_vocab(cfg, (x @ head).astype(jnp.float32))
    return logits, (aux, z), new_caches


# =====================================================================
# training loss
# =====================================================================
def _ce_terms(cfg: ModelConfig, logits, labels):
    """(sum of CE over valid labels, count of valid labels)."""
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = jnp.where(valid, logz - gold, 0.0)
    return ce.sum(), valid.sum()


def _mask_pad_vocab(cfg: ModelConfig, logits):
    if cfg.padded_vocab > cfg.vocab:
        vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(vmask[None, None, :], logits, NEG_INF)
    return logits


def chunked_ce(cfg: ModelConfig, x_final, head, labels, chunk: int):
    """Sequence-chunked, checkpointed CE: the (B, chunk, V) f32 logits block
    is the only logits liveness — full (B,S,V) f32 logits (the largest single
    training tensor for 150k-vocab archs) are never materialized; the
    backward pass recomputes each block's logits (jax.checkpoint)."""
    s = x_final.shape[1]
    n_chunks = s // chunk

    @jax.checkpoint
    def body(carry, idx):
        loss_sum, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x_final, idx * chunk, chunk, 1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, 1)
        logits = _mask_pad_vocab(cfg, (xs @ head).astype(jnp.float32))
        c_sum, c_cnt = _ce_terms(cfg, logits, ls)
        return (loss_sum + c_sum, cnt + c_cnt), None

    (loss_sum, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        jnp.arange(n_chunks))
    return loss_sum, cnt


def train_loss(cfg: ModelConfig, params, batch):
    """Cross-entropy over valid labels (labels < 0 are masked)."""
    x_final, (aux, z) = forward_hidden(cfg, params, batch)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    labels = batch["labels"]
    s = labels.shape[1]
    if cfg.loss_chunk and s % cfg.loss_chunk == 0 and s > cfg.loss_chunk:
        loss_sum, n_valid = chunked_ce(cfg, x_final, head, labels,
                                       cfg.loss_chunk)
    else:
        logits = _mask_pad_vocab(cfg, (x_final @ head).astype(jnp.float32))
        loss_sum, n_valid = _ce_terms(cfg, logits, labels)
    n_valid = jnp.maximum(n_valid, 1)
    loss = loss_sum / n_valid
    total = loss + cfg.router_aux_coef * aux + cfg.router_z_coef * z
    return total, {"ce": loss, "aux": aux, "z": z,
                   "tokens": n_valid}


# =====================================================================
# serving
# =====================================================================
def _zero_attn_cache(cfg, g, b, max_len, dt):
    """KV cache; 'int8' stores dictionary-quantized codes + per-(token,head)
    f32 scales — the paper's encode-small-integers idea applied to the
    serving cache (halves decode HBM; see blocks._attn_apply)."""
    hd = cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros((g, b, max_len, cfg.n_kv, hd), jnp.int8),
                "v": jnp.zeros((g, b, max_len, cfg.n_kv, hd), jnp.int8),
                "ks": jnp.zeros((g, b, max_len, cfg.n_kv), jnp.float32),
                "vs": jnp.zeros((g, b, max_len, cfg.n_kv), jnp.float32)}
    return {"k": jnp.zeros((g, b, max_len, cfg.n_kv, hd), dt),
            "v": jnp.zeros((g, b, max_len, cfg.n_kv, hd), dt)}


def init_serve_state(cfg: ModelConfig, batch_size: int, max_len: int,
                     enc_len: int = 0) -> dict:
    dt = L.dtype_of(cfg.dtype)
    g = n_groups(cfg)
    pat = block_pattern(cfg)
    b = batch_size
    di = cfg.d_inner
    caches = []
    for kind in pat:
        if kind in ("dense", "moe"):
            caches.append(_zero_attn_cache(cfg, g, b, max_len, dt))
        elif kind == "mlstm":
            dk = int(di * cfg.qk_dim_ratio) // cfg.n_heads
            dv = di // cfg.n_heads              # normalizer is separate
            caches.append({
                "state": jnp.zeros((g, b, cfg.n_heads, dk, dv), jnp.float32),
                "nstate": jnp.zeros((g, b, cfg.n_heads, dk), jnp.float32),
                "conv": jnp.zeros((g, b, cfg.conv_width - 1, di), dt)})
        elif kind == "slstm":
            dh = cfg.d_model // cfg.n_heads
            caches.append({
                "h": jnp.zeros((g, b, cfg.n_heads, dh), jnp.float32),
                "c": jnp.zeros((g, b, cfg.n_heads, dh), jnp.float32)})
        elif kind == "hymba":
            caches.append({
                "attn": _zero_attn_cache(cfg, g, b, max_len, dt),
                "conv": jnp.zeros((g, b, cfg.conv_width - 1, di), dt),
                "state": jnp.zeros((g, b, cfg.n_heads, cfg.ssm_state,
                                    di // cfg.n_heads), jnp.float32)})
        elif kind == "xdec":
            caches.append({"self": _zero_attn_cache(cfg, g, b, max_len, dt)})
        else:
            raise ValueError(kind)
    state = {"blocks": caches, "pos": jnp.asarray(0, jnp.int32)}
    if cfg.family == "audio":
        state["memory"] = jnp.zeros((b, enc_len, cfg.d_model), dt)
    return state


def prefill(cfg: ModelConfig, params, state, batch):
    logits, _, state = forward(cfg, params, batch, caches=state)
    return logits, state


def decode_step(cfg: ModelConfig, params, state, tokens):
    """tokens (B, 1) -> (logits (B,1,V), new state)."""
    logits, _, state = forward(cfg, params, {"tokens": tokens}, caches=state)
    return logits, state
