"""Wide&Deep over columnar ADV features — the paper's reference workload
(§2 cites Wide&Deep as the consumer of exactly these features).

Wide part: categorical codes -> fused one-hot linear layer (the
``onehot_wide`` kernel — one-hot never materialized). Deep part: dense ADV
feature vector (normalizations, bucketizations, embeddings gathered through
the dictionary) -> MLP. Trained end-to-end; the learned embedding tables are
written back to the dictionary as learned ADVs by the analytics cycle
(examples/analytics_cycle.py, paper §7).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class WideDeepConfig:
    wide_cards: tuple            # cardinality per wide categorical column
    deep_dim: int                # ADV feature vector width
    embed_cols: tuple = ()       # (cardinality, dim) per embedded column
    hidden: tuple = (64, 32)
    n_out: int = 1               # 1 = binary logit
    use_kernel: bool = False     # route wide part through the Pallas kernel


def init_widedeep(cfg: WideDeepConfig, key):
    kmax = max(cfg.wide_cards) if cfg.wide_cards else 1
    ks = jax.random.split(key, 4 + len(cfg.hidden))
    params = {
        # stacked wide tables (C, K_max, n_out) — padded to max cardinality
        "wide": jnp.zeros((len(cfg.wide_cards), kmax, cfg.n_out),
                          jnp.float32),
        "bias": jnp.zeros((cfg.n_out,), jnp.float32),
        "embeds": [jax.random.normal(ks[2 + i], (card, dim)) / np.sqrt(dim)
                   for i, (card, dim) in enumerate(cfg.embed_cols)],
    }
    in_dim = cfg.deep_dim + sum(d for _, d in cfg.embed_cols)
    dims = (in_dim,) + cfg.hidden + (cfg.n_out,)
    params["mlp"] = [
        {"w": jax.random.normal(ks[3 + i], (a, b)) / np.sqrt(a),
         "b": jnp.zeros((b,))}
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:]))]
    return params


def forward_widedeep(cfg: WideDeepConfig, params, wide_codes, deep_feats,
                     embed_codes=None):
    """wide_codes (C, N) int32; deep_feats (N, F); embed_codes list of (N,)."""
    if cfg.use_kernel:
        from repro.kernels.onehot_wide import onehot_wide
        wide = onehot_wide(wide_codes, params["wide"])
    else:
        from repro.kernels.onehot_wide.ref import onehot_wide_ref
        wide = onehot_wide_ref(wide_codes, params["wide"])
    h = deep_feats
    if embed_codes:
        embs = [tab[c] for tab, c in zip(params["embeds"], embed_codes)]
        h = jnp.concatenate([h] + embs, axis=-1)
    for i, layer in enumerate(params["mlp"]):
        h = h @ layer["w"] + layer["b"]
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
    return (wide + h + params["bias"])[:, 0] if cfg.n_out == 1 else wide + h


def loss_widedeep(cfg: WideDeepConfig, params, wide_codes, deep_feats,
                  labels, embed_codes=None):
    logits = forward_widedeep(cfg, params, wide_codes, deep_feats,
                              embed_codes)
    # binary cross-entropy with logits
    l = jnp.maximum(logits, 0) - logits * labels + \
        jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return l.mean()


def make_widedeep_train_step(cfg: WideDeepConfig, lr: float = 0.05):
    @jax.jit
    def step(params, wide_codes, deep_feats, labels, embed_codes):
        loss, grads = jax.value_and_grad(
            lambda p: loss_widedeep(cfg, p, wide_codes, deep_feats, labels,
                                    embed_codes))(params)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return params, loss
    return step
