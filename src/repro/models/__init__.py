"""Model substrate: composable JAX definitions for all assigned architectures.

Families: dense / moe transformers (GQA + RoPE), ssm (xLSTM), hybrid (Hymba
parallel attn+SSM heads), vlm / audio (backbone + stub frontend per brief).
All layer stacks are ``lax.scan``-over-stacked-params for compact HLO; every
model consumes dictionary-coded tokens through the ADV/embedding path
(the paper's technique as the input substrate, DESIGN.md §3).
"""
from repro.models.config import ModelConfig
from repro.models.lm import (init_params, param_specs, forward,
                             train_loss, init_serve_state, decode_step)

__all__ = ["ModelConfig", "init_params", "param_specs", "forward",
           "train_loss", "init_serve_state", "decode_step"]
