"""Token-choice top-k MoE with capacity (GShard-style einsum dispatch).

Dispatch/combine are expressed as one-hot einsums so GSPMD can shard the
(G, S, E, C) tensors over data (G) and experts (E=model axis) and insert the
canonical MoE all-to-all between the token-sharded and expert-sharded
layouts. Aux losses: load-balance (Switch) + router z-loss.

The dispatch tensor is the MoE analogue of the paper's one-hot featurization:
a categorical 'expert id' feature one-hot-encoded and immediately contracted,
never materialized in HBM longer than one layer (remat'd in backward).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def capacity(s: int, k: int, e: int, factor: float) -> int:
    return max(1, int(s * k / e * factor))


def route(router_logits: jnp.ndarray, k: int, e: int, cap: int):
    """router_logits (G,S,E) -> dispatch (G,S,E,C) bool-ish, combine (G,S,E,C),
    aux losses. Slot assignment is priority-ordered over the k choices."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)               # (G,S,k)
    # normalize the k gates (moonshot/deepseek style)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    g, s, _ = probs.shape
    counts = jnp.zeros((g, e), jnp.int32)
    dispatch = jnp.zeros((g, s, e, cap), jnp.bfloat16)
    combine = jnp.zeros((g, s, e, cap), jnp.float32)
    for slot in range(k):
        mask = jax.nn.one_hot(idx[:, :, slot], e, dtype=jnp.int32)   # (G,S,E)
        pos = jnp.cumsum(mask, axis=1) - 1 + counts[:, None, :]      # (G,S,E)
        keep = (pos < cap) & (mask > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, -1), cap,
                                dtype=jnp.bfloat16)                  # (G,S,E,C)
        dispatch = dispatch + pos_oh
        combine = combine + pos_oh.astype(jnp.float32) * \
            gate_vals[:, :, slot][:, :, None, None]
        counts = counts + mask.sum(axis=1)

    # load-balance loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                                     # (E,)
    top1 = jax.nn.one_hot(idx[:, :, 0], e, dtype=jnp.float32)
    ce = top1.mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(router_logits.astype(jnp.float32),
                                  axis=-1) ** 2)
    return dispatch, combine, aux, z


def moe_ff(x: jnp.ndarray, router_w: jnp.ndarray, w_gate: jnp.ndarray,
           w_up: jnp.ndarray, w_down: jnp.ndarray, *, top_k: int,
           cap_factor: float):
    """x (G,S,D); router_w (D,E); expert weights (E,D,F)/(E,F,D).

    Returns (out (G,S,D), aux_loss scalar)."""
    g, s, d = x.shape
    e = router_w.shape[-1]
    cap = capacity(s, top_k, e, cap_factor)
    logits = jnp.einsum("gsd,de->gse", x, router_w,
                        preferred_element_type=jnp.float32)
    dispatch, combine, aux, z = route(logits, top_k, e, cap)
    xin = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), x)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, w_gate)) * \
        jnp.einsum("egcd,edf->egcf", xin, w_up)
    eout = jnp.einsum("egcf,efd->egcd", h, w_down)
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), eout)
    return out, aux, z
