"""Feature pipeline split into a compile-time plan and a run-time executor.

The paper's device pipeline is 'codes in, features out' (§6, Fig 2): only
dictionary codes (b-bit packed) and K-row ADV tables move to the device;
row-space float features are produced on-device by the fused ADV gather and
consumed immediately — never materialized in host memory or HBM-resident
files, the data-movement/duplication win over the CSV-export workflow of
Fig 1.

Layering (this module):

- :class:`FeaturePlan` — the compile-time half. Builds the per-column fused
  K-row ADV tables, puts them on device ONCE (amortized forever), stacks the
  host code streams into a single (C, N) int32 matrix, and maintains all of
  it under streaming inserts via :meth:`FeaturePlan.refresh` (only columns
  whose AugmentedDictionary actually changed are re-put). Plans can be
  partitioned per IMCU (:meth:`FeaturePlan.imcu_shards`) so a shard touches
  only its own partition's codes.
- :class:`FeatureExecutor` — the run-time half. One jit'd gather over the
  stacked code batch per bucket shape; optional fused multi-table Pallas
  kernel (one kernel pass instead of per-column take + concatenate); a
  double-buffered :meth:`FeatureExecutor.batches` iterator that overlaps
  host code-slicing for batch i+1 with the device gather for batch i via
  ``jax.device_put`` prefetch (depth >= 2).
- :class:`FeaturePipeline` — the original facade, kept API-compatible.

Data-movement accounting is built in (``bytes_moved_*``) so benchmarks and
EXPERIMENTS.md can quantify the claim.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Iterator, Mapping

import numpy as np
import jax
import jax.numpy as jnp

from repro.columnar.bitpack import packed_nbytes
from repro.columnar.table import Table
from repro.core.adv import AugmentedDictionary
from repro.core.feature_spec import FeatureSet
from repro.kernels.adv_gather import ops as adv_ops


@dataclass
class ColumnPlan:
    """One column's compiled gather plan."""
    column: str
    adv_names: list[str]
    fused_host: np.ndarray        # (K, F_col) host copy (refresh diffing)
    fused_table: jnp.ndarray      # (K, F_col) resident on device
    bits: int
    aug_version: int              # AugmentedDictionary.version at build time

    @property
    def out_dim(self) -> int:
        return int(self.fused_table.shape[1])

    @property
    def cardinality(self) -> int:
        return int(self.fused_table.shape[0])


class FeaturePlan:
    """Compile-time artifact: device-resident ADV tables + host code matrix."""

    def __init__(self, table: Table, features: FeatureSet,
                 augmented: dict[str, AugmentedDictionary] | None = None):
        self.table = table
        self.features = features
        self.augmented = augmented if augmented is not None \
            else features.build(table)
        self.stats = {"tables_put": 0, "tables_refreshed": 0,
                      "fused_rebuilds": 0}
        self.plans: list[ColumnPlan] = []
        for column, aug in self.augmented.items():
            names = [s.adv_name for s in features.specs if s.column == column]
            self.plans.append(self._compile_column(column, aug, names))
        codes = [table[p.column].codes() for p in self.plans]
        # (C, N): one row-aligned int32 code stream per planned column —
        # a batch slice is ONE fancy-index + ONE host->device transfer
        self.codes_matrix = (np.stack(codes) if codes
                             else np.zeros((0, table.n_rows), np.int32))
        # one-slot box so IMCU shard plans share (and co-invalidate) the
        # fused super-table with their parent, like `plans` and `stats`
        self._fused_box: dict[str, adv_ops.FusedTables | None] = {"t": None}

    def _compile_column(self, column: str, aug: AugmentedDictionary,
                        names: list[str],
                        count_put: bool = True) -> ColumnPlan:
        fused_host = aug.fused_table(names)
        if count_put:
            self.stats["tables_put"] += 1
        return ColumnPlan(column=column, adv_names=names,
                          fused_host=fused_host,
                          fused_table=jnp.asarray(fused_host),
                          bits=aug.dictionary.bits, aug_version=aug.version)

    # -- shape info -------------------------------------------------------------
    @property
    def columns(self) -> list[str]:
        return [p.column for p in self.plans]

    @property
    def n_rows(self) -> int:
        return int(self.codes_matrix.shape[1])

    @property
    def out_dim(self) -> int:
        return sum(p.out_dim for p in self.plans)

    # -- fused multi-table layout (one-kernel-pass path) -------------------------
    def fused_tables(self) -> adv_ops.FusedTables:
        """Block-diagonal super-table for the fused gather-concat kernel."""
        if self._fused_box["t"] is None:
            self._fused_box["t"] = adv_ops.fuse_tables(
                [p.fused_host for p in self.plans])
            self.stats["fused_rebuilds"] += 1
        return self._fused_box["t"]

    # -- maintenance (§6.3: streaming inserts) -----------------------------------
    def refresh(self, new_codes: Mapping[str, np.ndarray] | None = None) -> int:
        """Incremental plan refresh after ``Dictionary.add_rows``.

        Re-derives ADVs for grown dictionaries (``extend_for_new_codes``) and
        re-puts device tables ONLY for columns whose AugmentedDictionary
        changed since compile — untouched columns keep their resident tables.
        ``new_codes`` optionally appends freshly inserted rows (codes from
        ``add_rows``) to the plan's code matrix; it must cover every planned
        column with equal lengths. Returns the number of columns refreshed.
        """
        fresh = None
        if new_codes is not None:          # validate BEFORE mutating anything
            missing = [c for c in self.columns if c not in new_codes]
            if missing:
                raise KeyError(f"new_codes missing columns {missing}")
            fresh = np.stack([np.asarray(new_codes[c], np.int32).reshape(-1)
                              for c in self.columns])
        refreshed = 0
        for i, p in enumerate(self.plans):
            aug = self.augmented[p.column]
            aug.extend_for_new_codes()
            if aug.version == p.aug_version:
                continue
            self.plans[i] = self._compile_column(p.column, aug, p.adv_names,
                                                 count_put=False)
            self.stats["tables_refreshed"] += 1
            refreshed += 1
        if refreshed:
            self._fused_box["t"] = None    # all shard views rebuild lazily
        if fresh is not None:
            self.codes_matrix = np.concatenate(
                [self.codes_matrix, fresh], axis=1)
        return refreshed

    # -- partitioning (per-IMCU shard plans) --------------------------------------
    def imcu_shards(self) -> list["FeaturePlan"]:
        """One plan per IMCU partition, sharing this plan's device tables.

        Shard k's code matrix is a zero-copy view into this plan's already
        materialized matrix, windowed to the IMCU's row range.
        Device-resident ADV tables (and the fused super-table) are shared
        and co-invalidated, not re-put.
        """
        shards = []
        for start, stop in self.imcu_bounds():
            shard = FeaturePlan.__new__(FeaturePlan)
            shard.table = self.table
            shard.features = self.features
            shard.augmented = self.augmented
            shard.stats = self.stats               # shared accounting
            shard.plans = self.plans               # shared device tables
            shard.codes_matrix = self.codes_matrix[:, start:stop]
            shard._fused_box = self._fused_box      # shared, co-invalidated
            shards.append(shard)
        return shards

    def imcu_bounds(self) -> list[tuple[int, int]]:
        if not self.plans:
            raise ValueError("plan has no feature columns to partition")
        return self.table[self.plans[0].column].imcu_bounds()

    # -- data-movement accounting (paper's central claim) --------------------------
    def bytes_moved_adv(self, batch_rows: int) -> int:
        """Host->device bytes on the ADV path: packed codes + amortized-0 tables.

        Code stream is the only per-batch traffic; the K-row fused tables are
        resident (moved once, amortized across all batches), matching the
        paper's 'dictionary created once ... easily amortized'.
        """
        return sum(packed_nbytes(batch_rows, p.bits) for p in self.plans)

    def bytes_moved_recompute(self, batch_rows: int) -> int:
        """Traditional path ships row-space f32 features."""
        return 4 * batch_rows * self.out_dim

    def bytes_resident_tables(self) -> int:
        return sum(int(p.fused_table.size) * 4 for p in self.plans)


class FeatureExecutor:
    """Run-time half: jit'd stacked gather + double-buffered batch iterator.

    ADV tables enter the jit'd gathers as *arguments*, not trace-time
    constants, so a :meth:`FeaturePlan.refresh` flows into already-compiled
    batch shapes automatically (only a table *shape* change retraces).
    """

    def __init__(self, plan: FeaturePlan, use_kernel: bool = False,
                 prefetch: int = 2):
        if prefetch < 1:
            raise ValueError("prefetch depth must be >= 1")
        self.plan = plan
        self.use_kernel = use_kernel
        self.prefetch = prefetch
        self._jit_take = jax.jit(self._take_impl)
        self._jit_fused = jax.jit(self._fused_impl,
                                  static_argnames=("out_dim", "bn", "bk"))
        if self.kernel_active:
            plan.fused_tables()        # build eagerly, not inside the jit trace

    @property
    def kernel_active(self) -> bool:
        """Fused one-hot kernel path, guarded like the single-table op: huge-K
        plans fall back to the XLA gather (one-hot tiling is wasteful there)."""
        return self.use_kernel and (
            sum(p.cardinality for p in self.plan.plans)
            <= adv_ops.MAX_ONEHOT_K)

    def _take_impl(self, codes: jnp.ndarray, tables) -> jnp.ndarray:
        # mode="clip" matches the fused kernel's OOB clamp (jax's default
        # would NaN-fill, and the two paths must agree)
        outs = [jnp.take(t, codes[i], axis=0, mode="clip")
                for i, t in enumerate(tables)]
        return jnp.concatenate(outs, axis=-1)

    def _fused_impl(self, codes: jnp.ndarray, table: jnp.ndarray,
                    row_offsets: jnp.ndarray, card_limits: jnp.ndarray,
                    out_dim: int, bn: int, bk: int) -> jnp.ndarray:
        # fused multi-table Pallas kernel: ONE pass over the code matrix
        return adv_ops.gather_fused_parts(table, row_offsets, codes, out_dim,
                                          card_limits=card_limits,
                                          bn=bn, bk=bk)

    def gather_device(self, dev_codes: jnp.ndarray) -> jnp.ndarray:
        """(C, B) stacked device codes -> (B, out_dim) concatenated features."""
        if self.kernel_active:
            fused = self.plan.fused_tables()
            return self._jit_fused(dev_codes, fused.table, fused.row_offsets,
                                   fused.card_limits, out_dim=fused.out_dim,
                                   bn=fused.bn, bk=fused.bk)
        return self._jit_take(dev_codes,
                              tuple(p.fused_table for p in self.plan.plans))

    # -- single batch -------------------------------------------------------------
    def slice_codes(self, row_idx: np.ndarray) -> np.ndarray:
        """Host-side work for one batch: one fancy-index on the code matrix."""
        return self.plan.codes_matrix[:, row_idx]

    def batch(self, row_idx: np.ndarray) -> jnp.ndarray:
        """Featurize the given rows: ship int32 codes, gather ADVs on device."""
        return self.gather_device(jax.device_put(self.slice_codes(row_idx)))

    # -- double-buffered iteration --------------------------------------------------
    def batches(self, batch_size: int, seed: int = 0,
                epochs: int = 1) -> Iterator[tuple[np.ndarray, jnp.ndarray]]:
        """Shuffled minibatch iterator with ``prefetch``-deep async pipeline.

        Up to ``prefetch`` device gathers are kept in flight: the host slices
        and ``device_put``s the codes for batch i+1 (i+2, ...) while the
        device still works on batch i, so consumers that block on each result
        hide the host-side slicing and transfer latency.
        """
        rng = np.random.default_rng(seed)
        n = self.plan.n_rows

        def indices():
            for _ in range(epochs):
                perm = rng.permutation(n)
                for start in range(0, n - batch_size + 1, batch_size):
                    yield perm[start:start + batch_size]

        inflight: deque[tuple[np.ndarray, jnp.ndarray]] = deque()
        for idx in indices():
            dev_codes = jax.device_put(self.slice_codes(idx))
            inflight.append((idx, self.gather_device(dev_codes)))
            if len(inflight) >= self.prefetch:
                yield inflight.popleft()
        while inflight:
            yield inflight.popleft()


class FeaturePipeline:
    """Facade over (FeaturePlan, FeatureExecutor) — the original seed API."""

    def __init__(self, table: Table, features: FeatureSet,
                 use_kernel: bool = False, prefetch: int = 2):
        self.table = table
        self.features = features
        self.plan = FeaturePlan(table, features)
        self.executor = FeatureExecutor(self.plan, use_kernel=use_kernel,
                                        prefetch=prefetch)
        self.augmented = self.plan.augmented
        self.use_kernel = use_kernel

    @property
    def out_dim(self) -> int:
        return self.plan.out_dim

    # -- device path ---------------------------------------------------------------
    def batch(self, row_idx: np.ndarray) -> jnp.ndarray:
        return self.executor.batch(row_idx)

    def batches(self, batch_size: int, seed: int = 0, epochs: int = 1):
        yield from self.executor.batches(batch_size, seed=seed, epochs=epochs)

    # -- host baseline (Fig 1 traditional path) -------------------------------------
    def batch_recompute(self, row_idx: np.ndarray) -> np.ndarray:
        """Decode values + row-space transform + ship f32 — the CSV workflow."""
        outs = []
        for i, p in enumerate(self.plan.plans):
            aug = self.augmented[p.column]
            codes = self.plan.codes_matrix[i, row_idx]
            for name in p.adv_names:
                outs.append(aug.featurize_recompute(name, codes))
        return np.concatenate(outs, axis=1)

    # -- data-movement accounting ----------------------------------------------------
    def bytes_moved_adv(self, batch_rows: int) -> int:
        return self.plan.bytes_moved_adv(batch_rows)

    def bytes_moved_recompute(self, batch_rows: int) -> int:
        return self.plan.bytes_moved_recompute(batch_rows)

    def bytes_resident_tables(self) -> int:
        return self.plan.bytes_resident_tables()
