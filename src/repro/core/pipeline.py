"""Feature pipeline split into a compile-time plan and a run-time executor.

The paper's device pipeline is 'codes in, features out' (§6, Fig 2): only
dictionary codes (b-bit packed) and K-row ADV tables move to the device;
row-space float features are produced on-device by the fused ADV gather and
consumed immediately — never materialized in host memory or HBM-resident
files, the data-movement/duplication win over the CSV-export workflow of
Fig 1.

Layering (this module):

- :class:`FeaturePlan` — the compile-time half. Builds the per-column fused
  K-row ADV tables, puts them on device ONCE (amortized forever), and lays
  out the host code streams in one of two forms:

  * ``packed=False`` — a single (C, N) int32 matrix; a batch slice is ONE
    fancy-index + ONE host->device transfer.
  * ``packed=True``  — the packed fast path: per-column uint32 word streams
    repacked once at ``tpu_width(bits)`` (straight from the Column/IMCU
    device views), sliced per batch on word boundaries. int32 code streams
    never exist — neither in host RAM nor on the wire.

  Both layouts are maintained under streaming inserts via
  :meth:`FeaturePlan.refresh` (only columns whose AugmentedDictionary
  actually changed are re-put; packed streams are repacked in place only
  when a dictionary grows across a tpu_width boundary). Plans can be
  partitioned per IMCU (:meth:`FeaturePlan.imcu_shards`) so a shard touches
  only its own partition's codes.
- :class:`FeatureExecutor` — the run-time half. One jit'd gather over the
  stacked code batch per bucket shape; optional fused multi-table Pallas
  kernel (one kernel pass instead of per-column take + concatenate); a
  double-buffered :meth:`FeatureExecutor.batches` iterator that overlaps
  host code-slicing for batch i+1 with the device gather for batch i via
  ``jax.device_put`` prefetch (depth >= 2). In packed mode the word streams
  are kept DEVICE-resident (they are 32/bits x smaller than the int32
  matrix they replace), so a word-aligned range batch moves nothing but a
  start index — the fused ``adv_gather_packed`` kernel (or its split XLA
  fallback past the VMEM budget) unpacks in-register and gathers in one
  pass.
- :class:`ShardedFeatureExecutor` — the mesh half. ``imcu_shards()`` of a
  packed plan yields per-IMCU word-stream SLICES (zero-copy at word-aligned
  boundaries, seam repack otherwise); each slice is committed to its own
  serve-mesh device with replicated ADV tables, and arbitrary-row requests
  are routed to the shard that owns them — featurization compute moves to
  the columnar data, never shard bytes to one compute device.
- :class:`FeaturePipeline` — the original facade, kept API-compatible.

Data-movement accounting is built in (``bytes_moved_*``) so benchmarks and
EXPERIMENTS.md can quantify the claim. Host->device bytes per batch row, by
path (b = dictionary bits, db = tpu_width(b) <= 2b, F = feature dim):

    ========================  =================================  ==========
    path                      bytes/row                          example*
    ========================  =================================  ==========
    recompute (Fig 1 CSV)     4 x F                              232
    int32 codes (packed=0)    4 x C                              16
    packed words (packed=1)   sum_c db_c / 8                     3.25
    packed + device-resident  ~0 (words moved once, amortized)   ~0
    ========================  =================================  ==========

    *4-column mixed-cardinality serve workload (db = 8,8,8,2; F = 58).
"""
from __future__ import annotations

import bisect
import functools
import itertools
from collections import deque
from dataclasses import dataclass
from typing import Iterator, Mapping

import numpy as np
import jax
import jax.numpy as jnp

from repro.columnar.bitpack import (pack_bits, packed_gather, packed_nbytes,
                                    unpack_bits)
from repro.columnar.rle import rle_decode, rle_encode, rle_nbytes
from repro.columnar import query as colquery
from repro.columnar.table import Table
from repro.core.adv import AugmentedDictionary
from repro.core.feature_spec import FeatureSet
from repro.kernels.adv_gather import ops as adv_ops
from repro.kernels.bitunpack.kernel import tpu_width
from repro.kernels.predicate_scan import ops as scan_ops


def _pad32(n: int) -> int:
    """Round up to the word-alignment quantum: a row index that is a
    multiple of 32 is word-aligned at EVERY divisor width (32/db | 32)."""
    return ((max(n, 1) + 31) // 32) * 32


def _agg_from_counts(d, counts: np.ndarray, agg: str) -> float:
    """Dict-aware aggregate tail: a masked per-code histogram + the K
    dictionary values give count/sum/mean without touching any row."""
    counts = np.asarray(counts, np.float64)
    n = float(counts.sum())
    if agg == "count":
        return n
    if not d.is_numeric():
        raise TypeError(f"{agg} requires a numeric dictionary "
                        f"(column {d.name!r} is {d.values.dtype})")
    s = float(np.dot(d.values.astype(np.float64), counts))
    if agg == "sum":
        return s
    if agg == "mean":
        return s / n if n else float("nan")
    raise ValueError(f"unknown agg {agg!r}")


def pad_rows_edge(rows: np.ndarray, to: int) -> np.ndarray:
    """Right-pad a row-index vector to a static shape by repeating the last
    row — always a valid index; callers slice the padded outputs off. The
    ONE encoding of the pad-to-static-bucket contract on the host side."""
    pad = to - rows.shape[0]
    if pad <= 0:
        return rows
    return np.concatenate([rows, np.full(pad, rows[-1], dtype=rows.dtype)])


def _slice_words(flat: jnp.ndarray, off: int, start, batch: int, db: int):
    """Device-side window into the flat resident stream: the batch's words
    for the column whose stream begins at ``off`` (start % 32 == 0,
    batch % 32 == 0, so the division is exact at any divisor width)."""
    s = 32 // db
    return jax.lax.dynamic_slice(flat, (off + start // s,), (batch // s,))


def _multi_windows(flat: jnp.ndarray, off: int, starts, batch: int, db: int):
    """K stacked word windows flattened into one (K * batch/s,) stream —
    windows are word-aligned, so concatenation preserves code order."""
    s = 32 // db
    return jax.vmap(
        lambda st: jax.lax.dynamic_slice(flat, (off + st // s,),
                                         (batch // s,)))(starts).reshape(-1)


@functools.partial(jax.jit, static_argnames=("dbs", "offs", "batch"))
def _packed_split_range(flat, tables, start, *, dbs, offs, batch):
    """Packed range batch, split path: per-column device unpack + gather."""
    wins = [_slice_words(flat, off, start, batch, db)
            for off, db in zip(offs, dbs)]
    return adv_ops.adv_gather_packed_split(wins, dbs, tables, batch)


@functools.partial(jax.jit, static_argnames=("dbs", "offs", "batch",
                                             "out_dim", "bn", "bk", "bw"))
def _packed_fused_range(flat, table, row_offsets, card_limits, start, *,
                        dbs, offs, batch, out_dim, bn, bk, bw):
    """Packed range batch through the fused one-pass Pallas kernel."""
    wins = [_slice_words(flat, off, start, batch, db)
            for off, db in zip(offs, dbs)]
    return adv_ops.adv_gather_packed(wins, dbs, table, row_offsets,
                                     card_limits, batch, out_dim,
                                     bn=bn, bk=bk, bw=bw)


@functools.partial(jax.jit, static_argnames=("dbs", "offs", "batch"))
def _packed_split_multi(flat, tables, starts, *, dbs, offs, batch):
    """K coalesced range batches in ONE launch -> (K, batch, out_dim).

    Amortizes per-launch overhead (dispatch + per-op fixed cost) across K
    batches — the serving pump's answer to many small range requests.
    """
    k = starts.shape[0]
    wins = [_multi_windows(flat, off, starts, batch, db)
            for off, db in zip(offs, dbs)]
    out = adv_ops.adv_gather_packed_split(wins, dbs, tables, k * batch)
    return out.reshape(k, batch, -1)


@functools.partial(jax.jit, static_argnames=("dbs", "offs", "batch",
                                             "out_dim", "bn", "bk", "bw"))
def _packed_fused_multi(flat, table, row_offsets, card_limits, starts, *,
                        dbs, offs, batch, out_dim, bn, bk, bw):
    """K coalesced range batches through the fused Pallas kernel."""
    k = starts.shape[0]
    wins = [_multi_windows(flat, off, starts, batch, db)
            for off, db in zip(offs, dbs)]
    out = adv_ops.adv_gather_packed(wins, dbs, table, row_offsets,
                                    card_limits, k * batch, out_dim,
                                    bn=bn, bk=bk, bw=bw)
    return out.reshape(k, batch, out_dim)


@functools.partial(jax.jit, static_argnames=("dbs", "word_offs"))
def _packed_split_rows(flat_words, tables, rows, *, dbs, word_offs):
    """Arbitrary-row indexed gather, split path: one coalesced word gather
    + broadcast field extract + per-table gathers. Index-only host->device
    traffic — the device computes word index + bit offset itself."""
    return adv_ops.adv_gather_packed_rows_split(flat_words, word_offs, dbs,
                                                tables, rows)


@functools.partial(jax.jit, static_argnames=("dbs", "word_offs", "out_dim",
                                             "bn", "bk"))
def _packed_fused_rows(flat_words, table, row_offsets, card_limits, rows, *,
                       dbs, word_offs, out_dim, bn, bk):
    """Arbitrary-row indexed gather through the fused one-pass Pallas
    kernel: unpack -> clamp -> multi-hot gather against resident words."""
    return adv_ops.adv_gather_packed_rows(flat_words, word_offs, dbs, table,
                                          row_offsets, card_limits, rows,
                                          out_dim, bn=bn, bk=bk)


@functools.partial(jax.jit, static_argnames=("dbs", "word_offs", "cap"))
def _packed_split_where(flat_words, tables, mask, *, dbs, word_offs, cap):
    """Selection-mask -> (rows, features) in ONE launch: the bitmap
    compaction and the indexed gather fuse into a single jit, so the
    compacted index vector never surfaces as a separate dispatch on the
    filtered-serving hot path (each dependent eager step costs a dispatch
    + device round trip)."""
    rows = scan_ops.compact_rows(mask, cap)
    return rows, adv_ops.adv_gather_packed_rows_split(flat_words, word_offs,
                                                      dbs, tables, rows)


class _ShardStats(dict):
    """Per-shard stats that roll every numeric delta up into the parent.

    ``imcu_shards()`` used to hand every shard the PARENT's dict, so
    per-shard ``words_put``/``tables_put`` counts were unattributable. Each
    shard now owns one of these: ``shard.stats['words_put'] += 1`` bumps the
    shard-local counter AND forwards the delta to the plan total, so the
    parent's numbers keep meaning 'whole plan' while each shard's dict
    answers 'who did it'.
    """

    def __init__(self, parent: dict, init: Mapping | None = None):
        super().__init__(init or {})
        self._parent = parent

    def __setitem__(self, key, value):
        old = self.get(key, 0)
        if isinstance(value, (int, float)) and isinstance(old, (int, float)):
            self._parent[key] = self._parent.get(key, 0) + (value - old)
        super().__setitem__(key, value)


@dataclass
class ColumnPlan:
    """One column's compiled gather plan."""
    column: str
    adv_names: list[str]
    fused_host: np.ndarray        # (K, F_col) host copy (refresh diffing)
    fused_table: jnp.ndarray      # (K, F_col) resident on device
    bits: int
    aug_version: int              # AugmentedDictionary.version at build time

    @property
    def out_dim(self) -> int:
        return int(self.fused_table.shape[1])

    @property
    def cardinality(self) -> int:
        return int(self.fused_table.shape[0])


class FeaturePlan:
    """Compile-time artifact: device-resident ADV tables + host code layout."""

    def __init__(self, table: Table, features: FeatureSet,
                 augmented: dict[str, AugmentedDictionary] | None = None,
                 packed: bool = False):
        self.table = table
        self.features = features
        self.augmented = augmented if augmented is not None \
            else features.build(table)
        self.packed = packed
        self.stats = {"tables_put": 0, "tables_refreshed": 0,
                      "fused_rebuilds": 0, "words_repacked": 0,
                      "words_put": 0, "rle_encoded": 0, "rehydrated": 0}
        self.plans: list[ColumnPlan] = []
        for column, aug in self.augmented.items():
            names = [s.adv_name for s in features.specs if s.column == column]
            self.plans.append(self._compile_column(column, aug, names))
        if packed:
            # packed fast path: per-column device-width word streams from the
            # Column/IMCU device views — the (C, N) int32 matrix never exists
            self._codes_matrix = None
            self._n_rows = table.n_rows
            self.packed_words: list[np.ndarray] = []
            self.device_bits: list[int] = []
            # packed_versions bumps on ANY stream change (repack or append);
            # packed_layout_versions bumps ONLY on a width-boundary repack.
            # Interior IMCU shards key their slices on the layout version —
            # a streaming append rewrites the tail, so only the open-ended
            # LAST shard (and the parent) must re-sync for it
            self.packed_versions: list[int] = []
            self.packed_layout_versions: list[int] = []
            for p in self.plans:
                words, db = table[p.column].device_words()
                self.packed_words.append(words)
                self.device_bits.append(db)
                self.packed_versions.append(0)
                self.packed_layout_versions.append(0)
        else:
            codes = [table[p.column].codes() for p in self.plans]
            # (C, N): one row-aligned int32 code stream per planned column —
            # a batch slice is ONE fancy-index + ONE host->device transfer
            self._codes_matrix = (np.stack(codes) if codes
                                  else np.zeros((0, table.n_rows), np.int32))
        # one-slot box so IMCU shard plans share (and co-invalidate) the
        # fused super-table with their parent, like `plans` and `stats`
        self._fused_box: dict[str, adv_ops.FusedTables | None] = {"t": None}

    def _compile_column(self, column: str, aug: AugmentedDictionary,
                        names: list[str],
                        count_put: bool = True) -> ColumnPlan:
        fused_host = aug.fused_table(names)
        if count_put:
            self.stats["tables_put"] += 1
        return ColumnPlan(column=column, adv_names=names,
                          fused_host=fused_host,
                          fused_table=jnp.asarray(fused_host),
                          bits=aug.dictionary.bits, aug_version=aug.version)

    # -- shape info -------------------------------------------------------------
    @property
    def columns(self) -> list[str]:
        return [p.column for p in self.plans]

    @property
    def codes_matrix(self) -> np.ndarray:
        if self.packed:
            raise RuntimeError(
                "packed plan never materializes the int32 code matrix — "
                "use packed_words / host_codes()")
        return self._codes_matrix

    @property
    def n_rows(self) -> int:
        return self._n_rows if self.packed else int(self._codes_matrix.shape[1])

    @property
    def out_dim(self) -> int:
        return sum(p.out_dim for p in self.plans)

    # -- host-side code access ---------------------------------------------------
    def host_codes(self, rows: np.ndarray) -> np.ndarray:
        """(C, len(rows)) int32 codes for arbitrary rows.

        int32 plans: one fancy-index on the stacked matrix. Packed plans:
        per-column word gather — touches O(len(rows)) uint32 words and never
        unpacks the stream (the only int32 ever built is the batch itself,
        for consumers that need arbitrary-row access: recompute baselines
        and non-range service requests).
        """
        if not self.packed:
            return self._codes_matrix[:, rows]
        rows = np.asarray(rows)
        out = np.empty((len(self.plans), rows.shape[0]), np.int32)
        for i, (w, db) in enumerate(zip(self.packed_words, self.device_bits)):
            out[i] = packed_gather(w, db, rows)
        return out

    def host_features(self, rows: np.ndarray) -> np.ndarray:
        """(len(rows), F) features computed ENTIRELY on the host — the
        degraded-mode slow path for rows whose resident device stream is
        gone (device loss before the emergency rebuild lands). Gathers
        codes from the host packed words and indexes the host fused ADV
        tables with the same OOB clamp as the device paths
        (``mode="clip"``), so results stay bit-exact with a device launch
        over the same plan state."""
        rows = np.asarray(rows)
        if not self.plans:
            return np.zeros((rows.shape[0], 0), np.float32)
        codes = self.host_codes(rows)
        outs = [p.fused_host[np.clip(codes[i], 0,
                                     p.fused_host.shape[0] - 1)]
                for i, p in enumerate(self.plans)]
        return np.concatenate(outs, axis=-1)

    # -- fused multi-table layout (one-kernel-pass path) -------------------------
    def fused_tables(self) -> adv_ops.FusedTables:
        """Block-diagonal super-table for the fused gather-concat kernel."""
        if self._fused_box["t"] is None:
            self._fused_box["t"] = adv_ops.fuse_tables(
                [p.fused_host for p in self.plans])
            self.stats["fused_rebuilds"] += 1
        return self._fused_box["t"]

    # -- maintenance (§6.3: streaming inserts) -----------------------------------
    def refresh(self, new_codes: Mapping[str, np.ndarray] | None = None) -> int:
        """Incremental plan refresh after ``Dictionary.add_rows``.

        Re-derives ADVs for grown dictionaries (``extend_for_new_codes``) and
        re-puts device tables ONLY for columns whose AugmentedDictionary
        changed since compile — untouched columns keep their resident tables.
        ``new_codes`` optionally appends freshly inserted rows (codes from
        ``add_rows``) to the plan's code layout; it must cover every planned
        column with equal lengths. Packed plans repack a column's word
        stream only when its dictionary grew across a tpu_width boundary,
        and append new rows by rewriting at most one partial tail word.
        Returns the number of columns refreshed.
        """
        fresh = None
        if new_codes is not None:          # validate BEFORE mutating anything
            missing = [c for c in self.columns if c not in new_codes]
            if missing:
                raise KeyError(f"new_codes missing columns {missing}")
            fresh = np.stack([np.asarray(new_codes[c], np.int32).reshape(-1)
                              for c in self.columns])
        refreshed = 0
        for i, p in enumerate(self.plans):
            aug = self.augmented[p.column]
            aug.extend_for_new_codes()
            if aug.version == p.aug_version:
                continue
            self.plans[i] = self._compile_column(p.column, aug, p.adv_names,
                                                 count_put=False)
            self.stats["tables_refreshed"] += 1
            refreshed += 1
        if refreshed:
            self._fused_box["t"] = None    # all shard views rebuild lazily
        if self.packed:
            for i, p in enumerate(self.plans):
                db = tpu_width(p.bits)
                if db != self.device_bits[i]:   # grew across a width boundary
                    codes = unpack_bits(self.packed_words[i],
                                        self.device_bits[i], self._n_rows)
                    self.packed_words[i] = pack_bits(codes, db)
                    self.device_bits[i] = db
                    self.packed_versions[i] += 1
                    self.packed_layout_versions[i] += 1
                    self.stats["words_repacked"] += 1
            if fresh is not None:
                for i in range(len(self.plans)):
                    self._append_packed(i, fresh[i])
                self._n_rows += fresh.shape[1]
        elif fresh is not None:
            self._codes_matrix = np.concatenate(
                [self._codes_matrix, fresh], axis=1)
        return refreshed

    def _append_packed(self, i: int, codes: np.ndarray) -> None:
        """Append rows to column i's word stream, rewriting at most the one
        partial tail word (fields at divisor widths never straddle words)."""
        db = self.device_bits[i]
        s = 32 // db
        words = self.packed_words[i]
        tail = self._n_rows % s
        if tail:
            codes = np.concatenate([unpack_bits(words[-1:], db, tail), codes])
            words = words[:-1]
        self.packed_words[i] = np.concatenate([words, pack_bits(codes, db)])
        self.packed_versions[i] += 1

    # -- partitioning (per-IMCU shard plans) --------------------------------------
    def imcu_shards(self) -> list["FeaturePlan"]:
        """One plan per IMCU partition, sharing this plan's device tables.

        int32 plans: shard k's code matrix is a zero-copy view into this
        plan's already materialized matrix, windowed to the IMCU's row
        range. Packed plans: shard k carries its own per-column word-stream
        slice (:class:`_PackedShardPlan`) — zero-copy when the IMCU boundary
        is word-aligned at the column's device width, repacked once per
        refresh generation only at unaligned seams — so a sharded executor
        can keep each slice resident on its own mesh device. The LAST shard
        is open-ended: rows appended by :meth:`refresh` extend it. Either
        way device-resident ADV tables (and the fused super-table) are
        shared and co-invalidated, not re-put, and every shard gets its own
        stats dict whose counts roll up into this plan's totals
        (``stats['per_shard']`` indexes them).
        """
        bounds = self.imcu_bounds()
        shard_stats = [
            _ShardStats(self.stats, {k: 0 for k, v in self.stats.items()
                                     if isinstance(v, (int, float))})
            for _ in bounds]
        self.stats["per_shard"] = shard_stats
        if self.packed:
            return [_PackedShardPlan(self, start, stop, st,
                                     last=(i == len(bounds) - 1))
                    for i, ((start, stop), st) in
                    enumerate(zip(bounds, shard_stats))]
        shards = []
        for (start, stop), st in zip(bounds, shard_stats):
            shard = FeaturePlan.__new__(FeaturePlan)
            shard.table = self.table
            shard.features = self.features
            shard.augmented = self.augmented
            shard.packed = False
            shard.stats = st                       # rolls up into self.stats
            shard.plans = self.plans               # shared device tables
            shard._codes_matrix = self._codes_matrix[:, start:stop]
            shard._fused_box = self._fused_box      # shared, co-invalidated
            shards.append(shard)
        return shards

    def imcu_bounds(self) -> list[tuple[int, int]]:
        if not self.plans:
            raise ValueError("plan has no feature columns to partition")
        return self.table[self.plans[0].column].imcu_bounds()

    # -- adaptive re-shard (tail split under streaming growth) --------------------
    def split_tail_shard(self, tail: "_PackedShardPlan", cut: int,
                         close: bool = True) -> "_PackedShardPlan":
        """Split the open tail shard at parent row ``cut``; return the NEW
        open tail shard covering [cut, n_rows).

        The answer to unbounded streaming growth: appends extend the LAST
        shard only, so once it outgrows its row budget the tail is split —
        the new shard's stream slice is zero-copy when ``cut`` is
        word-aligned at a column's device width (``cut % 32 == 0`` aligns
        at EVERY width) and seam-repacked otherwise, exactly like compile-
        time IMCU boundaries. The new shard gets a fresh rolled-up stats
        dict APPENDED to ``stats['per_shard']`` (existing shard indices —
        and their accumulated deltas — never move: continuity across
        shard-set changes). ``close=False`` leaves the old tail open so a
        caller can swap its routing table first and close after
        (:meth:`_PackedShardPlan.close_at`); until then both views serve
        [cut, n_rows) bit-identically from the same parent bytes.
        """
        if not self.packed:
            raise RuntimeError("tail re-shard applies to packed plans only")
        if not isinstance(tail, _PackedShardPlan) or tail._parent is not self:
            raise ValueError("tail is not a shard view of this plan")
        if not tail._last:
            raise ValueError("only the open tail shard can split")
        start, stop = tail.shard_bounds
        if not start < cut <= stop:
            raise ValueError(f"cut {cut} outside open tail ({start}, {stop}]")
        st = _ShardStats(self.stats,
                         {k: 0 for k, v in self.stats.items()
                          if isinstance(v, (int, float))})
        new = _PackedShardPlan(self, cut, stop, st, last=True)
        self.stats.setdefault("per_shard", []).append(st)
        if close:
            tail.close_at(cut)
        return new

    # -- data-movement accounting (paper's central claim) --------------------------
    def bytes_moved_adv(self, batch_rows: int) -> int:
        """Host->device bytes per batch on the ADV path, for THIS plan's
        layout: device-width packed words (``packed=True``) vs 4-byte int32
        codes. The K-row fused tables are resident either way (moved once,
        amortized across all batches, the paper's 'dictionary created once
        ... easily amortized') — and a packed executor additionally keeps
        the word streams device-resident, so range serving amortizes even
        the code traffic to ~0.
        """
        if self.packed:
            return sum(packed_nbytes(batch_rows, db)
                       for db in self.device_bits)
        return 4 * batch_rows * len(self.plans)

    def bytes_moved_recompute(self, batch_rows: int) -> int:
        """Traditional path ships row-space f32 features."""
        return 4 * batch_rows * self.out_dim

    def bytes_resident_tables(self) -> int:
        return sum(int(p.fused_table.size) * 4 for p in self.plans)

    def bytes_resident_codes(self) -> int:
        """Host bytes held by the code layout (the duplication the packed
        path avoids: 32/db x smaller than the int32 matrix)."""
        if self.packed:
            return sum(int(w.nbytes) for w in self.packed_words)
        return int(self._codes_matrix.nbytes)


class _PackedShardPlan(FeaturePlan):
    """One IMCU partition of a packed plan: a per-column word-stream slice.

    Shares the parent's AugmentedDictionaries, device-resident ADV tables
    and fused super-table box (co-invalidated on refresh); what is
    partitioned is exactly the resident word streams. A shard's slice of
    column i is zero-copy when the partition boundary is word-aligned at
    the column's device width (``start % (32/db) == 0`` — always true for
    the default 2**19-row IMCUs); an unaligned seam repacks JUST this
    shard's rows, once per parent refresh generation (cached against
    ``packed_versions[i]``). ``last=True`` marks the open-ended tail shard:
    rows appended by the PARENT's :meth:`FeaturePlan.refresh` extend it, so
    a sharded service keeps serving streaming inserts without resharding.
    Refresh always goes through the parent — the word streams, dictionaries
    and versions live there.
    """

    def __init__(self, parent: FeaturePlan, start: int, stop: int,
                 stats: _ShardStats, last: bool = False):
        # deliberately NOT calling FeaturePlan.__init__: every layout
        # artifact is derived from the parent
        self._parent = parent
        self._start = start
        self._stop = stop
        self._last = last
        self.packed = True
        self.table = parent.table
        self.features = parent.features
        self.augmented = parent.augmented
        self.plans = parent.plans               # shared device tables
        self._fused_box = parent._fused_box     # shared, co-invalidated
        self.stats = stats                      # rolls up into parent totals
        self._words_cache: dict[int, tuple[int, np.ndarray]] = {}
        # cold residency tier: col -> (rle values, run lengths, cum ends).
        # Non-None means this shard holds NO packed copy of its own — host
        # reads decode the runs directly (see host_codes override)
        self._rle: dict[int, tuple[np.ndarray, np.ndarray,
                                   np.ndarray]] | None = None

    @property
    def shard_bounds(self) -> tuple[int, int]:
        """[start, stop) in parent rows (stop tracks appends when last)."""
        stop = self._parent.n_rows if self._last else self._stop
        return self._start, max(stop, self._start)

    @property
    def _n_rows(self) -> int:                   # FeaturePlan.n_rows reads this
        start, stop = self.shard_bounds
        return stop - start

    @property
    def device_bits(self) -> list[int]:
        return self._parent.device_bits

    @property
    def packed_versions(self) -> list[int]:
        # executors key their resident-stream sync on these, so a parent
        # refresh transparently re-puts the shard views it actually moved:
        # a width-boundary repack changes every shard's slice (layout
        # version), but a streaming APPEND only rewrites the open-ended
        # tail — interior shards' bytes are untouched, so they keep their
        # resident streams (no n_shards x full re-put per insert)
        if self._last:
            return self._parent.packed_versions
        return self._parent.packed_layout_versions

    @property
    def packed_words(self) -> list[np.ndarray]:
        return [self._shard_words(i) for i in range(len(self.plans))]

    def _shard_words(self, i: int) -> np.ndarray:
        if self._rle is not None:
            # cold shard: no packed copy is retained — rebuild column i's
            # words from its runs at the CURRENT device width (codes never
            # change for existing rows, so runs survive width repacks).
            # Deliberately uncached: rehydrate() is the bulk warm-up path
            values, lengths, _ = self._rle[i]
            return pack_bits(rle_decode(values, lengths),
                             self._parent.device_bits[i])
        parent = self._parent
        version = self.packed_versions[i]
        hit = self._words_cache.get(i)
        if hit is not None and hit[0] == version:
            return hit[1]
        db = parent.device_bits[i]
        s = 32 // db
        start, stop = self.shard_bounds
        if start % s == 0:                      # word-aligned boundary
            words = parent.packed_words[i][start // s:(stop + s - 1) // s]
        else:                                   # seam: repack this shard only
            codes = packed_gather(parent.packed_words[i], db,
                                  np.arange(start, stop))
            words = pack_bits(codes, db)
            self.stats["words_repacked"] += 1
        self._words_cache[i] = (version, words)
        return words

    def refresh(self, new_codes=None) -> int:
        raise RuntimeError("shard plans are views — refresh the parent "
                           "FeaturePlan; every shard re-syncs automatically")

    # -- residency ladder: cold tier (RLE runs, no packed copy) ------------------
    @property
    def is_cold(self) -> bool:
        return self._rle is not None

    def demote_cold(self) -> int:
        """Demote this CLOSED shard to the cold tier: encode every column's
        codes as RLE runs and drop the host packed slice — the shard's only
        storage becomes the runs (plus zero-copy parent views it can always
        re-derive from). Returns the run bytes held. Correctness rests on
        codes being immutable for existing rows (dictionaries only grow):
        the runs stay valid across any later width repack, and rehydration
        simply packs them at the then-current device width. The open tail
        is refused — appends extend it and would stale the runs."""
        if self._last:
            raise ValueError("the open tail shard cannot go cold: streaming "
                             "appends extend it and would stale the runs")
        if self._rle is not None:
            return self.rle_bytes()
        runs = {}
        for i in range(len(self.plans)):
            codes = unpack_bits(self._shard_words(i),
                                self._parent.device_bits[i], self._n_rows)
            values, lengths = rle_encode(codes)
            runs[i] = (values, lengths, np.cumsum(lengths))
        self._rle = runs
        self._words_cache.clear()               # the packed copy is dropped
        self.stats["rle_encoded"] += 1
        return self.rle_bytes()

    def rehydrate(self) -> None:
        """Promote out of the cold tier: decode every column's runs and
        repack at the CURRENT device width, priming the slice cache so the
        executor's next version-keyed re-put finds host words ready."""
        if self._rle is None:
            return
        for i in range(len(self.plans)):
            values, lengths, _ = self._rle[i]
            words = pack_bits(rle_decode(values, lengths),
                              self._parent.device_bits[i])
            self._words_cache[i] = (self.packed_versions[i], words)
        self._rle = None
        self.stats["rehydrated"] += 1

    def rle_bytes(self) -> int:
        """Host bytes held by the cold runs (0 when not cold)."""
        if self._rle is None:
            return 0
        return sum(rle_nbytes(v, l, self._parent.device_bits[i])
                   for i, (v, l, _) in self._rle.items())

    def host_codes(self, rows: np.ndarray) -> np.ndarray:
        """Cold shards gather codes straight from the runs — one
        searchsorted per column against the cumulative run ends, never
        materializing a packed or decoded stream. Warm/hot shards use the
        inherited packed-word gather."""
        if self._rle is None:
            return super().host_codes(rows)
        rows = np.asarray(rows)
        out = np.empty((len(self.plans), rows.shape[0]), np.int32)
        for i, (values, lengths, ends) in self._rle.items():
            run = np.searchsorted(ends, rows, side="right")
            out[i] = values[np.minimum(run, values.size - 1)]
        return out

    def close_at(self, cut: int) -> None:
        """Close this open tail shard at parent row ``cut`` (it becomes an
        interior shard bounded by [start, cut)). Internal half of
        :meth:`FeaturePlan.split_tail_shard` — callers that swapped routing
        first may close last, so readers never see rows go unowned. The
        slice cache must drop: the version SOURCE switches from full packed
        versions to layout versions on close, and a numerically equal
        version must not revive a slice with the old open-ended bounds."""
        if not self._last:
            raise ValueError("only the open tail shard can close")
        start, stop = self.shard_bounds
        if not start < cut <= stop:
            raise ValueError(f"cut {cut} outside open tail ({start}, {stop}]")
        self._stop = cut
        self._last = False
        self._words_cache.clear()


class _DeviceTableCache:
    """Per-DEVICE cache of placed ADV tables (plain + fused).

    Shard executors that share a device (more IMCU shards than mesh
    devices) share one of these, so the replicated tables exist once per
    device — never once per shard — and ``tables_put`` counts real
    transfers."""

    def __init__(self):
        self.tables: tuple | None = None
        self.tables_key: tuple | None = None
        self.fused_src = None
        self.fused = None


# process-unique launch-stream identity (see FeatureExecutor.stream_token):
# unlike id(executor), a token is never reused after an executor is dropped,
# so health state keyed on it can never alias onto a NEW stream
_STREAM_TOKENS = itertools.count()


class FeatureExecutor:
    """Run-time half: jit'd stacked gather + double-buffered batch iterator.

    ADV tables enter the jit'd gathers as *arguments*, not trace-time
    constants, so a :meth:`FeaturePlan.refresh` flows into already-compiled
    batch shapes automatically (only a table *shape* change retraces).

    Packed plans additionally keep the word streams device-resident
    (re-put incrementally when a refresh bumps a column's version) and serve
    word-aligned ranges via :meth:`batch_range` with zero per-batch
    host->device code traffic — and ARBITRARY rows via the jit-cached
    indexed gather (:meth:`_rows_future`,
    compiled once per static batch shape like the range path): the device
    computes word index + bit offset against its resident streams, so the
    only per-call traffic is the 4B x N index vector, independent of column
    count. ``autotune=True`` sweeps the fused packed kernel's (bn, bk, bw)
    block shapes once per workload shape, and the int32 fused kernel's
    (bn, bk) likewise (:func:`adv_ops.autotune_fused`).
    """

    def __init__(self, plan: FeaturePlan, use_kernel: bool = False,
                 prefetch: int = 2, autotune: bool = False, device=None,
                 table_cache: _DeviceTableCache | None = None,
                 commit: bool = True):
        if prefetch < 1:
            raise ValueError("prefetch depth must be >= 1")
        self.plan = plan
        self.use_kernel = use_kernel
        self.prefetch = prefetch
        self.autotune = autotune
        self.packed = plan.packed
        # mesh placement: device=None serves from the process default (the
        # original single-device behavior); a concrete device COMMITS every
        # resident operand (word stream, per-plan tables, fused super-table)
        # there, so launches against this executor run on that device — the
        # sharded-serving building block (one executor per IMCU shard).
        # ``table_cache`` lets executors sharing a device share the placed
        # table copies (ShardedFeatureExecutor passes one per device).
        self.device = device
        # stable launch-stream identity for per-stream health state
        # (breakers): survives as a dict key where id(self) would be
        # recycled by the allocator after a drop_replica/evict
        self.stream_token = next(_STREAM_TOKENS)
        self._tcache = table_cache if table_cache is not None \
            else _DeviceTableCache()
        self._jit_take = jax.jit(self._take_impl)
        self._jit_fused = jax.jit(self._fused_impl,
                                  static_argnames=("out_dim", "bn", "bk"))
        self._fused_blocks_cache: dict[int, tuple[int, int]] = {}
        # compiled-predicate cache: a deployed filter family scans on every
        # request, so the code-set compile + the device put of the packed
        # term arrays must not repeat per call (keyed also by dictionary
        # cardinalities — appends that grow a dictionary can change what a
        # value predicate matches). Unconditional: int32 plans still reach
        # _compiled_pred to raise the packed-plan guard.
        self._pred_cache: dict = {}
        if self.packed:
            # ONE flat device-resident stream holds every column's words
            # (column c's start at _word_offs[c]); range windows are
            # dynamic_slices into it and the random-row kernels gather from
            # it directly — no per-column duplicate buffers
            self._flat_words: jnp.ndarray | None = None
            self._word_offs: tuple[int, ...] = ()
            self._words_sig: tuple | None = None
            self._capacity = 0
            self._blocks: dict[int, tuple[int, int, int]] = {}
            self._rows_blocks_cache: dict[int, tuple[int, int]] = {}
            # commit=False defers the word-stream device put (tiered
            # residency: a warm shard's executor exists but holds no HBM
            # until promotion calls ensure_range_capacity — any direct
            # launch still self-commits through the same call)
            if commit:
                self.ensure_range_capacity(plan.n_rows)
        if self.kernel_active:
            plan.fused_tables()        # build eagerly, not inside the jit trace

    @property
    def kernel_active(self) -> bool:
        """Fused one-hot kernel path, guarded like the single-table op: huge-K
        plans fall back to the XLA gather (one-hot tiling is wasteful there),
        and BOTH fused kernels (packed and int32) respect the ΣK×ΣF VMEM
        budget (past it the gathers split into unfused per-table takes)."""
        if not self.use_kernel:
            return False
        return adv_ops.fused_kernel_fits(
            [p.cardinality for p in self.plan.plans],
            [p.out_dim for p in self.plan.plans])

    def _take_impl(self, codes: jnp.ndarray, tables) -> jnp.ndarray:
        # mode="clip" matches the fused kernel's OOB clamp (jax's default
        # would NaN-fill, and the two paths must agree)
        outs = [jnp.take(t, codes[i], axis=0, mode="clip")
                for i, t in enumerate(tables)]
        return jnp.concatenate(outs, axis=-1)

    def _fused_impl(self, codes: jnp.ndarray, table: jnp.ndarray,
                    row_offsets: jnp.ndarray, card_limits: jnp.ndarray,
                    out_dim: int, bn: int, bk: int) -> jnp.ndarray:
        # fused multi-table Pallas kernel: ONE pass over the code matrix
        return adv_ops.gather_fused_parts(table, row_offsets, codes, out_dim,
                                          card_limits=card_limits,
                                          bn=bn, bk=bk)

    def _device_tables(self) -> tuple:
        """Per-plan fused tables as launch arguments, on this executor's
        device. device=None passes the plan's own resident tables through
        (shared across executors, refresh flows as jit arguments); a
        committed executor keeps its own device copies, re-put only when a
        column's AugmentedDictionary version moves."""
        if self.device is None:
            return tuple(p.fused_table for p in self.plan.plans)
        key = tuple(p.aug_version for p in self.plan.plans)
        if self._tcache.tables_key != key:
            self._tcache.tables = tuple(
                jax.device_put(p.fused_host, self.device)
                for p in self.plan.plans)
            self._tcache.tables_key = key
            self.plan.stats["tables_put"] += len(self.plan.plans)
        return self._tcache.tables

    def _device_fused(self) -> adv_ops.FusedTables:
        """The shared block-diagonal super-table, committed to this
        executor's device (replicated per shard; the word streams are what
        stays partitioned). Re-placed only when a refresh rebuilds it."""
        fused = self.plan.fused_tables()
        if self.device is None:
            return fused
        if self._tcache.fused_src is not fused:
            self._tcache.fused = adv_ops.place_fused(fused, self.device)
            self._tcache.fused_src = fused
        return self._tcache.fused

    def _fused_blocks(self, batch: int) -> tuple[int, int]:
        """(bn, bk) for the int32 fused kernel — swept per batch shape when
        ``autotune=True`` (the packed path's sweep, ported), else the
        fuse-time defaults."""
        blocks = self._fused_blocks_cache.get(batch)
        if blocks is None:
            fused = self._device_fused()
            if self.autotune:
                probe = jnp.zeros((len(self.plan.plans), batch), jnp.int32)
                blocks = adv_ops.autotune_fused(probe, fused, batch)
            else:
                blocks = (fused.bn, fused.bk)
            self._fused_blocks_cache[batch] = blocks
        return blocks

    def gather_device(self, dev_codes: jnp.ndarray) -> jnp.ndarray:
        """(C, B) stacked device codes -> (B, out_dim) concatenated features."""
        if self.kernel_active:
            fused = self._device_fused()
            bn, bk = self._fused_blocks(int(dev_codes.shape[1]))
            return self._jit_fused(dev_codes, fused.table, fused.row_offsets,
                                   fused.card_limits, out_dim=fused.out_dim,
                                   bn=bn, bk=bk)
        return self._jit_take(dev_codes, self._device_tables())

    # -- packed fast path: device-resident words, range batches -------------------
    def ensure_range_capacity(self, limit: int) -> None:
        """Grow the device word stream to cover rows [0, pad32(limit)).

        Padding words are zeros -> code 0 (a valid row of every table); any
        features gathered past the real row count are sliced off by callers.
        """
        if not self.packed:
            raise RuntimeError("range capacity applies to packed plans only")
        self._capacity = max(self._capacity, _pad32(limit))
        self._sync_device_words()

    def _sync_device_words(self) -> None:
        """Re-put the flat resident stream when any column's words moved.

        One concatenated buffer replaces per-column arrays, so a refresh
        that touches any column re-puts the whole stream — word streams are
        32/db x smaller than the codes they encode, so one put stays cheap,
        and holding a single copy (instead of flat + per-column duplicates)
        keeps device residency at exactly Σ stream bytes.
        """
        plan = self.plan
        sig = (tuple(plan.packed_versions), tuple(plan.device_bits),
               self._capacity)
        if self._words_sig == sig:
            return
        parts, offs, off = [], [], 0
        for i in range(len(plan.plans)):
            need = self._capacity * plan.device_bits[i] // 32
            w = plan.packed_words[i]
            if w.shape[0] < need:
                w = np.concatenate([w, np.zeros(need - w.shape[0],
                                                np.uint32)])
            else:
                w = w[:need]
            parts.append(w)
            offs.append(off)
            off += need
        flat = (np.concatenate(parts) if parts
                else np.zeros(0, np.uint32))
        self._flat_words = jax.device_put(np.ascontiguousarray(flat),
                                          self.device)
        self._word_offs = tuple(offs)
        self._words_sig = sig
        plan.stats["words_put"] += 1

    # -- tiered residency: per-stream HBM accounting ------------------------------
    def resident_bytes(self) -> int:
        """Device bytes currently held by this stream's resident words."""
        if not self.packed or self._flat_words is None:
            return 0
        return int(self._flat_words.size) * 4

    def stream_nbytes(self) -> int:
        """Projected device bytes of a FULL commit at the current capacity
        (what a promotion would charge) — defined whether or not the words
        are resident right now."""
        if not self.packed:
            return 0
        plan = self.plan
        cap = max(self._capacity, _pad32(plan.n_rows))
        return sum(cap * db // 32 * 4 for db in plan.device_bits)

    def evict_words(self) -> int:
        """Release the resident word stream (demotion to a host tier);
        returns the bytes freed. The device buffer is dereferenced, NOT
        deleted: an in-flight launch may still hold it, and refcounting
        frees it the moment the last launch retires. Any later launch (or
        an explicit promotion) re-puts through the version-keyed sync."""
        freed = self.resident_bytes()
        self._flat_words = None
        self._words_sig = None
        return freed

    def _kernel_blocks(self, batch: int) -> tuple[int, int, int]:
        """(bn, bk, bw) for the fused packed RANGE kernel — autotuned per
        batch shape on first use when requested, else fuse-time defaults."""
        blocks = self._blocks.get(batch)
        if blocks is None:
            fused = self._device_fused()
            if self.autotune:
                dbs = tuple(self.plan.device_bits)
                wins, flat = [], self._flat_words
                for off, db in zip(self._word_offs, dbs):
                    wins.append(flat[off:off + batch * db // 32])
                blocks = adv_ops.autotune_packed(wins, dbs, fused, batch)
            else:
                blocks = (fused.bn, fused.bk, 512)
            self._blocks[batch] = blocks
        return blocks

    def _rows_kernel_blocks(self, n: int) -> tuple[int, int]:
        """(bn, bk) for the fused random-row kernel — swept on the rows
        kernel ITSELF (its gather cost profile differs from the range
        kernel's) when ``autotune=True``, else fuse-time defaults."""
        blocks = self._rows_blocks_cache.get(n)
        if blocks is None:
            fused = self._device_fused()
            if self.autotune:
                blocks = adv_ops.autotune_packed_rows(
                    self._flat_words, self._word_offs,
                    tuple(self.plan.device_bits), fused, n)
            else:
                blocks = (fused.bn, fused.bk)
            self._rows_blocks_cache[n] = blocks
        return blocks

    def _range_future(self, start: int, batch: int) -> jnp.ndarray:
        """Async gather of rows [start, start+batch) from resident words.

        Per-batch host->device traffic: ONE scalar (the start index).
        Returns the full (batch, out_dim) device buffer; callers slice the
        valid prefix when retiring.
        """
        if start % 32 or batch % 32:
            raise ValueError("packed ranges must be word-aligned "
                             f"(start % 32 == 0, batch % 32 == 0); got "
                             f"[{start}, {start + batch})")
        self.ensure_range_capacity(max(start + batch, self.plan.n_rows))
        dbs = tuple(self.plan.device_bits)
        if self.kernel_active:
            fused = self._device_fused()
            bn, bk, bw = self._kernel_blocks(batch)
            return _packed_fused_range(
                self._flat_words, fused.table, fused.row_offsets,
                fused.card_limits, start, dbs=dbs, offs=self._word_offs,
                batch=batch, out_dim=fused.out_dim, bn=bn, bk=bk, bw=bw)
        return _packed_split_range(
            self._flat_words, self._device_tables(),
            start, dbs=dbs, offs=self._word_offs, batch=batch)

    def _multi_range_future(self, starts, batch: int) -> jnp.ndarray:
        """Async gather of K coalesced ranges -> (K, batch, out_dim) buffer.

        ONE device launch serves all K ranges; the only host->device traffic
        is the (K,) start-index vector. This is what lets a serving pump
        amortize launch overhead across many small queued requests.
        """
        starts = np.asarray(starts, np.int64).reshape(-1)
        if starts.size == 0:
            raise ValueError("need at least one range start")
        if batch % 32 or (starts % 32).any():
            raise ValueError("packed ranges must be word-aligned "
                             "(starts % 32 == 0, batch % 32 == 0)")
        self.ensure_range_capacity(max(int(starts.max()) + batch,
                                       self.plan.n_rows))
        sv = jnp.asarray(starts, jnp.int32)
        dbs = tuple(self.plan.device_bits)
        if self.kernel_active:
            fused = self._device_fused()
            bn, bk, bw = self._kernel_blocks(batch)
            return _packed_fused_multi(
                self._flat_words, fused.table, fused.row_offsets,
                fused.card_limits, sv, dbs=dbs, offs=self._word_offs,
                batch=batch, out_dim=fused.out_dim, bn=bn, bk=bk, bw=bw)
        return _packed_split_multi(
            self._flat_words, self._device_tables(),
            sv, dbs=dbs, offs=self._word_offs, batch=batch)

    def batch_range(self, start: int, n: int) -> jnp.ndarray:
        """Featurize the contiguous rows [start, start+n) (start % 32 == 0)
        without any host code work: unpack happens inside the gather."""
        return self._range_future(start, _pad32(n))[:n]

    # -- packed random-row path: indices in, features out -------------------------
    def _rows_future(self, rows) -> jnp.ndarray:
        """Async indexed gather of arbitrary rows from the resident words.

        Per-call host->device traffic: the (N,) int32 index vector — 4B per
        row, independent of column count. One compiled shape per index
        length (callers pad to static bucket shapes, the range path's
        compiled-shape discipline). The serving pump's unified launch:
        K coalesced bucket-padded row sets arrive here flattened.
        """
        if not self.packed:
            raise RuntimeError("indexed row gather applies to packed plans "
                               "only; int32 plans ship code slices")
        # the stream must cover every live row: refresh() appends can push
        # n_rows past the capacity the stream was last put at, and an index
        # past the stream would silently clip into another column's words
        self.ensure_range_capacity(self.plan.n_rows)
        # np rows go straight into the jit: its argument transfer IS the
        # 4B x N host->device index shipment (a separate device_put would
        # just add one more dispatch on the serving hot path)
        dev_rows = rows if isinstance(rows, jnp.ndarray) \
            else np.ascontiguousarray(rows, dtype=np.int32)
        dbs = tuple(self.plan.device_bits)
        if self.kernel_active:
            fused = self._device_fused()
            bn, bk = self._rows_kernel_blocks(int(dev_rows.shape[0]))
            return _packed_fused_rows(
                self._flat_words, fused.table, fused.row_offsets,
                fused.card_limits, dev_rows, dbs=dbs,
                word_offs=self._word_offs, out_dim=fused.out_dim,
                bn=bn, bk=bk)
        return _packed_split_rows(
            self._flat_words, self._device_tables(),
            dev_rows, dbs=dbs, word_offs=self._word_offs)

    # -- predicate pushdown: scan -> compact -> gather on resident words ----------
    def _scan_terms(self, pred) -> tuple[tuple, str]:
        """Compile a value-space predicate to device scan terms: each leaf
        runs once over its column's K dictionary entries, and column names
        resolve to this plan's resident stream slots."""
        if not self.packed:
            raise RuntimeError("predicate pushdown runs on packed plans "
                               "only; int32 plans filter host-side")
        dicts = {c: self.plan.augmented[c].dictionary
                 for c in self.plan.columns}
        cp = colquery.compile_predicate(pred, dicts)
        slot = {c: i for i, c in enumerate(self.plan.columns)}
        terms = tuple(scan_ops.ScanTerm(col=slot[t.column], kind=t.kind,
                                        lo=t.lo, hi=t.hi, lut=t.lut)
                      for t in cp.terms)
        return terms, cp.combine

    def _compiled_pred(self, pred):
        """(terms, combine, packed device arrays) for a predicate, cached.

        Cache key includes every dictionary's cardinality: dictionaries
        only ever GROW (appends may add values), and a grown dictionary can
        change a value predicate's matching code set, so stale entries age
        out naturally the first request after such a refresh."""
        key = (pred, tuple(self.plan.augmented[c].dictionary.cardinality
                           for c in self.plan.columns))
        hit = self._pred_cache.get(key)
        if hit is None:
            terms, combine = self._scan_terms(pred)
            packed = scan_ops.pack_terms(terms,
                                         tuple(self.plan.device_bits))
            hit = self._pred_cache[key] = (terms, combine, packed)
        return hit

    def _mask_future(self, terms: tuple, combine: str,
                     packed=None) -> jnp.ndarray:
        """Async device scan: compiled terms -> (n_rows,) bool selection
        mask against the resident word streams. No decoded code stream
        exists anywhere — the scan unpacks in-register."""
        self.ensure_range_capacity(self.plan.n_rows)
        dbs = tuple(self.plan.device_bits)
        if self.use_kernel:
            return scan_ops.predicate_scan(
                self._flat_words, self._word_offs, dbs, terms,
                self.plan.n_rows, combine)
        return scan_ops.predicate_scan_split(
            self._flat_words, self._word_offs, dbs, terms,
            self.plan.n_rows, combine, packed=packed)

    def _mask_count_future(self, pred):
        """(mask, count) device futures from one scan launch (split path;
        the Pallas path adds an eager reduction)."""
        terms, combine, packed = self._compiled_pred(pred)
        if self.use_kernel:
            mask = self._mask_future(terms, combine)
            return mask, mask.sum()
        self.ensure_range_capacity(self.plan.n_rows)
        return scan_ops.predicate_scan_split_count(
            self._flat_words, self._word_offs,
            tuple(self.plan.device_bits), terms, self.plan.n_rows,
            combine, packed=packed)

    def predicate_mask(self, pred) -> jnp.ndarray:
        """(n_rows,) bool device mask for a value-space predicate."""
        terms, combine, packed = self._compiled_pred(pred)
        return self._mask_future(terms, combine, packed)

    def count_where(self, pred) -> int:
        """SELECT COUNT(*) WHERE pred — one device scan + reduction."""
        return int(self._mask_count_future(pred)[1])

    def filtered_rows(self, pred) -> np.ndarray:
        """Matching row indices (ascending int64), compacted on device."""
        mask, cnt_dev = self._mask_count_future(pred)
        cnt = int(cnt_dev)             # one scalar sync: the static shape
        if cnt == 0:
            return np.zeros(0, np.int64)
        rows = scan_ops.compact_rows(mask, _pad32(cnt))
        return np.asarray(rows[:cnt]).astype(np.int64)

    def batch_where(self, pred) -> tuple[np.ndarray, jnp.ndarray]:
        """Filtered featurization: scan -> compact -> indexed gather, all
        against the resident streams. Returns (rows, features) for the
        matching rows in ascending row order. The ONE host sync is the
        match count (the static launch shape); the compacted index vector
        feeds the gather without ever visiting the host."""
        mask, cnt_dev = self._mask_count_future(pred)
        cnt = int(cnt_dev)
        if cnt == 0:
            return (np.zeros(0, np.int64),
                    jnp.zeros((0, self.plan.out_dim), jnp.float32))
        if self.kernel_active:
            rows_dev = scan_ops.compact_rows(mask, _pad32(cnt))
            feats = self._rows_future(rows_dev)    # device-to-device indices
            return np.asarray(rows_dev[:cnt]).astype(np.int64), feats[:cnt]
        self.ensure_range_capacity(self.plan.n_rows)
        rows_dev, feats = _packed_split_where(
            self._flat_words, self._device_tables(), mask,
            dbs=tuple(self.plan.device_bits), word_offs=self._word_offs,
            cap=_pad32(cnt))
        return np.asarray(rows_dev[:cnt]).astype(np.int64), feats[:cnt]

    def _masked_counts_from(self, column: str, mask: jnp.ndarray) -> jnp.ndarray:
        """Async (K,) per-code counts of ``column`` under a device mask."""
        try:
            ci = self.plan.columns.index(column)
        except ValueError:
            raise KeyError(f"column {column!r} not in plan "
                           f"({self.plan.columns})") from None
        d = self.plan.augmented[column].dictionary
        return scan_ops.masked_counts(
            self._flat_words, self._word_offs[ci],
            self.plan.device_bits[ci], mask, d.cardinality,
            self.plan.n_rows, use_kernel=self.use_kernel)

    def groupby_where(self, column: str, pred) -> tuple[np.ndarray, np.ndarray]:
        """GROUP BY column COUNT(*) WHERE pred — masked histogram over the
        resident words; returns (values, counts) like ``groupby_count``."""
        counts = self._masked_counts_from(column,
                                          self.predicate_mask(pred))
        d = self.plan.augmented[column].dictionary
        return d.values, np.asarray(counts).astype(np.int64)

    def agg_where(self, pred, column: str, agg: str = "count") -> float:
        """Masked count/sum/mean of ``column`` under ``pred`` — K-entry
        dictionary tail work on top of the device masked histogram."""
        counts = self._masked_counts_from(column, self.predicate_mask(pred))
        d = self.plan.augmented[column].dictionary
        return _agg_from_counts(d, np.asarray(counts), agg)

    # -- single batch -------------------------------------------------------------
    def slice_codes(self, row_idx: np.ndarray) -> np.ndarray:
        """Host-side work for one batch: one fancy-index on the code matrix
        (int32 plans) or a per-column word gather (packed plans)."""
        return self.plan.host_codes(row_idx)

    def batch(self, row_idx: np.ndarray) -> jnp.ndarray:
        """Featurize the given rows. int32 plans ship the stacked code slice;
        packed plans ship ONLY the row indices — the device computes word
        index + bit offset against its resident streams (no host code
        materialization for any access pattern)."""
        if self.packed:
            rows = np.asarray(row_idx, np.int64).reshape(-1)
            n = rows.shape[0]
            if n == 0:                 # match the int32 path's empty gather
                return jnp.zeros((0, self.plan.out_dim), jnp.float32)
            if rows.min() < 0 or rows.max() >= self.plan.n_rows:
                # numpy fancy-indexing raised on the old host-gather path;
                # the device gather clips, which would silently read
                # ANOTHER column's words — keep the error contract
                raise IndexError(
                    f"row indices out of range [0, {self.plan.n_rows})")
            rows = pad_rows_edge(rows, _pad32(n))
            return self._rows_future(rows.astype(np.int32))[:n]
        return self.gather_device(jax.device_put(self.slice_codes(row_idx),
                                                 self.device))

    # -- double-buffered iteration --------------------------------------------------
    def batches(self, batch_size: int, seed: int = 0,
                epochs: int = 1) -> Iterator[tuple[np.ndarray, jnp.ndarray]]:
        """Shuffled minibatch iterator with ``prefetch``-deep async pipeline.

        Up to ``prefetch`` device gathers are kept in flight: the host slices
        and ``device_put``s the codes for batch i+1 (i+2, ...) while the
        device still works on batch i, so consumers that block on each result
        hide the host-side slicing and transfer latency.

        Packed plans shuffle at word-aligned BLOCK granularity (the order of
        contiguous ``batch_size``-row ranges is permuted, rows within a range
        stay contiguous) so batches slice on word boundaries and no int32
        codes are ever built; ``batch_size`` must be a multiple of 32.
        """
        rng = np.random.default_rng(seed)
        n = self.plan.n_rows

        if self.packed:
            if batch_size % 32:
                raise ValueError("packed plans need batch_size % 32 == 0 "
                                 f"(word-aligned ranges), got {batch_size}")
            # a per-epoch word-aligned jitter rotates which remainder rows
            # fall outside the epoch's blocks (mirroring the int32 path's
            # fresh permutation); only a sub-word tail (< 32 rows, when
            # n % 32 != 0) is never range-reachable
            leftover = (n % batch_size) // 32 * 32

            def ranges():
                for _ in range(epochs):
                    jitter = 32 * rng.integers(0, leftover // 32 + 1)
                    yield from rng.permutation(
                        np.arange(jitter, n - batch_size + 1, batch_size))

            inflight: deque[tuple[np.ndarray, jnp.ndarray]] = deque()
            for start in ranges():
                idx = np.arange(start, start + batch_size)
                inflight.append((idx, self._range_future(int(start),
                                                         batch_size)))
                if len(inflight) >= self.prefetch:
                    yield inflight.popleft()
            while inflight:
                yield inflight.popleft()
            return

        def indices():
            for _ in range(epochs):
                perm = rng.permutation(n)
                for start in range(0, n - batch_size + 1, batch_size):
                    yield perm[start:start + batch_size]

        inflight: deque[tuple[np.ndarray, jnp.ndarray]] = deque()
        for idx in indices():
            dev_codes = jax.device_put(self.slice_codes(idx), self.device)
            inflight.append((idx, self.gather_device(dev_codes)))
            if len(inflight) >= self.prefetch:
                yield inflight.popleft()
        while inflight:
            yield inflight.popleft()


class ShardedFeatureExecutor:
    """Mesh half of per-IMCU serving: one committed executor per shard.

    The plan is partitioned by :meth:`FeaturePlan.imcu_shards` and each
    shard's resident word stream is placed on its own mesh device
    (:func:`repro.distributed.sharding.serve_devices` round-robins shards
    over the serve mesh when devices outnumber or undernumber shards) —
    'move compute to the data': a featurization launch for rows owned by
    shard k runs on shard k's device against shard-local operands only.
    ADV tables (K-row, amortized) are replicated per device; the word
    streams — the part that scales with table rows — are what stays
    partitioned, so aggregate resident bytes stay at Σ stream bytes.

    :meth:`batch` is the synchronous routed gather (host buckets the rows
    by owning shard, per-shard sub-launches run concurrently, results are
    reassembled in request order). The serving pump drives the per-shard
    executors directly (one launch queue per shard) for the async path.

    The shard set is ADAPTIVE (feedback re-shapes layout, the paper's
    cycle): :meth:`add_replica` places a second committed copy of a hot
    shard's resident stream on another device and :meth:`next_executor`
    round-robins read launches across the copies (read fan-out — each
    stream brings its own device queue, so a hot shard's capacity scales
    with replicas; writes need no fan-in because every stream re-syncs
    from the parent plan's versioned words at its next launch);
    :meth:`split_tail` closes the open tail shard at a cut row and opens a
    fresh tail on another device once streaming appends outgrow a row
    budget. Routing state (``starts`` + bisect list) is swapped as one
    atomic snapshot tuple, and the split orders create-new → swap-routing
    → close-old so a reader holding either snapshot stays bit-exact.
    Mutators themselves are NOT safe against a concurrent :meth:`batch` —
    FeatureService serializes them behind its pump; standalone users must
    quiesce first.
    """

    def __init__(self, plan: FeaturePlan, use_kernel: bool = False,
                 prefetch: int = 2, autotune: bool = False, devices=None,
                 hbm_budget_bytes: int | None = None):
        if not plan.packed:
            raise ValueError("sharded executors serve packed plans; int32 "
                             "plans route host code slices instead")
        from repro.distributed.sharding import DeviceBudget, serve_devices
        self.plan = plan
        self.use_kernel = use_kernel
        self.prefetch = prefetch
        self.autotune = autotune
        self.hbm_budget_bytes = hbm_budget_bytes
        self.shards = plan.imcu_shards()
        self.device_pool = (list(devices) if devices is not None
                            else jax.devices())
        self.devices = serve_devices(len(self.shards), self.device_pool)
        # tables replicate once per DEVICE, not per shard: shards placed on
        # the same device (more IMCUs than mesh devices) share the copies —
        # the cache dict persists so replicas/splits landing on a device
        # later reuse the same placed tables (place_fused reuse)
        self._caches = {id(dev): _DeviceTableCache() for dev in self.devices}
        # tiered residency at build time: walk the shards in order and
        # commit each stream only while it fits the per-device byte budget
        # (DeviceBudget ledger); the rest stay WARM — executor built, no
        # HBM held — and the serving layer's promotion ladder takes over.
        # No budget (the default) commits everything, today's behavior.
        ledger = DeviceBudget(hbm_budget_bytes)
        self.executors = []
        for sp, dev in zip(self.shards, self.devices):
            ex = FeatureExecutor(sp, use_kernel=use_kernel, prefetch=prefetch,
                                 autotune=autotune, device=dev,
                                 table_cache=self._caches[id(dev)],
                                 commit=False)
            if ledger.fits(id(dev), ex.stream_nbytes()):
                ex.ensure_range_capacity(sp.n_rows)
                ledger.charge(id(dev), ex.resident_bytes())
            self.executors.append(ex)
        self.replicas: list[list[FeatureExecutor]] = [[] for _ in self.shards]
        self._rr = [0] * len(self.shards)   # read-fan-out cursor per shard
        self._set_routing()

    def _cache_for(self, dev) -> _DeviceTableCache:
        return self._caches.setdefault(id(dev), _DeviceTableCache())

    def _set_routing(self) -> None:
        """Swap the routing table as ONE snapshot: readers grab the tuple
        once, so a concurrent swap can never hand them a torn view (new
        starts with an old bisect list)."""
        starts = np.array([sp._start for sp in self.shards], np.int64)
        self.starts = starts
        self._starts_list = starts.tolist()  # bisect beats np for O(1)
        self._routing = (starts, self._starts_list)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    # -- adaptive shard management -------------------------------------------------
    def n_streams(self, shard: int) -> int:
        """Launch streams serving this shard (primary + replicas)."""
        return 1 + len(self.replicas[shard])

    def stream_executors(self, shard: int) -> list[FeatureExecutor]:
        return [self.executors[shard], *self.replicas[shard]]

    def next_executor(self, shard: int) -> FeatureExecutor:
        """Read fan-out: round-robin the shard's launch streams. With no
        replicas this is exactly the primary (zero-cost fast path)."""
        reps = self.replicas[shard]
        if not reps:
            return self.executors[shard]
        i = self._rr[shard]
        self._rr[shard] = (i + 1) % (1 + len(reps))
        return self.executors[shard] if i == 0 else reps[i - 1]

    def device_load(self) -> dict[int, int]:
        """Resident launch streams per device (``id(dev)`` keyed) — the
        placement pressure the replica/split policies balance against."""
        load: dict[int, int] = {}
        for ex in self.executors:
            load[id(ex.device)] = load.get(id(ex.device), 0) + 1
        for reps in self.replicas:
            for ex in reps:
                load[id(ex.device)] = load.get(id(ex.device), 0) + 1
        return load

    def device_bytes(self) -> dict[int, int]:
        """LIVE resident word-stream bytes per device (``id(dev)`` keyed),
        summed over every launch stream (primaries + replicas). Computed
        from the buffers actually held — never a ledger that could drift —
        so budget enforcement and tests measure ground truth. Replicated
        ADV tables are excluded by design: K-row constants shared per
        device, while the budget governs what scales with table rows."""
        out: dict[int, int] = {}
        for s in range(self.n_shards):
            for ex in self.stream_executors(s):
                b = ex.resident_bytes()
                if b:
                    out[id(ex.device)] = out.get(id(ex.device), 0) + b
        return out

    def budget_ledger(self):
        """A :class:`repro.distributed.sharding.DeviceBudget` seeded from
        the live per-device bytes — the fits/headroom view the promotion
        and demotion policies consult."""
        from repro.distributed.sharding import DeviceBudget
        ledger = DeviceBudget(self.hbm_budget_bytes)
        for dev_id, n in self.device_bytes().items():
            ledger.charge(dev_id, n)
        return ledger

    def add_replica(self, shard: int, device=None,
                    avoid=frozenset()) -> FeatureExecutor:
        """Commit a REPLICA of ``shard``'s resident word stream (plus the
        replicated tables, reused per device) to an under-loaded device and
        fan reads out over it. The replica shares the shard's plan view, so
        its puts attribute to the same ``per_shard`` stats entry, and a
        parent ``refresh()`` re-puts it lazily at its next launch exactly
        like the primary (version-keyed sync — write fan-in for free).
        ``avoid`` (device ids) marks unhealthy devices the default
        placement should route around — the failover path's 're-replicate
        elsewhere'."""
        sp = self.shards[shard]
        if device is None:
            from repro.distributed.sharding import replica_device
            held = {id(e.device) for e in self.stream_executors(shard)}
            device = replica_device(self.device_pool, self.device_load(),
                                    exclude=held, unhealthy=avoid)
        ex = FeatureExecutor(sp, use_kernel=self.use_kernel,
                             prefetch=self.prefetch, autotune=self.autotune,
                             device=device, table_cache=self._cache_for(device))
        self.replicas[shard].append(ex)
        self._rr[shard] = 0
        return ex

    def drop_replica(self, shard: int, index: int = -1) -> FeatureExecutor:
        """Retire one of ``shard``'s replicas (future launches stop routing
        to it; in-flight launches already hold their operands)."""
        if not self.replicas[shard]:
            raise ValueError(f"shard {shard} has no replicas to drop")
        ex = self.replicas[shard].pop(index)
        self._rr[shard] = 0
        return ex

    def evict_device(self, dev_id: int):
        """Remove every launch stream resident on a DEAD device
        (``dev_id = id(device)``) — the first half of device-loss
        recovery. Replicas on the device are dropped outright; a shard
        whose PRIMARY died promotes its first surviving replica (the
        promoted stream already holds the resident words, so serving
        continues without a transfer). Returns ``(removed, orphans)``:
        ``removed`` is ``[(shard, executor), ...]`` for every stream taken
        out of rotation (the caller retires their health state), and
        ``orphans`` lists shards left with NO live stream — their dead
        primary stays in place as a routing placeholder and the caller
        must serve them from host words until :meth:`rebuild_on` lands.
        """
        removed: list[tuple[int, FeatureExecutor]] = []
        orphans: list[int] = []
        for s in range(self.n_shards):
            reps = self.replicas[s]
            dead = [ex for ex in reps if id(ex.device) == dev_id]
            if dead:
                self.replicas[s] = [ex for ex in reps
                                    if id(ex.device) != dev_id]
                removed.extend((s, ex) for ex in dead)
                self._rr[s] = 0
            if id(self.executors[s].device) == dev_id:
                removed.append((s, self.executors[s]))
                if self.replicas[s]:           # failover: promote a replica
                    self.executors[s] = self.replicas[s].pop(0)
                    self.devices[s] = self.executors[s].device
                    self._rr[s] = 0
                else:
                    orphans.append(s)
        self._caches.pop(dev_id, None)         # placed tables died with it
        return removed, orphans

    def rebuild_on(self, shard: int, device=None,
                   lost=frozenset()) -> FeatureExecutor:
        """Emergency rebuild of ``shard``'s primary stream on a healthy
        device — the second half of device-loss recovery. The fresh
        executor re-commits the shard's resident word stream from the HOST
        packed words through the same version-keyed put path a refresh
        uses (plus the per-device table cache), so the rebuilt stream is
        bit-exact with the lost one by construction. Default placement
        routes around ``lost`` devices (ids) and anything already holding
        a stream of this shard. Raises if the surviving pool is empty —
        the caller keeps host-serving until hardware returns."""
        if device is None:
            from repro.distributed.sharding import (replica_device,
                                                    surviving_devices)
            pool = surviving_devices(self.device_pool, lost)
            if not pool:
                raise ValueError(
                    f"no surviving device to rebuild shard {shard} on")
            held = {id(e.device) for e in self.stream_executors(shard)}
            device = replica_device(pool, self.device_load(),
                                    exclude=held, unhealthy=lost)
        ex = FeatureExecutor(self.shards[shard], use_kernel=self.use_kernel,
                             prefetch=self.prefetch, autotune=self.autotune,
                             device=device, table_cache=self._cache_for(device))
        self.executors[shard] = ex
        self.devices[shard] = device
        self._rr[shard] = 0
        return ex

    def tail_rows(self) -> int:
        """Rows currently owned by the open tail shard (append pressure)."""
        start, stop = self.shards[-1].shard_bounds
        return stop - start

    def split_tail(self, cut: int | None = None, device=None) -> int:
        """Split the open tail shard at parent row ``cut`` (default: the
        word-aligned midpoint) and serve the new tail [cut, n_rows) from
        its own committed executor on an under-loaded device. Returns the
        new shard's index.

        Swap order keeps every reader bit-exact throughout: the new shard
        plan + executor exist first, the routing snapshot flips second
        (rows >= cut now route to the new stream), and the old tail closes
        LAST — a reader holding the pre-swap snapshot still finds rows >=
        cut valid in the then-still-open old tail.
        """
        tail = self.shards[-1]
        start, stop = tail.shard_bounds
        if cut is None:
            # word-aligned midpoint, clamped so the default stays valid on
            # a sub-32-row tail (cut == stop closes it behind an empty one)
            cut = min(start + max(32, (stop - start) // 2 // 32 * 32), stop)
        new_plan = self.plan.split_tail_shard(tail, cut, close=False)
        if device is None:
            from repro.distributed.sharding import replica_device
            device = replica_device(self.device_pool, self.device_load())
        ex = FeatureExecutor(new_plan, use_kernel=self.use_kernel,
                             prefetch=self.prefetch, autotune=self.autotune,
                             device=device, table_cache=self._cache_for(device))
        self.shards.append(new_plan)
        self.executors.append(ex)
        self.replicas.append([])
        self._rr.append(0)
        self.devices.append(device)
        self._set_routing()
        tail.close_at(cut)
        return len(self.shards) - 1

    def shard_of(self, rows: np.ndarray) -> np.ndarray:
        """Owning shard per row. Rows past the last compile-time bound
        (streaming appends) belong to the open-ended last shard."""
        starts, _ = self._routing
        s = np.searchsorted(starts, rows, side="right") - 1
        return np.minimum(s, len(starts) - 1)

    @staticmethod
    def _shard_scalar(slist: list[int], row: int) -> int:
        return min(bisect.bisect_right(slist, row) - 1, len(slist) - 1)

    def route(self, rows: np.ndarray, lo: int | None = None,
              hi: int | None = None):
        """Bucket request rows by owning shard: [(shard, local_rows, dest)].

        ``dest`` gives each local row's position in the original request
        (``None`` = the whole request in order — the clustered-lookup fast
        path: two scalar bisects, no per-row work, no index
        materialization). Local rows are shard-relative, so every
        sub-launch's indices stay within its device's stream. Callers that
        already know the request's min/max row pass them in (the submit hot
        path validates on them anyway).
        """
        starts, slist = self._routing       # one snapshot, never torn
        rows = np.asarray(rows, np.int64).reshape(-1)
        if lo is None:
            lo, hi = int(rows.min()), int(rows.max())
        s_lo = self._shard_scalar(slist, lo)
        s_hi = self._shard_scalar(slist, hi)
        if s_lo == s_hi:                   # whole request owned by one shard
            return [(s_lo, rows - starts[s_lo], None)]
        s = np.searchsorted(starts, rows, side="right") - 1
        shard = np.minimum(s, len(starts) - 1)
        out = []
        for s in np.unique(shard):
            (dest,) = np.nonzero(shard == s)
            out.append((int(s), rows[dest] - starts[s], dest))
        return out

    # -- predicate pushdown, sharded: scan per shard, serve matches locally -------
    def _shard_masks(self, pred) -> list[tuple[int, FeatureExecutor, jnp.ndarray]]:
        """Dispatch every shard's device scan before blocking on any count.

        The predicate compiles ONCE (dictionaries are shared across shard
        views); each shard's scan runs on the executor that owns (or
        replicates) its resident stream, so filter evaluation happens where
        the data lives — compute to the data, like the gathers.
        """
        terms = combine = None
        out = []
        for s in range(self.n_shards):
            ex = self.next_executor(s)
            if terms is None:
                terms, combine = ex._scan_terms(pred)
            out.append((s, ex, ex._mask_future(terms, combine)))
        return out

    def count_where(self, pred) -> int:
        return sum(int(m.sum()) for _, _, m in self._shard_masks(pred))

    def filtered_rows(self, pred) -> np.ndarray:
        """Matching GLOBAL row indices, ascending (shards are ordered by
        start row, so shard-order concatenation IS global row order)."""
        starts, _ = self._routing
        parts = []
        for s, ex, mask in self._shard_masks(pred):
            cnt = int(mask.sum())
            if cnt == 0:
                continue
            rows = scan_ops.compact_rows(mask, _pad32(cnt))
            parts.append(np.asarray(rows[:cnt]).astype(np.int64)
                         + int(starts[s]))
        return np.concatenate(parts) if parts else np.zeros(0, np.int64)

    def batch_where(self, pred) -> tuple[np.ndarray, jnp.ndarray]:
        """Filtered featurization across the mesh: each shard scans its own
        resident stream, compacts its matches on device, and gathers them
        LOCALLY — no shard ships bytes to another device; the host only
        assembles the per-shard results in global row order."""
        starts, _ = self._routing
        futs, total = [], 0
        for s, ex, mask in self._shard_masks(pred):
            cnt = int(mask.sum())
            if cnt == 0:
                continue
            rows = scan_ops.compact_rows(mask, _pad32(cnt))
            futs.append((s, ex._rows_future(rows), rows, cnt))
            total += cnt
        if not futs:
            return (np.zeros(0, np.int64),
                    jnp.zeros((0, self.plan.out_dim), jnp.float32))
        rows_out = np.empty(total, np.int64)
        feats_out = np.empty((total, self.plan.out_dim), np.float32)
        off = 0
        for s, fut, rows, cnt in futs:     # all dispatched; block in order
            rows_out[off:off + cnt] = \
                np.asarray(rows[:cnt]).astype(np.int64) + int(starts[s])
            feats_out[off:off + cnt] = np.asarray(fut)[:cnt]
            off += cnt
        return rows_out, jnp.asarray(feats_out)

    def groupby_where(self, column: str,
                      pred) -> tuple[np.ndarray, np.ndarray]:
        """GROUP BY column COUNT(*) WHERE pred across the mesh: per-shard
        masked histograms (local words, local mask) summed on the host —
        K-entry partials, never row-space traffic."""
        futs = [ex._masked_counts_from(column, mask)
                for _, ex, mask in self._shard_masks(pred)]
        counts = np.sum([np.asarray(f) for f in futs], axis=0)
        d = self.plan.augmented[column].dictionary
        return d.values, counts.astype(np.int64)

    def agg_where(self, pred, column: str, agg: str = "count") -> float:
        futs = [ex._masked_counts_from(column, mask)
                for _, ex, mask in self._shard_masks(pred)]
        counts = np.sum([np.asarray(f) for f in futs], axis=0)
        d = self.plan.augmented[column].dictionary
        return _agg_from_counts(d, counts, agg)

    def batch(self, row_idx: np.ndarray) -> jnp.ndarray:
        """Routed featurization of arbitrary rows, request order preserved.

        Dispatches every shard's sub-launch before blocking on any result,
        so independent shards gather concurrently.
        """
        rows = np.asarray(row_idx, np.int64).reshape(-1)
        n = rows.shape[0]
        if n == 0:
            return jnp.zeros((0, self.plan.out_dim), jnp.float32)
        lo, hi = int(rows.min()), int(rows.max())
        if lo < 0 or hi >= self.plan.n_rows:
            raise IndexError(
                f"row indices out of range [0, {self.plan.n_rows})")
        routed = self.route(rows, lo, hi)
        futs = []
        for s, local, dest in routed:      # dispatch all, block after
            padded = pad_rows_edge(local, _pad32(local.shape[0]))
            futs.append((self.next_executor(s)._rows_future(
                padded.astype(np.int32)), local.shape[0], dest))
        if len(futs) == 1:
            return futs[0][0][:n]
        out = np.empty((n, self.plan.out_dim), np.float32)
        for fut, m, dest in futs:
            out[dest] = np.asarray(fut)[:m]
        return jnp.asarray(out)


class FeaturePipeline:
    """Facade over (FeaturePlan, FeatureExecutor) — the original seed API."""

    def __init__(self, table: Table, features: FeatureSet,
                 use_kernel: bool = False, prefetch: int = 2,
                 packed: bool = False):
        self.table = table
        self.features = features
        self.plan = FeaturePlan(table, features, packed=packed)
        self.executor = FeatureExecutor(self.plan, use_kernel=use_kernel,
                                        prefetch=prefetch)
        self.augmented = self.plan.augmented
        self.use_kernel = use_kernel

    @property
    def out_dim(self) -> int:
        return self.plan.out_dim

    # -- device path ---------------------------------------------------------------
    def batch(self, row_idx: np.ndarray) -> jnp.ndarray:
        return self.executor.batch(row_idx)

    def batches(self, batch_size: int, seed: int = 0, epochs: int = 1):
        yield from self.executor.batches(batch_size, seed=seed, epochs=epochs)

    # -- host baseline (Fig 1 traditional path) -------------------------------------
    def batch_recompute(self, row_idx: np.ndarray) -> np.ndarray:
        """Decode values + row-space transform + ship f32 — the CSV workflow."""
        outs = []
        codes_all = self.plan.host_codes(row_idx)
        for i, p in enumerate(self.plan.plans):
            aug = self.augmented[p.column]
            for name in p.adv_names:
                outs.append(aug.featurize_recompute(name, codes_all[i]))
        return np.concatenate(outs, axis=1)

    # -- data-movement accounting ----------------------------------------------------
    def bytes_moved_adv(self, batch_rows: int) -> int:
        return self.plan.bytes_moved_adv(batch_rows)

    def bytes_moved_recompute(self, batch_rows: int) -> int:
        return self.plan.bytes_moved_recompute(batch_rows)

    def bytes_resident_tables(self) -> int:
        return self.plan.bytes_resident_tables()
