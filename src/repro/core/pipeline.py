"""FeaturePipeline: columnar table -> device feature batches (paper §6, Fig 2).

The pipeline moves ONLY dictionary codes (b-bit packed) and K-row ADV tables to
the device; row-space float features are produced on-device by the fused ADV
gather and consumed immediately — they are never materialized in host memory
or HBM-resident files, which is the paper's data-movement/duplication win over
the CSV-export workflow of Fig 1.

Data-movement accounting is built in (``bytes_moved_*``) so benchmarks and
EXPERIMENTS.md can quantify the claim.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.columnar.bitpack import packed_nbytes
from repro.columnar.table import Table
from repro.core.adv import AugmentedDictionary
from repro.core.feature_spec import FeatureSet


@dataclass
class _ColumnPlan:
    column: str
    adv_names: list[str]
    fused_table: jnp.ndarray      # (K, F_col) on device
    codes: np.ndarray             # host int32 row codes
    bits: int

    @property
    def out_dim(self) -> int:
        return int(self.fused_table.shape[1])


class FeaturePipeline:
    """Compiles a FeatureSet against a Table into device-side gather plans."""

    def __init__(self, table: Table, features: FeatureSet,
                 use_kernel: bool = False):
        self.table = table
        self.features = features
        self.augmented: dict[str, AugmentedDictionary] = features.build(table)
        self.use_kernel = use_kernel
        self._plans: list[_ColumnPlan] = []
        for column, aug in self.augmented.items():
            names = [s.adv_name for s in features.specs if s.column == column]
            fused = jnp.asarray(aug.fused_table(names))
            self._plans.append(_ColumnPlan(
                column=column, adv_names=names, fused_table=fused,
                codes=table[column].codes(), bits=aug.dictionary.bits))
        self.out_dim = sum(p.out_dim for p in self._plans)
        self._jit_gather = jax.jit(self._gather_all)

    # -- device path ---------------------------------------------------------------
    def _gather_one(self, fused_table: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
        if self.use_kernel:
            from repro.kernels.adv_gather import ops as adv_ops
            return adv_ops.adv_gather(fused_table, codes)
        return jnp.take(fused_table, codes, axis=0)

    def _gather_all(self, code_batch: dict[str, jnp.ndarray]) -> jnp.ndarray:
        outs = [self._gather_one(p.fused_table, code_batch[p.column])
                for p in self._plans]
        return jnp.concatenate(outs, axis=-1)

    def batch(self, row_idx: np.ndarray) -> jnp.ndarray:
        """Featurize the given rows: ship int32 codes, gather ADVs on device."""
        code_batch = {p.column: jnp.asarray(p.codes[row_idx]) for p in self._plans}
        return self._jit_gather(code_batch)

    def batches(self, batch_size: int, seed: int = 0, epochs: int = 1):
        """Shuffled minibatch iterator over the table."""
        rng = np.random.default_rng(seed)
        n = self.table.n_rows
        for _ in range(epochs):
            perm = rng.permutation(n)
            for start in range(0, n - batch_size + 1, batch_size):
                idx = perm[start:start + batch_size]
                yield idx, self.batch(idx)

    # -- host baseline (Fig 1 traditional path) -------------------------------------
    def batch_recompute(self, row_idx: np.ndarray) -> np.ndarray:
        """Decode values + row-space transform + ship f32 — the CSV workflow."""
        outs = []
        for p in self._plans:
            aug = self.augmented[p.column]
            codes = p.codes[row_idx]
            for name in p.adv_names:
                outs.append(aug.featurize_recompute(name, codes))
        return np.concatenate(outs, axis=1)

    # -- data-movement accounting (paper's central claim) -----------------------------
    def bytes_moved_adv(self, batch_rows: int) -> int:
        """Host->device bytes on the ADV path: packed codes + amortized-0 tables.

        Code stream is the only per-batch traffic; the K-row fused tables are
        resident (moved once, amortized across all batches), matching the
        paper's 'dictionary created once ... easily amortized'.
        """
        return sum(packed_nbytes(batch_rows, p.bits) for p in self._plans)

    def bytes_moved_recompute(self, batch_rows: int) -> int:
        """Traditional path ships row-space f32 features."""
        return 4 * batch_rows * self.out_dim

    def bytes_resident_tables(self) -> int:
        return sum(int(p.fused_table.size) * 4 for p in self._plans)
