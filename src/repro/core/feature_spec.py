"""Declarative featurization specs (paper Table 6 as a config surface).

A :class:`FeatureSet` names which column gets which featurization(s) with which
parameters — the 'featurization methods stored and managed by the database'
of paper §7. ``build()`` materializes the ADVs on an AugmentedDictionary per
column and returns the pipeline-ready mapping.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.columnar.table import Table
from repro.core.adv import AugmentedDictionary


@dataclass(frozen=True)
class FeatureSpec:
    column: str
    kind: str                    # one of repro.core.adv._BUILDERS
    name: str | None = None      # ADV name; default f"{column}.{kind}"
    params: tuple = ()           # sorted (key, value) tuples for hashability

    @property
    def adv_name(self) -> str:
        return self.name or f"{self.column}.{self.kind}"

    def params_dict(self) -> dict[str, Any]:
        return dict(self.params)


def spec(column: str, kind: str, name: str | None = None, **params: Any) -> FeatureSpec:
    canon = tuple(sorted(
        (k, tuple(v) if isinstance(v, (list, np.ndarray)) else
         (tuple(sorted(v.items())) if isinstance(v, dict) else v))
        for k, v in params.items()))
    return FeatureSpec(column=column, kind=kind, name=name, params=canon)


@dataclass
class FeatureSet:
    specs: list[FeatureSpec] = field(default_factory=list)

    def add(self, column: str, kind: str, name: str | None = None,
            **params: Any) -> "FeatureSet":
        self.specs.append(spec(column, kind, name, **params))
        return self

    def columns(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self.specs:
            seen.setdefault(s.column)
        return list(seen)

    def build(self, table: Table) -> dict[str, AugmentedDictionary]:
        """Create/extend AugmentedDictionaries for every spec'd column."""
        out: dict[str, AugmentedDictionary] = {}
        for s in self.specs:
            col = table[s.column]
            aug = out.setdefault(s.column, AugmentedDictionary(col.dictionary))
            params = {k: (np.asarray(v) if isinstance(v, tuple) and k == "boundaries"
                          else (dict(v) if k == "mapping" else v))
                      for k, v in s.params_dict().items()}
            aug.add(s.adv_name, s.kind, **params)
        return out
