"""Analytics-cycle feedback (paper §7, Fig 2).

The paper's architecture stores learned artifacts back into the columnar
database so later analyses reuse them:

- trained embedding tables -> learned ADVs (``store_embedding``)
- model-inferred bucketizations (the 'ML G1 / DL G2' columns of Table 5)
  -> learned ADVs (``learn_bucketization``)
- feature importance/ranking feedback (``rank_features``)
"""
from __future__ import annotations

import numpy as np

from repro.core.adv import AugmentedDictionary


def store_embedding(aug: AugmentedDictionary, name: str,
                    table: np.ndarray, analysis: str = "") -> None:
    """Persist a trained (K, dim) embedding as a learned ADV for transfer reuse."""
    aug.add_learned(name, table, params={"analysis": analysis,
                                         "kind_hint": "embedding"})


def learn_bucketization(aug: AugmentedDictionary, name: str,
                        scores: np.ndarray, n_buckets: int,
                        analysis: str = "") -> np.ndarray:
    """Derive a new bucketization from per-dictionary-entry model scores.

    ``scores``: (K,) scalar the analysis assigned each dictionary value (e.g. a
    learned 1-d projection of its embedding, or its average predicted logit).
    Buckets are count-weighted quantiles of the scores, so each bucket holds
    roughly equal data mass — the paper's 'new bucketizations learned during
    the course of analysis'. Returns the (K,) bucket-index table written back.
    """
    scores = np.asarray(scores, np.float64).reshape(-1)
    counts = aug.dictionary.counts
    if scores.size != counts.size:
        raise ValueError("scores must have one entry per dictionary value")
    order = np.argsort(scores)
    cdf = np.cumsum(counts[order]) / max(counts.sum(), 1)
    bucket_of_sorted = np.minimum((cdf * n_buckets).astype(np.int64),
                                  n_buckets - 1)
    buckets = np.empty(scores.size, np.float32)
    buckets[order] = bucket_of_sorted.astype(np.float32)
    aug.add_learned(name, buckets,
                    params={"analysis": analysis, "n_buckets": n_buckets,
                            "kind_hint": "bucketize"})
    return buckets


def rank_features(grads: dict[str, np.ndarray]) -> list[tuple[str, float]]:
    """Feature importance from gradient magnitudes (paper §7 'importance/
    ranking/relevance of each feature'). Input: feature-name -> grad slice."""
    scores = {name: float(np.sqrt(np.mean(np.square(g))))
              for name, g in grads.items()}
    return sorted(scores.items(), key=lambda kv: -kv[1])
