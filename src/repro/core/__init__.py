"""The paper's primary contribution: Augmented Dictionary Values (ADVs).

- :mod:`repro.core.adv` — ADV columns attached to columnar dictionaries
- :mod:`repro.core.feature_spec` — declarative featurization specs (Table 6)
- :mod:`repro.core.pipeline` — FeaturePipeline: columnar table -> device
  feature batches via fused ADV gathers (minimal data movement)
- :mod:`repro.core.feedback` — analytics-cycle write-back (paper §7)
"""
from repro.core.adv import AugmentedDictionary, ADV
from repro.core.feature_spec import FeatureSpec, FeatureSet
from repro.core.pipeline import (FeaturePipeline, FeaturePlan,
                                 FeatureExecutor, ShardedFeatureExecutor)

__all__ = ["AugmentedDictionary", "ADV", "FeatureSpec", "FeatureSet",
           "FeaturePipeline", "FeaturePlan", "FeatureExecutor",
           "ShardedFeatureExecutor"]
