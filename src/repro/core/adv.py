"""Augmented Dictionary Values (paper §6.3) — the core innovation.

An :class:`AugmentedDictionary` wraps a columnar :class:`Dictionary` and
attaches named ADV columns: per-dictionary-entry precomputed feature values
stored in the floating-point format the consuming ML/DL algorithm needs
(paper Table 4/5 — 'populated with floating-point numbers of the type that can
be directly used by the algorithms without conversion').

Featurizing N rows is then ``adv_table[codes]`` — a K-row gather, executed on
device by ``repro.kernels.adv_gather``. Multiple alternative featurizations
(e.g. two bucketizations of the same column, Table 4) coexist as sibling ADVs,
and learned artifacts (embeddings, model-derived buckets) are written back as
new ADVs by :mod:`repro.core.feedback` (paper §7).

Each ADV also carries the distribution statistics the paper suggests
(entropy/diversity/peculiarity, §6.3) for feature-interest ranking.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.columnar.dictionary import Dictionary
from repro.columnar import featurize as F


@dataclass
class ADV:
    """One augmented dictionary value column."""
    name: str
    table: np.ndarray            # (K,) or (K, F) float32 — code -> feature row
    kind: str                    # 'float'|'minmax'|'zscore'|...|'embedding'|'learned'
    params: dict = field(default_factory=dict)
    learned: bool = False        # True if produced by the analytics cycle (§7)

    def __post_init__(self) -> None:
        self.table = np.asarray(self.table, dtype=np.float32)
        if self.table.ndim == 1:
            self.table = self.table[:, None]

    @property
    def dim(self) -> int:
        return int(self.table.shape[1])

    @property
    def cardinality(self) -> int:
        return int(self.table.shape[0])

    # -- §6.3 'statistical measures of its data distribution' -------------------
    def interest_stats(self, counts: np.ndarray) -> dict[str, float]:
        p = counts / max(counts.sum(), 1)
        ent = float(-(p[p > 0] * np.log2(p[p > 0])).sum())
        flat = self.table[:, 0]
        uniq = np.unique(flat)
        diversity = uniq.size / max(flat.size, 1)
        # 'peculiarity': weighted distance of a value's feature from the
        # count-weighted mean, normalized by std — flags rare-but-extreme codes.
        mu = float(np.dot(flat, p))
        sd = float(np.sqrt(np.dot((flat - mu) ** 2, p))) or 1.0
        peculiarity = float(np.max(np.abs(flat - mu)) / sd)
        return {"entropy": ent, "diversity": diversity,
                "peculiarity": peculiarity}


# featurizations whose tables depend on the count distribution, not just the
# value set: duplicate-value inserts (cardinality unchanged) still shift
# their normalization constants, so maintenance must rebuild them whenever
# the dictionary version moved — not only when it grew
_COUNT_SENSITIVE = {"mean_norm", "zscore", "quantile"}

_BUILDERS: dict[str, Callable[..., np.ndarray]] = {
    "float": F.to_float,
    "minmax": F.minmax_scale,
    "mean_norm": F.mean_normalize,
    "zscore": F.zscore,
    "log": F.log_scale,
    "onehot": F.onehot,
    "binarize": F.binarize,
    "quantile": F.quantile_bucket,
    "hash_bucket": F.hash_bucket,
    "bucketize": F.bucketize,
    "bucketize_cat": F.bucketize_categorical,
    "embedding": F.embedding_init,
}


class AugmentedDictionary:
    """Dictionary + named ADV columns + maintenance under inserts (§6.3)."""

    def __init__(self, dictionary: Dictionary):
        self.dictionary = dictionary
        self.advs: dict[str, ADV] = {}
        # bumped on any ADV mutation; feature plans compare it to decide
        # whether their device-resident fused tables need a refresh
        self.version = 0
        self._built_at: dict[str, int] = {}    # adv name -> dictionary.version

    # -- creation ---------------------------------------------------------------
    def add(self, name: str, kind: str, **params: Any) -> ADV:
        if name in self.advs:
            raise KeyError(f"ADV {name!r} already exists")
        builder = _BUILDERS.get(kind)
        if builder is None:
            raise KeyError(f"unknown featurization kind {kind!r}; "
                           f"known: {sorted(_BUILDERS)}")
        table = builder(self.dictionary, **params)
        adv = ADV(name=name, table=table, kind=kind, params=params)
        self.advs[name] = adv
        self._built_at[name] = self.dictionary.version
        self.version += 1
        return adv

    def add_learned(self, name: str, table: np.ndarray,
                    params: dict | None = None) -> ADV:
        """Write-back path for the analytics cycle (paper §7): store an
        artifact learned during training as a first-class ADV."""
        adv = ADV(name=name, table=np.asarray(table, np.float32),
                  kind="learned", params=params or {}, learned=True)
        if adv.cardinality != self.dictionary.cardinality:
            raise ValueError(
                f"learned ADV rows {adv.cardinality} != dictionary "
                f"cardinality {self.dictionary.cardinality}")
        self.advs[name] = adv
        self.version += 1
        return adv

    def __getitem__(self, name: str) -> ADV:
        return self.advs[name]

    def __contains__(self, name: str) -> bool:
        return name in self.advs

    # -- the fast path (what the paper is about) ----------------------------------
    def featurize(self, name: str, codes: np.ndarray) -> np.ndarray:
        """Row-space features via ADV gather: out[i] = adv.table[codes[i]].

        Host/numpy reference; the device path is kernels/adv_gather (Pallas).
        """
        return self.advs[name].table[np.asarray(codes)]

    def featurize_many(self, names: list[str], codes: np.ndarray) -> np.ndarray:
        """Fused multi-ADV gather: one pass over codes, concatenated features.

        This is the 'single efficient step' of paper §6: K-row tables are
        concatenated once (dictionary-domain, cheap), then one gather serves
        every requested featurization.
        """
        fused = np.concatenate([self.advs[n].table for n in names], axis=1)
        return fused[np.asarray(codes)]

    def fused_table(self, names: list[str]) -> np.ndarray:
        return np.concatenate([self.advs[n].table for n in names], axis=1)

    # -- recompute baseline (what the paper replaces) ------------------------------
    def featurize_recompute(self, name: str, codes: np.ndarray) -> np.ndarray:
        """Row-space recompute: decode values then transform every row.

        Benchmark baseline modeling the traditional CSV-export pipeline
        (paper Fig 1): value decode + row-space arithmetic. Normalization
        constants come from full-column statistics (as a real preprocessing
        pass would), so outputs match the ADV path bit-for-bit-ish.
        """
        adv = self.advs[name]
        codes = np.asarray(codes)
        d = self.dictionary
        kind, params = adv.kind, adv.params
        if kind in ("embedding", "learned"):
            return adv.table[codes]                     # no row-space analogue
        if kind == "onehot":
            return F.onehot_rows(codes, d.cardinality)
        values = d.decode(codes)                        # N-row value materialize
        if kind == "float":
            out = values.astype(np.float32)
        elif kind == "minmax":
            v = values.astype(np.float64)
            lo, hi = float(d.vmin), float(d.vmax)
            out = (v - lo) / ((hi - lo) or 1.0)
        elif kind == "mean_norm":
            v = values.astype(np.float64)
            lo, hi = float(d.vmin), float(d.vmax)
            out = (v - d.mean()) / ((hi - lo) or 1.0)
        elif kind == "zscore":
            out = (values.astype(np.float64) - d.mean()) / (d.std() or 1.0)
        elif kind == "log":
            out = np.log1p(values.astype(np.float64))
        elif kind == "binarize":
            out = values.astype(np.float64) > params["threshold"]
        elif kind == "quantile":
            edges = d.quantile_edges(params["q"])
            out = np.searchsorted(edges, values.astype(np.float64),
                                  side="right")
        elif kind == "hash_bucket":
            # hash each row value (the whole point is ADV hashes only K values)
            row_table = F.hash_bucket(d, **params)
            out = row_table[codes][:, 0] if row_table.ndim > 1 else row_table[codes]
        elif kind == "bucketize":
            b = np.asarray(params["boundaries"], np.float64)
            out = np.searchsorted(b, values.astype(np.float64), side="right")
        elif kind == "bucketize_cat":
            mapping = params["mapping"]
            default = params.get("default", 0.0)
            out = np.array([float(mapping.get(v, default))
                            for v in values.tolist()])
        else:
            raise KeyError(kind)
        out = np.asarray(out, np.float32)
        return out[:, None] if out.ndim == 1 else out

    # -- maintenance (§6.3: inserts/updates/deletes) --------------------------------
    def extend_for_new_codes(self) -> None:
        """After Dictionary.add_rows/remove_rows, bring derived ADVs up to
        date: grown dictionaries get their tables recomputed for the new tail
        (learned ADVs get zero rows until next feedback), and count-sensitive
        featurizations (zscore etc.) rebuild even when cardinality is
        unchanged — duplicate-value inserts shift their statistics too."""
        k = self.dictionary.cardinality
        dv = self.dictionary.version
        changed = False
        for adv in self.advs.values():
            stale_counts = (not adv.learned
                            and adv.kind in _COUNT_SENSITIVE
                            and self._built_at.get(adv.name) != dv)
            if adv.cardinality == k and not stale_counts:
                continue
            changed = True
            if adv.learned:
                pad = np.zeros((k - adv.cardinality, adv.dim), np.float32)
                adv.table = np.concatenate([adv.table, pad], axis=0)
            else:
                fresh = _BUILDERS[adv.kind](self.dictionary, **adv.params)
                fresh = np.asarray(fresh, np.float32)
                if fresh.ndim == 1:
                    fresh = fresh[:, None]
                adv.table = fresh
                self._built_at[adv.name] = dv
        if changed:
            self.version += 1

    # -- reporting ---------------------------------------------------------------
    def summary(self) -> str:
        d = self.dictionary
        lines = [f"AugmentedDictionary[{d.name}: K={d.cardinality}, "
                 f"bits={d.bits}, rows={d.n_rows}]"]
        for adv in self.advs.values():
            stats = adv.interest_stats(d.counts)
            lines.append(f"  ADV {adv.name}: kind={adv.kind} dim={adv.dim} "
                         f"learned={adv.learned} entropy={stats['entropy']:.2f} "
                         f"diversity={stats['diversity']:.2f}")
        return "\n".join(lines)
