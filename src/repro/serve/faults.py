"""Fault layer for the serving stack: typed errors, policy, chaos injection.

A production front door cannot die because one launch threw. This module
holds the pieces the pump in :mod:`repro.serve.feature_service` uses to
keep serving through faults:

- **Typed per-ticket errors.** A launch group that keeps failing resolves
  its tickets to :class:`ServeError` (surfaced by ``poll``/``result``/
  ``collect`` per-ticket — never by killing the service); a request whose
  ``deadline_ms`` expires before launch resolves to
  :class:`DeadlineExceeded` (also a :class:`TimeoutError`, so generic
  timeout handling catches it). Both chain the underlying cause via
  ``__cause__``.
- **FaultPolicy.** One knob bundle for the pump's recovery machinery:
  retry count, capped exponential backoff, circuit-breaker thresholds and
  probe cooldown, straggler-detector tuning. Defaults are production-ish;
  tests shrink the time constants.
- **Circuit breaker** (:class:`StreamBreaker`): per launch stream
  (primary or replica executor). ``breaker_fails`` CONSECUTIVE failures —
  thrown launches or straggler strikes — open it for ``cooldown_s``;
  while open the pump routes the shard's launches to its other streams
  (replicas as an availability mechanism, not just a throughput one).
  After the cooldown the stream is half-open: the round-robin's next
  launch is the probe, success closes the breaker, failure re-opens it.
- **Device health** (:class:`DeviceHealth`): one step up from breakers —
  a per-DEVICE view of repeated launch failures. Every breaker TRIP is
  attributed to the failing stream's device; ``device_fails`` consecutive
  trips (no successful round trip in between) declare the device DOWN, as
  does a single :class:`DeviceDown` error (the injectable 'device died
  outright' fault). A down device's resident streams get evicted and
  rebuilt on a healthy device from the host packed words — the service's
  device-loss recovery path.
- **FaultInjector**: the deterministic, seed-driven chaos harness. Wired
  into the pump behind a no-op default (``faults=None`` costs one
  ``is None`` test per launch), it evaluates script rules against every
  launch: fail the next N launches of shard k (optionally only stream r —
  'fail replica r N times then heal'), fire on every j-th matching launch
  (periodic faults), delay a launch (straggler simulation), STALL a
  launch's retire (async straggler — the readiness gate hedged launches
  race against, without blocking the pump the way a delay does), kill a
  device outright (every launch touching it raises :class:`DeviceDown`
  until revived), plus a seed-driven random mode for the nightly chaos
  sweep. Injection happens ON the pump's launch path before dispatch, so
  an injected fault takes exactly the recovery path a real device error
  takes.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np


class ServeError(RuntimeError):
    """A ticket's request failed (launch faults exhausted their retries).

    Carries the failure's serving context: ``ticket``, owning ``shard``,
    and ``attempts`` (launch tries including the first). The underlying
    device/injection error is chained as ``__cause__``. The service stays
    up: only this ticket resolved to an error.
    """

    def __init__(self, msg: str, *, ticket: int | None = None,
                 shard: int | None = None, attempts: int = 0):
        super().__init__(msg)
        self.ticket = ticket
        self.shard = shard
        self.attempts = attempts


class DeadlineExceeded(ServeError, TimeoutError):
    """A ticket's ``deadline_ms`` expired before its chunks launched.

    Subclasses :class:`TimeoutError` too, so callers that only distinguish
    'timed out' from 'failed' can catch the builtin."""


class InjectedFault(RuntimeError):
    """The error a :class:`FaultInjector` 'fail' rule raises on the launch
    path — stands in for a real device/runtime error in chaos tests."""


class DeviceDown(RuntimeError):
    """A launch touched a device that is gone (injected via
    :meth:`FaultInjector.kill_device`, or raised by a real runtime when
    the accelerator drops off the bus). Unlike a transient launch fault,
    ONE of these marks the whole device down: every resident stream on it
    is evicted and rebuilt elsewhere rather than retried in place."""


@dataclass
class FaultPolicy:
    """Recovery knobs for the serving pump (see module docstring).

    ``max_retries`` bounds a chunk's RE-launches (so a chunk is attempted
    at most ``1 + max_retries`` times); backoff between retries is
    ``backoff_s * 2**(attempt-1)`` capped at ``backoff_cap_s``, and is
    skipped entirely when another healthy stream of the shard can take the
    retry immediately (replica failover). ``breaker_fails`` consecutive
    failures open a stream's breaker for ``breaker_cooldown_s``.
    Stragglers: a launch flagged by the per-shard
    :class:`repro.train.fault.StragglerDetector` (EWMA + ``threshold``
    sigma, ``warmup`` samples) counts as a breaker strike when it took at
    least ``straggler_min_s`` — the absolute floor keeps scheduler jitter
    on fast hosts from striking healthy streams.

    Device loss: ``device_fails`` CONSECUTIVE breaker trips attributed to
    one device (no successful round trip on it in between) declare the
    device down; a :class:`DeviceDown` error does so immediately. The
    pump supervisor restarts a crashed pump loop (ledger intact) at most
    ``pump_restarts`` times; past the budget the crash is terminal, the
    pre-supervisor behavior. Hedging: once a retire wait on a launch
    exceeds ``max(hedge_min_s, hedge_factor x the shard's EWMA round-trip
    mean)`` and the shard has another healthy stream, a duplicate launch
    races the straggler (first retire wins); ``hedge=False`` turns the
    speculation off (the no-hedge benchmark baseline).
    """
    max_retries: int = 3
    backoff_s: float = 0.02
    backoff_cap_s: float = 0.5
    breaker_fails: int = 3
    breaker_cooldown_s: float = 0.25
    straggler_threshold: float = 3.0
    straggler_warmup: int = 5
    straggler_min_s: float = 0.05
    device_fails: int = 3
    pump_restarts: int = 2
    hedge: bool = True
    hedge_factor: float = 4.0
    hedge_min_s: float = 0.05

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if min(self.backoff_s, self.backoff_cap_s) < 0:
            raise ValueError("backoff must be >= 0")
        if self.breaker_fails < 1:
            raise ValueError("breaker_fails must be >= 1")
        if self.breaker_cooldown_s < 0:
            raise ValueError("breaker_cooldown_s must be >= 0")
        if self.device_fails < 1:
            raise ValueError("device_fails must be >= 1")
        if self.pump_restarts < 0:
            raise ValueError("pump_restarts must be >= 0")
        if self.hedge_factor < 1.0 or self.hedge_min_s < 0:
            raise ValueError("hedge_factor must be >= 1 and "
                             "hedge_min_s >= 0")

    def backoff_for(self, attempt: int) -> float:
        """Capped exponential backoff before retry number ``attempt``."""
        return min(self.backoff_s * (2.0 ** max(attempt - 1, 0)),
                   self.backoff_cap_s)


@dataclass
class StreamBreaker:
    """Per-launch-stream circuit breaker state (owned by the service, one
    per executor id; mutated only under the service lock)."""
    fails: int = 0              # consecutive failures / straggler strikes
    open_until: float = 0.0    # perf_counter deadline while open
    opened: int = 0             # times this breaker tripped (stats)

    def is_open(self, threshold: int, now: float) -> bool:
        """Open = skip this stream (unless it is the only one). Past
        ``open_until`` the stream is half-open: selectable again, and the
        first launch routed to it is the recovery probe."""
        return self.fails >= threshold and now < self.open_until

    def strike(self, threshold: int, cooldown_s: float,
               now: float) -> bool:
        """Record one failure; returns True when this strike TRIPPED the
        breaker closed->open (the moment a stream turns unhealthy)."""
        self.fails += 1
        if self.fails < threshold:
            return False
        self.open_until = now + cooldown_s      # probe failure re-opens
        tripped = self.fails == threshold
        if tripped:
            self.opened += 1
        return tripped

    def reset(self) -> None:
        """A round trip completed on this stream — healthy again."""
        self.fails = 0
        self.open_until = 0.0


@dataclass
class DeviceHealth:
    """Per-device failure attribution, one step above stream breakers.

    Owned by the service, keyed by ``id(device)``, mutated only under the
    service lock. Breaker trips feed :meth:`strike`; a successful round
    trip on the device feeds :meth:`ok` (consecutive counting — a device
    that intersperses successes is sick streams, not dead hardware); a
    :class:`DeviceDown` error feeds :meth:`mark_down` directly. Once a
    device is down it STAYS down for the service's lifetime (its streams
    are rebuilt elsewhere; re-admitting flapping hardware is an operator
    decision, not an automatic one — :meth:`revive` exists for tests and
    tooling)."""
    trips: dict = field(default_factory=dict)   # id(device) -> consecutive
    down: set = field(default_factory=set)      # id(device) declared dead
    lost: int = 0                               # devices declared dead ever

    def strike(self, dev_id: int, threshold: int) -> bool:
        """One breaker trip attributed to ``dev_id``; True when this trip
        crossed ``threshold`` and newly declared the device down."""
        if dev_id in self.down:
            return False
        n = self.trips.get(dev_id, 0) + 1
        self.trips[dev_id] = n
        return n >= threshold and self.mark_down(dev_id)

    def ok(self, dev_id: int) -> None:
        """A launch retired successfully on this device — not dead."""
        self.trips.pop(dev_id, None)

    def mark_down(self, dev_id: int) -> bool:
        """Declare the device dead; True when it was alive until now."""
        if dev_id in self.down:
            return False
        self.down.add(dev_id)
        self.trips.pop(dev_id, None)
        self.lost += 1
        return True

    def is_down(self, dev_id: int) -> bool:
        return dev_id in self.down

    def revive(self, dev_id: int) -> None:
        self.down.discard(dev_id)
        self.trips.pop(dev_id, None)

    def survivors(self, devices) -> list:
        """The pool minus down devices — where rebuilds may land (empty
        when every device is gone: serving falls back to host gathers)."""
        return [d for d in devices if id(d) not in self.down]


@dataclass
class _Rule:
    kind: str                   # 'fail' | 'delay' | 'stall'
    shard: int | None           # None = any shard
    stream: int | None          # None = any stream of the shard
    remaining: int              # firings left (rule heals at 0)
    after: int = 0              # matching launches to skip first
    every: int = 1              # fire on every j-th matching launch
    delay_s: float = 0.0
    seen: int = 0               # matching launches observed so far
    klass: str | None = None    # None = any request class


class FaultInjector:
    """Deterministic, seed-driven launch-fault injection for chaos tests.

    Scripted rules fire in registration order, at most one per launch
    (deterministic given the launch sequence). ``seed`` drives the random
    mode only; scripted rules need no randomness at all.

    Thread-safe: the pump calls :meth:`before_launch` outside the service
    lock (delays must not stall clients touching service state), so the
    injector guards its own counters.
    """

    def __init__(self, seed: int | None = None):
        self._rng = np.random.default_rng(seed)
        self._rules: list[_Rule] = []
        self._random: dict | None = None
        self._dead_devices: set[int] = set()
        self._lock = threading.Lock()
        self.launches_seen = 0
        self.faults_injected = 0
        self.delays_injected = 0
        self.stalls_injected = 0
        self.device_faults = 0

    # -- scripting -----------------------------------------------------------------
    def fail_launches(self, n: int = 1, *, shard: int | None = None,
                      stream: int | None = None, after: int = 0,
                      every: int = 1,
                      klass: str | None = None) -> "FaultInjector":
        """Fail the next ``n`` matching launches (then heal). ``shard``/
        ``stream`` restrict the blast radius ('fail replica ``stream`` of
        shard k ``n`` times then heal'); ``klass`` restricts to launches
        serving one request class ('fail only batch-class groups' — the
        front door's per-class chaos axis); ``after`` skips that many
        matching launches first; ``every=j`` fires on every j-th match
        (periodic faults). Returns self for chaining."""
        self._rules.append(_Rule("fail", shard, stream, n, after, every,
                                 klass=klass))
        return self

    def delay_launches(self, seconds: float, n: int = 1, *,
                       shard: int | None = None, stream: int | None = None,
                       after: int = 0, every: int = 1,
                       klass: str | None = None) -> "FaultInjector":
        """Sleep ``seconds`` on the next ``n`` matching launches —
        straggler simulation (the launch SUCCEEDS, late)."""
        self._rules.append(_Rule("delay", shard, stream, n, after, every,
                                 delay_s=seconds, klass=klass))
        return self

    def stall_launches(self, seconds: float, n: int = 1, *,
                       shard: int | None = None, stream: int | None = None,
                       after: int = 0, every: int = 1,
                       klass: str | None = None) -> "FaultInjector":
        """ASYNC straggler: the next ``n`` matching launches dispatch
        normally but their result buffers are treated as not-ready for
        ``seconds`` (the service gates the retire on the stall). Unlike
        :meth:`delay_launches` the pump keeps running — this is the slow
        device compute a hedged duplicate launch can actually race and
        beat, where a delay blocks the dispatcher itself."""
        self._rules.append(_Rule("stall", shard, stream, n, after, every,
                                 delay_s=seconds, klass=klass))
        return self

    def kill_device(self, device) -> "FaultInjector":
        """Kill ``device``: every subsequent launch dispatched to it
        raises :class:`DeviceDown` (persistently, until
        :meth:`revive_device`) — the 'accelerator fell off the bus' fault
        the device-loss recovery path evicts and rebuilds around."""
        with self._lock:
            self._dead_devices.add(id(device))
        return self

    def revive_device(self, device) -> "FaultInjector":
        """Heal a killed device (injection stops; whether the service
        trusts it again is the service's DeviceHealth policy, not ours)."""
        with self._lock:
            self._dead_devices.discard(id(device))
        return self

    def random_faults(self, p_fail: float = 0.0, p_delay: float = 0.0,
                      delay_s: float = 0.05,
                      max_events: int | None = None) -> "FaultInjector":
        """Seed-driven random mode for sweep harnesses: every launch
        draws once; ``u < p_fail`` fails it, ``u < p_fail + p_delay``
        delays it. Deterministic for a given seed and launch sequence."""
        if not 0 <= p_fail + p_delay <= 1:
            raise ValueError("p_fail + p_delay must be within [0, 1]")
        self._random = {"p_fail": p_fail, "p_delay": p_delay,
                        "delay_s": delay_s, "left": max_events}
        return self

    # -- the pump-side hook --------------------------------------------------------
    def _match(self, rule: _Rule, shard: int, stream: int,
               klass: str | None) -> bool:
        if rule.remaining <= 0:
            return False
        if rule.shard is not None and rule.shard != shard:
            return False
        if rule.klass is not None and rule.klass != klass:
            return False
        return rule.stream is None or rule.stream == stream

    def before_launch(self, shard: int, stream: int,
                      device=None, klass: str | None = None) -> float:
        """Called by the pump for every launch, BEFORE dispatch: (shard,
        stream index within the shard — 0 is the primary, i>0 replica
        i-1, ``device`` the stream's placement, ``klass`` the request
        class of the group being launched). May sleep (delay rule) or
        raise (:class:`InjectedFault` fail rules; :class:`DeviceDown` when
        the device was killed). Returns the launch's injected STALL in
        seconds (0.0 normally) — the service gates the launch's retire on
        it, simulating slow device compute without blocking the pump."""
        delay = 0.0
        stall = 0.0
        fail = None
        with self._lock:
            self.launches_seen += 1
            if device is not None and id(device) in self._dead_devices:
                self.device_faults += 1
                raise DeviceDown(
                    f"injected device loss under shard {shard} "
                    f"stream {stream}")
            for rule in self._rules:
                if not self._match(rule, shard, stream, klass):
                    continue
                rule.seen += 1
                if rule.seen <= rule.after or \
                        (rule.seen - rule.after) % rule.every:
                    continue
                rule.remaining -= 1
                if rule.kind == "fail":
                    self.faults_injected += 1
                    fail = InjectedFault(
                        f"injected launch fault on shard {shard} "
                        f"stream {stream}")
                elif rule.kind == "stall":
                    self.stalls_injected += 1
                    stall = rule.delay_s
                else:
                    self.delays_injected += 1
                    delay = rule.delay_s
                break                           # one rule per launch
            rnd = self._random
            if fail is None and not delay and not stall \
                    and rnd is not None and \
                    (rnd["left"] is None or rnd["left"] > 0):
                u = float(self._rng.random())
                if u < rnd["p_fail"]:
                    self.faults_injected += 1
                    if rnd["left"] is not None:
                        rnd["left"] -= 1
                    fail = InjectedFault(
                        f"random launch fault on shard {shard} "
                        f"stream {stream}")
                elif u < rnd["p_fail"] + rnd["p_delay"]:
                    self.delays_injected += 1
                    if rnd["left"] is not None:
                        rnd["left"] -= 1
                    delay = rnd["delay_s"]
        if delay:
            import time
            time.sleep(delay)
        if fail is not None:
            raise fail
        return stall
