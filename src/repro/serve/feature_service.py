"""FeatureService: pump-driven, coalescing, mesh-shardable ADV serving.

The serving-side rendering of the paper's §6 pipeline: learned features are
served directly out of the data system ('codes in, features out'), not
exported and recomputed. A request names table rows; the service chunks it
to static bucket shapes (the same trick :class:`repro.serve.engine.ServeEngine`
uses for token batches, so jit compiles once per bucket) and queues the
chunks on the launch queue of the shard that owns their rows.

Serving architecture (request -> route -> per-shard coalescer -> one
multiplexing pump -> per-shard launch streams)::

    submit(rows) --route by IMCU--> [shard 0 queue] --group--\\   pump
                               \\--> [shard 1 queue] --group--->  (one
                                          ...                /   thread)
                 launch async on dev 0 / dev 1 / ... <-------/
              results <-- retire into per-ticket buffers (request order)

Unsharded services have exactly one queue (the PR 3 architecture,
unchanged); ``sharded=True`` over a packed plan builds one
:class:`repro.core.ShardedFeatureExecutor` — per-IMCU resident word-stream
shards, each committed to its own mesh device. A request's rows are
bucketed by owning IMCU on host at submit time (whole-request fast path
when one shard owns them all — the clustered 'user block' pattern); each
shard's queue coalesces up to ``coalesce`` same-bucket chunks into ONE
launch against its local shard, with ``prefetch`` launches in flight *per
shard*, so independent shards' gathers run concurrently on their own
devices instead of serializing through one launch stream. ONE pump thread
multiplexes every stream — launches dispatch asynchronously, so the
devices overlap while the pump runs ahead; a thread per shard would fight
the client for the GIL on exactly the small-core hosts that need the
overlap most (measured 0.3-0.6x; dispatch is the cheap part). Results are
reassembled in request order via per-chunk destination maps.

Packed serving ships indices only: every chunk — word-aligned range or
arbitrary row set — is served by the indexed gather
(:meth:`FeatureExecutor._rows_future`): the kernel computes word index +
bit offset against the resident streams, so the per-launch host->device
traffic is the padded (coalesce x bucket) int32 index vector.
``stats['bytes_h2d']`` therefore reports INDEX bytes; int32 plans still
ship (C, bucket) code slices and account those. Per-shard attribution
lives in ``stats['shard_launches'] / ['shard_batches'] /
['shard_bytes_h2d']`` (lists indexed by shard, summing to the totals).

``linger_us`` adds a latency-aware pump policy (bounded-latency
coalescing): under light load a pump may hold a PARTIAL launch group open
until the group's oldest chunk has been queued ``linger_us`` microseconds,
trading that bounded wait for fuller groups (backpressure already grows
groups under heavy load, so lingering only ever engages when the queue is
shallow). ``linger_us=0`` (default) launches whatever is queued per tick —
the PR 3 behavior.

``pause``/``resume`` hold launches (queueing continues) so callers can
force maximal coalescing; ``shutdown`` (also via the context-manager
protocol) drains the queues and joins every pump thread. Services hold
live threads — call :meth:`shutdown` (or use ``with``) when disposing of
one.

Adaptive shard management (mesh mode): the shard set is no longer frozen
at plan-build time. A load monitor fed by the per-shard stats deltas
(request-rate EWMA over ``stats['shard_batches']``) drives two policies —

- **hot-shard replication with read fan-out**: when one shard's EWMA runs
  ``hot_factor`` x the mean of the OTHER shards' (so the threshold stays
  reachable at any shard count), its resident word stream is replicated to
  the least-loaded device and the pump round-robins that shard's launches
  across the copies. Each replica stream brings its own ``prefetch``-deep
  in-flight window, so a hot shard's aggregate service capacity (launch
  windows x devices) scales with replicas; a ``refresh()`` write
  invalidates every copy for free because replicas re-sync from the
  parent plan's versioned words at their next launch. Cold shards shed
  replicas again (EWMA below the mean).
- **tail re-shard**: streaming appends extend only the open tail shard;
  past ``row_budget`` rows the tail is split at a word-aligned cut, the
  new shard's stream slice is committed to an under-loaded device, and
  the routing table (bisect bounds + per-shard queues + stats lanes) is
  swapped atomically — queued chunks of the old tail are re-routed (and
  split when they straddle the cut) under the service lock, so no
  in-flight ticket is dropped, reordered, or served from the wrong slice.

Both policies run ONLY on the pump thread (the sole launcher), either
automatically every ``rebalance_every`` launches or on demand via
:meth:`rebalance` / :meth:`add_replica` / :meth:`drop_replica` /
:meth:`split_tail`, which marshal onto the pump and block for the result —
so a shard-set mutation can never race a launch that is being dispatched.

Fault tolerance (launch-level isolation, replica failover, deadlines):
an exception during a launch or its retire fails ONLY the chunks of that
launch group — every other shard and queued request keeps serving, and
only errors in the pump's own control logic (outside the guarded launch/
retire paths) remain terminal. A failed group re-enqueues at the head of
its shard's queue with capped exponential backoff
(:class:`repro.serve.faults.FaultPolicy`); each chunk remembers the
streams it already failed on, so on a shard with replicas the retry
routes to a DIFFERENT copy immediately (no backoff — replica failover
turns replication into an availability mechanism). A stream that keeps
failing — thrown launches or straggler-flagged latencies (the per-shard
:class:`repro.train.fault.StragglerDetector` over launch round-trip
times) — opens its circuit breaker: the pump stops routing to it until a
cooldown passes, then the next round-robin launch is the recovery probe;
the monitor's third policy re-replicates shards whose streams are
unhealthy onto devices that are not. Retries exhausted, the affected
tickets resolve to a typed :class:`repro.serve.faults.ServeError`
surfaced per-ticket by :meth:`poll`/:meth:`result`/:meth:`collect` — the
service itself stays up and keeps accepting submits. ``deadline_ms`` on
:meth:`submit` evicts a request's still-queued chunks once expired (the
ticket resolves to :class:`repro.serve.faults.DeadlineExceeded`, also a
``TimeoutError``), and ``timeout=`` on :meth:`result`/:meth:`collect`/
:meth:`drain` bounds every blocking wait. The chaos harness
(:class:`repro.serve.faults.FaultInjector`, ``faults=`` — no-op by
default) injects deterministic failures and straggler delays ON the
launch path, so injected faults exercise exactly the recovery machinery
real device errors would.

Fault tolerance, phase 2 (component death, not just launch faults):

- **Device-loss recovery.** Launch failures are attributed to the
  failing stream's DEVICE (:class:`repro.serve.faults.DeviceHealth`):
  ``device_fails`` consecutive breaker trips — or one
  :class:`repro.serve.faults.DeviceDown` error — declare the device
  dead. The service then evicts every resident stream on it
  (:meth:`ShardedFeatureExecutor.evict_device` — replicas dropped,
  orphaned primaries promoted from surviving replicas) and shards left
  with NO live stream enter emergency rebuild: the monitor's fourth
  policy (and the pump, as soon as it has a free beat) re-commits the
  shard's word stream on a surviving device from the HOST packed words
  through the same version-keyed put path a refresh uses. Until the
  rebuild lands, the shard's queued chunks are served through the
  host-gather slow path (:meth:`FeaturePlan.host_features` — bit-exact
  with the device gather by construction), so availability holds at 1.0
  even with EVERY device dead.
- **Supervised pump restart.** The pump thread runs under a supervisor:
  a pump-infrastructure exception (control logic, not a guarded launch)
  no longer kills the service — the supervisor restarts the pump loop
  with the ledger intact (queues, in-flight windows, tickets, admin
  queue), re-enqueueing any group the dying pump had taken but not
  finished, up to ``FaultPolicy.pump_restarts`` times; past the budget
  the crash is terminal exactly like before. Blocking entry points
  (``result``/``drain``/``collect``/``poll``) already poll on 0.5 s
  ticks, so a restart is invisible to them.
- **Speculative hedged launches.** A retire wait that outlives
  ``max(hedge_min_s, hedge_factor x the shard's EWMA round-trip)`` (and
  a warmed-up detector) dispatches a DUPLICATE of the launch group on a
  different healthy stream of the same shard — the
  :class:`repro.train.fault.StragglerDetector` backup-worker idiom at
  serving granularity. First buffer to come ready resolves the tickets;
  the loser is discarded unread and never double-counts launch stats
  (only ``hedges``/``hedge_wins``). A hedge win also strikes the
  straggling primary's breaker, feeding the same unhealthy-stream
  machinery as a thrown launch.

Tiered residency (HBM-hot / host-warm / RLE-cold, ``hbm_budget_bytes``):
mesh services are no longer capped at tables that fit HBM. Every shard
carries a residency TIER — **hot** (device-resident packed words,
today's path), **warm** (host packed words only; served through the
host-gather path device loss already uses), **cold** (RLE runs from
:mod:`repro.columnar.rle`; the host packed copy is dropped too and
rehydrated on promotion) — and the load monitor moves shards up and
down the ladder under a per-device HBM byte budget
(:class:`repro.distributed.sharding.DeviceBudget`; live bytes are
always measured from the buffers actually held, never a drifting
ledger). Construction commits shards in order until the budget is
spent; the rest start warm. A request for an off-device shard is a
**tier miss**: it serves IMMEDIATELY through the host path — bit-exact
with the device gather by construction — and marks the shard
promotion-pending; promotion itself is ASYNCHRONOUS on the pump (a
free-beat action, like emergency rebuilds), re-committing the stream
through the same version-keyed put a refresh uses, displacing the
coldest resident shard first when the device is full (EWMA order,
strict — equal-heat shards never thrash). Warm shards quiet for
``cold_after`` consecutive monitor ticks compress to RLE runs. The
host-gather path itself fans a multi-chunk group out over a small
thread pool (``host_gather_workers``), cutting the miss-window p99.
Tier state: ``stats['tier_hot'/'tier_warm'/'tier_cold']`` (gauges),
``promotions``/``demotions``/``rehydrations``/``tier_misses``
(counters), :attr:`tiers`, :meth:`device_bytes`, and manual
:meth:`promote`/:meth:`demote` (admin actions on the pump, like every
shard-set mutation). Replication, device-loss rebuild and pushdown all
compose: policies skip off-device shards, a dead device's demoted
shards stay demoted (no rebuild — they were host-served anyway), and
promotion of a shard whose home device died rebuilds on a survivor.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from repro.core.pipeline import (FeatureExecutor, FeaturePipeline,
                                 FeaturePlan, ShardedFeatureExecutor,
                                 pad_rows_edge)
from repro.serve.classes import LatencyHistogram, RequestClass
from repro.serve.faults import (DeadlineExceeded, DeviceDown, DeviceHealth,
                                FaultInjector, FaultPolicy, ServeError,
                                StreamBreaker)
from repro.train.fault import StragglerDetector

DEFAULT_BUCKETS = (64, 256, 1024)


@dataclass
class _Chunk:
    """One bucket-shaped slice of a request, queued for a shard's pump."""
    ticket: int
    rows: np.ndarray        # raw (unpadded) SHARD-LOCAL row indices
    n: int                  # valid rows (== rows.shape[0])
    bucket: int             # static launch shape this chunk pads to
    shard: int              # owning shard (0 for unsharded services)
    # destination of these rows in the request output: an int start for a
    # contiguous run, or an explicit position vector for routed splits
    dest: int | np.ndarray = 0
    t_enq: float = field(default=0.0, compare=False)
    # -- fault-recovery state (pump thread only) --
    attempts: int = 0               # launches tried so far
    not_before: float = 0.0         # retry backoff deadline (perf_counter)
    avoid: frozenset = frozenset()  # stream tokens this chunk failed on
    klass: str = "default"          # request class (pump scheduling key)


@dataclass
class _Flight:
    """One dispatched launch awaiting retire (pump thread only).

    ``ready_at`` gates the retire on an injected stall (simulated slow
    device compute — 0.0 means none). The hedge fields appear when a
    duplicate launch was dispatched on another stream: the duplicate
    covers the SAME group, so its buffer layout matches ``parts`` and
    whichever buffer comes ready first retires the tickets."""
    dev: object                     # primary launch buffer (device)
    parts: list                     # (ticket, n, dest, row_off) per chunk
    group: list                     # the _Chunks this launch covers
    ex: object                      # primary stream executor
    t0: float                       # primary dispatch time (perf_counter)
    ready_at: float = 0.0           # injected-stall retire gate
    hedge_dev: object = None        # duplicate launch buffer, if hedged
    hedge_ex: object = None
    hedge_t0: float = 0.0
    hedge_ready_at: float = 0.0
    hedge_done: bool = False        # hedge attempted (or impossible)


class FeatureService:
    """Request-queue-driven feature serving over a compiled FeaturePlan."""

    def __init__(self, plan: FeaturePlan | FeaturePipeline, *,
                 use_kernel: bool = False, prefetch: int = 2,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 sharded: bool = False, coalesce: int = 4,
                 linger_us: float = 0.0, devices=None,
                 rebalance_every: int = 0, row_budget: int | None = None,
                 hot_factor: float = 4.0, max_replicas: int | None = None,
                 hbm_budget_bytes: int | None = None, cold_after: int = 2,
                 host_gather_workers: int | None = None,
                 faults: FaultInjector | None = None,
                 fault_policy: FaultPolicy | None = None,
                 classes: tuple[RequestClass, ...] | None = None):
        if isinstance(plan, FeaturePipeline):
            plan = plan.plan
        if prefetch < 2:
            raise ValueError("FeatureService is double-buffered: prefetch >= 2")
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad bucket sizes {buckets!r}")
        if linger_us < 0:
            raise ValueError("linger_us must be >= 0")
        if rebalance_every < 0:
            raise ValueError("rebalance_every must be >= 0")
        if row_budget is not None and row_budget < 32:
            raise ValueError("row_budget must be >= 32 (one alignment word)")
        if hot_factor < 1.0:
            raise ValueError("hot_factor must be >= 1 (hot means above mean)")
        if (rebalance_every or row_budget) and not (sharded and plan.packed):
            raise ValueError("adaptive shard management (rebalance_every / "
                             "row_budget) needs sharded=True over a packed "
                             "plan")
        if hbm_budget_bytes is not None and not (sharded and plan.packed):
            raise ValueError("tiered residency (hbm_budget_bytes) needs "
                             "sharded=True over a packed plan")
        if cold_after < 1:
            raise ValueError("cold_after must be >= 1 monitor tick")
        if host_gather_workers is None:
            # fan-out can only cut the miss window when there are spare
            # cores for the pool to land on; a 1-core host stays sequential
            host_gather_workers = min(4, os.cpu_count() or 1)
        if host_gather_workers < 1:
            raise ValueError("host_gather_workers must be >= 1")
        self.plan = plan
        self.packed = plan.packed
        self.prefetch = prefetch
        self.buckets = tuple(sorted(buckets))
        self.use_kernel = use_kernel
        self.sharded = sharded
        self._linger_s = linger_us * 1e-6
        if sharded and self.packed:
            # mesh mode: per-IMCU word-stream shards, one committed executor
            # + one launch queue/window per shard, all fed by the one pump
            self._sharded_ex = ShardedFeatureExecutor(
                plan, use_kernel=use_kernel, prefetch=prefetch,
                devices=devices, hbm_budget_bytes=hbm_budget_bytes)
            self._executors = self._sharded_ex.executors
            self._executor = self._executors[0]
            self._n_shards = self._sharded_ex.n_shards
        else:
            # ONE executor — device ADV tables are shared; legacy int32
            # sharding only changes where the host code slices come from
            self._sharded_ex = None
            self._executor = FeatureExecutor(plan, use_kernel=use_kernel,
                                             prefetch=prefetch)
            self._executors = [self._executor]
            self._n_shards = 1
        if self._executor.kernel_active:
            # align buckets to the fused kernel's row tile, else every
            # bucket gets padded AGAIN to a bn multiple inside the kernel
            bn = plan.fused_tables().bn
            self.buckets = tuple(sorted(
                {-(-b // bn) * bn for b in self.buckets}))
        elif self.packed:
            # word-aligned buckets keep the range iterator's discipline and
            # one compiled indexed shape per bucket
            self.buckets = tuple(sorted(
                {-(-b // 32) * 32 for b in self.buckets}))
        if sharded and not self.packed:
            self._shard_bounds = plan.imcu_bounds()
            self._shards = plan.imcu_shards()
            self._starts = np.array([b[0] for b in self._shard_bounds])
        if coalesce < 1:
            raise ValueError("coalesce must be >= 1")
        self.coalesce = coalesce if self.packed else 1
        # -- pump-shared state: everything below is guarded by _lock --
        # one launch queue + one in-flight window PER SHARD; each in-flight
        # entry is (device buffer, parts) where each part is
        # (ticket, n_valid_rows, dest, row_off) — row_off is the chunk's
        # start row in the flat (rows, F) launch buffer
        self._queues = [deque() for _ in range(self._n_shards)]
        self._inflights = [deque() for _ in range(self._n_shards)]
        self._busy = [0] * self._n_shards   # launches/retires mid-flight
        self._chunks_total: dict[int, int] = {}
        self._chunks_done: dict[int, int] = {}
        self._ticket_rows: dict[int, int] = {}
        self._out_buf: dict[int, np.ndarray] = {}
        self._results: dict[int, np.ndarray] = {}
        self._claimed: set[int] = set()     # tickets a result() call waits on
        self._next_ticket = 0
        self._submitted_at: dict[int, float] = {}
        self._paused = False
        self._shutdown = False
        self._flushes = 0               # drain()s in progress: no lingering
        self._pump_error: BaseException | None = None
        # -- fault-tolerance state --
        self._faults = faults
        self._policy = fault_policy if fault_policy is not None \
            else FaultPolicy()
        self._errors: dict[int, ServeError] = {}   # failed-ticket results
        self._dead: set[int] = set()    # failed tickets: drop their chunks
        self._deadlines: dict[int, float] = {}     # ticket -> perf_counter
        # breakers key on the executor's STABLE stream token, never id():
        # a dropped replica's id() can be recycled for a fresh executor,
        # which would alias the new stream onto a stale open breaker
        self._breakers: dict[int, StreamBreaker] = {}   # stream_token ->
        self._stream_rr = [0] * self._n_shards     # healthy-stream cursor
        # -- device-loss recovery state --
        self._device_health = DeviceHealth()
        self._needs_rebuild: set[int] = set()   # shards with no live stream
        # -- pump supervisor state (journal: what the pump held when it
        #    died, so a restart re-enqueues instead of losing tickets) --
        self._pump_restarts_used = 0
        self._pump_taken: tuple | None = None      # (shard, group) pre-launch
        self._pump_retiring: tuple | None = None   # (shard, _Flight)
        self._retire_prog = 0       # parts fully retired of current flight
        self._stragglers = [self._new_straggler()
                            for _ in range(self._n_shards)]
        # -- latency accounting --
        # the deque is the BENCH-COMPAT window (np.percentile over it is
        # biased toward the most recent 8192 tickets on long runs); the
        # histograms below see every completed ticket and back
        # latency_percentile()/class_stats() — the SLO-gate reading.
        # stats['latency_samples_total'] makes the window's truncation
        # detectable (> len(latencies) means the deque wrapped)
        self.latencies: deque[float] = deque(maxlen=8192)  # per-ticket s
        self._lat_hist = LatencyHistogram()
        # -- request classes (priority pump scheduling + per-class SLOs) --
        # every service carries a 'default' class (service-wide coalesce/
        # linger, priority 1, no deadline) so classless submits flow
        # exactly as before; the front door registers real classes here
        self._classes: dict[str, RequestClass] = {
            "default": RequestClass("default")}
        for rc in (classes or ()):
            if rc.name in self._classes and rc.name != "default":
                raise ValueError(f"duplicate request class {rc.name!r}")
            self._classes[rc.name] = rc
        self._ticket_class: dict[int, str] = {}
        self._class_stats: dict[str, dict] = {
            name: {"requests": 0, "completed": 0, "failed": 0, "rows": 0,
                   "hist": LatencyHistogram()}
            for name in self._classes}
        # -- adaptive shard management state --
        self.rebalance_every = rebalance_every
        self.row_budget = row_budget
        self.hot_factor = hot_factor
        self.max_replicas = max_replicas
        self._mon_alpha = 0.5           # EWMA weight per monitor tick
        self._mon_ewma = [0.0] * self._n_shards
        self._mon_last = [0] * self._n_shards
        self._mon_mark = 0              # launches at the last monitor tick
        self._route_gen = 0             # bumped on every routing-table swap
        self._admin_q: deque = deque()  # (fn, event, result_box) for the pump
        # -- tiered residency state --
        # construction committed shards in order while the budget lasted;
        # everything the ledger left uncommitted starts WARM
        self.cold_after = cold_after
        self._tier = (["hot" if ex.resident_bytes() > 0 else "warm"
                       for ex in self._executors]
                      if self._sharded_ex is not None
                      else ["hot"] * self._n_shards)
        self._offdevice = {s for s, t in enumerate(self._tier) if t != "hot"}
        self._promote_pending: set[int] = set()   # tier misses awaiting a beat
        self._warm_ticks = [0] * self._n_shards   # quiet ticks while warm
        self._host_served = [0] * self._n_shards  # host-path chunks (EWMA feed)
        self._host_workers = host_gather_workers
        self._host_pool: ThreadPoolExecutor | None = None   # lazy fan-out
        self.stats = {"requests": 0, "rows": 0, "padded_rows": 0,
                      "batches": 0, "launches": 0, "max_inflight": 0,
                      "latency_s_total": 0.0, "completed": 0,
                      "latency_samples_total": 0,
                      "packed_ranges": 0, "bytes_h2d": 0, "split_requests": 0,
                      "filtered_requests": 0,
                      "retries": 0, "failovers": 0, "timeouts": 0,
                      "failed_tickets": 0, "unhealthy_shards": 0,
                      "stragglers": 0,
                      "recoveries": 0, "pump_restarts": 0,
                      "hedges": 0, "hedge_wins": 0,
                      "devices_lost": 0, "host_gathers": 0,
                      "rebalances": 0, "replicas_added": 0,
                      "replicas_dropped": 0, "shard_splits": 0,
                      "promotions": 0, "demotions": 0, "rehydrations": 0,
                      "tier_misses": 0,
                      "tier_hot": self._tier.count("hot"),
                      "tier_warm": self._tier.count("warm"),
                      "tier_cold": 0,
                      "shard_launches": [0] * self._n_shards,
                      "shard_batches": [0] * self._n_shards,
                      "shard_bytes_h2d": [0] * self._n_shards}
        # conditions over ONE lock, so each event wakes only the threads
        # that care (on small-core hosts a spurious wake steals GIL time
        # from the XLA compute the pumps are trying to overlap):
        #   _work — the pump sleeps here; submits that queued work (and
        #           pause/shutdown/drain-flush) notify
        #   _cv       — result()/poll() waiters; notified when a ticket lands
        #   _idle     — drain() waiters; notified when all pumps go idle
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._cv = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._seq = 0                       # global launch order for retires
        self._pump = threading.Thread(target=self._pump_main,
                                      name="feature-service-pump",
                                      daemon=True)
        self._pump.start()

    # -- lifecycle ------------------------------------------------------------------
    def __enter__(self) -> "FeatureService":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    @property
    def n_shards(self) -> int:
        """Launch streams this service serves through (1 unsharded)."""
        return self._n_shards

    @property
    def replicas(self) -> list[int]:
        """Replica count per shard — the read-fan-out picture the adaptive
        policies produced (all zeros for unsharded services)."""
        if self._sharded_ex is None:
            return [0] * self._n_shards
        return [len(r) for r in self._sharded_ex.replicas]

    @property
    def monitor_ewma(self) -> list[float]:
        """Per-shard request-rate EWMA — the load monitor's current view
        (what :meth:`rebalance` decides replicate/shed/split from)."""
        return list(self._mon_ewma)

    @property
    def shard_starts(self) -> list[int]:
        """Routing-table row starts per shard (grows on tail splits)."""
        if self._sharded_ex is None:
            return [0]
        return list(self._sharded_ex._routing[1])

    def shutdown(self, drain: bool = True) -> None:
        """Stop every pump thread and join them.

        ``drain=True`` (default) serves everything already queued first (an
        orderly drain — results stay retrievable via :meth:`result` /
        :meth:`drain`); ``drain=False`` discards queued-but-unlaunched
        chunks, forgetting their tickets. Idempotent.
        """
        with self._lock:
            if not drain:
                dropped = set()
                for q in self._queues:
                    dropped.update(ch.ticket for ch in q)
                    q.clear()
                for t in dropped:
                    self._chunks_total.pop(t, None)
                    self._chunks_done.pop(t, None)
                    self._ticket_rows.pop(t, None)
                    self._out_buf.pop(t, None)
                    self._submitted_at.pop(t, None)
                    self._deadlines.pop(t, None)
                    self._ticket_class.pop(t, None)
            self._shutdown = True
            self._notify_everyone()
        self._pump.join()
        if self._host_pool is not None:
            self._host_pool.shutdown(wait=True)   # idempotent

    def _notify_everyone(self) -> None:
        """Wake every waiter class (lock held) — shutdown/error paths."""
        self._work.notify_all()
        self._cv.notify_all()
        self._idle.notify_all()

    def _check_pump(self) -> None:
        if self._pump_error is not None:
            raise RuntimeError("feature-service pump thread died") \
                from self._pump_error

    def pause(self) -> None:
        """Hold launches (submissions still queue) — lets a caller batch a
        burst of submits into maximally coalesced launches."""
        with self._lock:
            self._check_pump()
            self._paused = True
            self._work.notify_all()

    def resume(self) -> None:
        with self._lock:
            self._check_pump()
            self._paused = False
            self._work.notify_all()

    # -- fault tolerance: breakers, stream health, failure handling ------------------
    def _new_straggler(self) -> StragglerDetector:
        p = self._policy
        return StragglerDetector(threshold=p.straggler_threshold,
                                 warmup=p.straggler_warmup)

    def _breaker(self, ex) -> StreamBreaker:
        b = self._breakers.get(ex.stream_token)
        if b is None:
            b = self._breakers[ex.stream_token] = StreamBreaker()
        return b

    def _close_breaker_locked(self, ex, now: float) -> None:
        """A round trip proved the stream healthy: close its breaker, and
        when it was TRIPPED, give back its ``unhealthy_shards`` mark —
        the stat is a gauge of currently-unhealthy streams, not a
        lifetime trip counter. A success while the breaker is still OPEN
        does NOT close it: a shard whose only stream tripped keeps
        launching through the open breaker, and those forced launches are
        not probes — the breaker holds until the cooldown makes the
        stream half-open and a success there is the real probe."""
        b = self._breakers.get(ex.stream_token)
        if b is None or b.is_open(self._policy.breaker_fails, now):
            return
        if b.fails >= self._policy.breaker_fails:
            self.stats["unhealthy_shards"] -= 1
        b.reset()

    def _discard_breaker_locked(self, ex) -> None:
        """The stream is leaving the shard set (replica drop, device
        eviction, rebuild swap): forget its breaker — and give back its
        gauge mark when it left unhealthy. Without this, breakers leak
        per dropped stream (and a recycled executor id could inherit a
        stale open breaker — tokens make that structural, this makes the
        table size match the live stream set)."""
        b = self._breakers.pop(ex.stream_token, None)
        if b is not None and b.fails >= self._policy.breaker_fails:
            self.stats["unhealthy_shards"] -= 1

    def _shard_streams(self, s: int) -> list:
        return (self._sharded_ex.stream_executors(s)
                if self._sharded_ex is not None else [self._executor])

    def _healthy_streams(self, s: int, now: float) -> list:
        thr = self._policy.breaker_fails
        return [ex for ex in self._shard_streams(s)
                if not self._breaker(ex).is_open(thr, now)
                and not self._device_health.is_down(id(ex.device))]

    @property
    def unhealthy(self) -> list[int]:
        """Shards with at least one OPEN-breaker launch stream right now —
        what the monitor's failover policy re-replicates around."""
        with self._lock:
            now = time.perf_counter()
            return [s for s in range(self._n_shards)
                    if len(self._healthy_streams(s, now))
                    < len(self._shard_streams(s))]

    def _pick_stream(self, s: int, avoid: frozenset):
        """Healthy-stream selection with read fan-out (pump thread, lock
        held). Round-robins the shard's closed-breaker streams; a stream
        past its breaker cooldown is half-open and its next pick is the
        recovery probe. ``avoid`` (executor ids a retrying group already
        failed on) is excluded unless nothing else is left — a retry
        prefers a replica it has NOT watched fail. Returns (executor,
        stream index)."""
        streams = self._shard_streams(s)
        if len(streams) == 1 and not avoid:
            return streams[0], 0
        now = time.perf_counter()
        thr = self._policy.breaker_fails
        dh = self._device_health
        idx = list(range(len(streams)))
        healthy = [i for i in idx
                   if not self._breaker(streams[i]).is_open(thr, now)
                   and not dh.is_down(id(streams[i].device))]
        pool = ([i for i in healthy
                 if streams[i].stream_token not in avoid]
                or healthy
                or [i for i in idx if streams[i].stream_token not in avoid]
                or idx)
        self._stream_rr[s] += 1
        i = pool[self._stream_rr[s] % len(pool)]
        return streams[i], i

    def _strike_locked(self, ex, shard: int, now: float) -> bool:
        """One failure (or straggler flag) on a stream: breaker
        bookkeeping + the unhealthy-shard mark the monitor keys on.
        Returns True when this strike TRIPPED the breaker — the event
        device-loss attribution counts."""
        p = self._policy
        if self._breaker(ex).strike(p.breaker_fails, p.breaker_cooldown_s,
                                    now):
            self.stats["unhealthy_shards"] += 1
            return True
        return False

    def _observe_latency_locked(self, s: int, ex, dt: float,
                                now: float) -> None:
        """Feed the shard's straggler detector with one launch round-trip
        time; a flagged launch that also clears the absolute floor counts
        as a breaker strike (slow stream -> same unhealthy/re-replicate
        path as a failing one), otherwise the round trip proves the
        stream healthy and closes its breaker."""
        flagged = self._stragglers[s].observe(
            self.stats["shard_launches"][s], dt)
        if flagged and dt >= self._policy.straggler_min_s:
            self.stats["stragglers"] += 1
            self._strike_locked(ex, s, now)
        else:
            self._close_breaker_locked(ex, now)
            self._device_health.ok(id(ex.device))

    def _fail_ticket_locked(self, ticket: int, err: ServeError, *,
                            timeout: bool = False) -> None:
        """Resolve ``ticket`` to a typed error (lock held): the ledger
        entries go, the error is retrievable via poll/result/collect, and
        chunks of this ticket still queued anywhere are dropped on sight
        (``_dead``). Idempotent for already-resolved tickets."""
        if ticket not in self._chunks_total:
            return
        del self._chunks_total[ticket]
        self._chunks_done.pop(ticket, None)
        self._ticket_rows.pop(ticket, None)
        self._out_buf.pop(ticket, None)
        self._deadlines.pop(ticket, None)
        self._submitted_at.pop(ticket, None)
        self._dead.add(ticket)
        self._errors[ticket] = err
        self.stats["failed_tickets"] += 1
        k = self._ticket_class.pop(ticket, None)
        if k is not None:
            self._class_stats[k]["failed"] += 1
        if timeout:
            self.stats["timeouts"] += 1
        self._cv.notify_all()

    def _handle_launch_failure(self, s: int, group: list[_Chunk], ex,
                               err: Exception) -> None:
        """Fault isolation (lock held, pump thread): one launch group's
        failure touches ONLY its own chunks. Strike the stream's breaker,
        then re-enqueue the group at the head of its shard's queue —
        immediately when another healthy stream can take the retry
        (replica failover), else after capped exponential backoff.
        Chunks out of retries resolve their tickets to ServeError.

        Device attribution: a breaker TRIP counts one strike against the
        stream's device; a :class:`DeviceDown` error declares it dead
        outright. A newly-dead device triggers recovery (evict + rebuild
        elsewhere) before the group is re-enqueued, so the retry already
        sees the post-eviction stream set."""
        now = time.perf_counter()
        tripped = self._strike_locked(ex, s, now)
        if self._sharded_ex is not None and ex.device is not None:
            dev_id = id(ex.device)
            if isinstance(err, DeviceDown):
                newly_down = self._device_health.mark_down(dev_id)
            elif tripped:
                newly_down = self._device_health.strike(
                    dev_id, self._policy.device_fails)
            else:
                newly_down = False
            if newly_down:
                self._recover_device_locked(dev_id)
        retry = [ch for ch in group
                 if ch.attempts + 1 <= self._policy.max_retries
                 and ch.ticket not in self._dead]
        failed = [ch for ch in group if ch not in retry]
        for ch in failed:
            self._fail_ticket_locked(ch.ticket, ServeError(
                f"request failed after {ch.attempts + 1} launch attempts "
                f"on shard {s}: {err!r}", ticket=ch.ticket, shard=s,
                attempts=ch.attempts + 1))
            self._errors[ch.ticket].__cause__ = err
        if not retry:
            return
        failed_tok = ex.stream_token
        alt = any(e.stream_token != failed_tok
                  for e in self._healthy_streams(s, now))
        for ch in reversed(retry):
            ch.attempts += 1
            ch.avoid = ch.avoid | {failed_tok}
            ch.not_before = now if alt \
                else now + self._policy.backoff_for(ch.attempts)
            self._queues[s].appendleft(ch)
        self.stats["retries"] += 1
        self._work.notify_all()

    # -- device-loss recovery (evict -> host-serve -> rebuild) -----------------------
    def _recover_device_locked(self, dev_id: int) -> None:
        """A device was declared dead (lock held, pump thread): evict
        every resident stream on it — replicas dropped, orphaned
        primaries promoted from surviving replicas — and mark shards
        left with NO live stream for emergency rebuild. Their queued
        work is served through the host-gather slow path until the
        rebuild lands (:meth:`_pick_action` policy: hostserve before
        launch for marked shards)."""
        self.stats["devices_lost"] += 1
        removed, orphans = self._sharded_ex.evict_device(dev_id)
        for _s, rex in removed:
            self._discard_breaker_locked(rex)
        for s in orphans:
            # a shard the tier ladder already demoted was host-served
            # before the device died — no emergency rebuild; promotion
            # (if its load comes back) rebuilds on a survivor
            if s in self._offdevice:
                continue
            self._needs_rebuild.add(s)
        self._work.notify_all()

    def _rebuild_shard_locked(self, s: int) -> bool:
        """Emergency rebuild of an orphaned shard's stream on a surviving
        device (lock held, pump thread). False (shard stays host-served)
        when no device survives; True when the fresh stream is committed
        — from then on the shard launches normally again."""
        sx = self._sharded_ex
        lost = set(self._device_health.down)
        old = sx.executors[s]
        try:
            sx.rebuild_on(s, lost=lost)
        except ValueError:
            return False                 # nothing healthy to rebuild on
        self._discard_breaker_locked(old)
        self._needs_rebuild.discard(s)
        self.stats["recoveries"] += 1
        self._work.notify_all()
        return True

    def _host_features_group(self, s: int, group: list) -> list[np.ndarray]:
        """Compute a host-gather group's features (pump thread, NO lock
        held): one :meth:`FeaturePlan.host_features` per chunk — the same
        codes and the same OOB clamp as the device gather, so results are
        bit-exact — fanned out over a small lazy thread pool so a multi-
        chunk miss window costs ~one gather of wall time instead of
        ``len(group)``. Single-chunk groups (and ``host_gather_workers=1``)
        skip the pool. Safe concurrently: per-column word/RLE reads are
        pure, and the caches the gathers may populate are idempotent
        (equal values; last write wins). Tier mutations can't race — they
        run only on the pump thread, which is blocked here."""
        plan = (self._sharded_ex.shards[s]
                if self._sharded_ex is not None else self.plan)
        if len(group) == 1 or self._host_workers == 1:
            return [plan.host_features(ch.rows) for ch in group]
        if self._host_pool is None:
            self._host_pool = ThreadPoolExecutor(
                max_workers=self._host_workers,
                thread_name_prefix="feature-service-hostgather")
        return list(self._host_pool.map(
            lambda ch: plan.host_features(ch.rows), group))

    def _host_serve(self, s: int, group: list) -> None:
        """Serve one taken host-gather group end to end (pump thread, lock
        NOT held on entry): degraded-mode serving for shards with no live
        stream (device loss) and the TIER-MISS path for warm/cold shards.
        Never double-counts launch stats — only ``host_gathers`` (and
        ``tier_misses`` when the shard is off-device by tier rather than
        loss; a miss also marks the shard promotion-pending, the async
        promotion the pump picks up on a free beat). Crash-safe via the
        ``_pump_taken`` journal: a chunk leaves the journaled group only
        after its retire completes, so a pump restart re-serves exactly
        the unserved tail."""
        feats_list = self._host_features_group(s, group)
        with self._lock:
            self.stats["host_gathers"] += 1
            self._host_served[s] += len(group)
            miss = s in self._offdevice and s not in self._needs_rebuild
            if miss:
                self.stats["tier_misses"] += 1
                self._warm_ticks[s] = 0
                self._promote_pending.add(s)
            landed = False
            for feats in feats_list:
                ch = group[0]
                self._retire_prog = 0
                if self._retire(feats, [(ch.ticket, ch.n, ch.dest, 0)]):
                    landed = True
                del group[0]
            if landed:
                self._cv.notify_all()
            self._pump_taken = None
            self._busy[s] -= 1
            if self.rebalance_every and (
                    self.stats["launches"]
                    + self.stats["host_gathers"] - self._mon_mark
                    >= self.rebalance_every):
                self._rebalance_locked()
            if miss:
                self._work.notify_all()   # the promote arm has work now
            if self._all_idle():
                self._idle.notify_all()

    # -- request intake -------------------------------------------------------------
    def _route(self, rows: np.ndarray, lo: int, hi: int):
        """(shard, local_rows, dest) pieces for a request's rows.

        Single-pump services own everything in shard 0 (dest None = whole
        request in order). Multi-shard packed services bucket by owning
        IMCU — the clustered fast path (all rows in one shard, the common
        'per-user block' lookup) routes without materializing an index.
        """
        if self._n_shards == 1:
            return [(0, rows, None)]
        return self._sharded_ex.route(rows, lo, hi)

    def submit(self, rows: np.ndarray | None = None, *, where=None,
               deadline_ms: float | None = None,
               klass: str = "default") -> int:
        """Enqueue a featurization request; returns a ticket for the result.

        Only queues: the background pumps pick the chunks up, coalesce them
        with other queued work owned by the same shard and launch — the
        caller goes on submitting while the devices gather.

        ``where=<predicate>`` (instead of explicit ``rows``) is the
        pushdown form: the matching rows are found by the device-side
        predicate scan over the resident word streams (per shard on a mesh
        service) and then pumped through the SAME coalescing launch path as
        any explicit request — "serve features WHERE ..." as one ticket.

        ``deadline_ms`` bounds the request's time in the system: chunks
        still QUEUED once it expires are dropped before launch and the
        ticket resolves to :class:`DeadlineExceeded` (chunks already in
        flight retire normally — a deadline evicts queued work, it does
        not cancel device work).

        ``klass`` names a registered :class:`RequestClass` (construct the
        service with ``classes=``): it sets the pump's scheduling
        priority, coalescing policy and — when ``deadline_ms`` is not
        passed — the class's default deadline.
        """
        rc = self._classes.get(klass)
        if rc is None:
            raise ValueError(f"unknown request class {klass!r} "
                             f"(registered: {sorted(self._classes)})")
        if deadline_ms is None:
            deadline_ms = rc.deadline_ms
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0")
        filtered = where is not None
        if filtered:
            if rows is not None:
                raise ValueError("pass rows OR where, not both")
            if not self.packed:
                raise RuntimeError("predicate-filtered serving needs a "
                                   "packed plan (resident word streams)")
            ex = self._sharded_ex if self._sharded_ex is not None \
                else self._executor
            rows = ex.filtered_rows(where)
            if rows.size == 0:
                # empty selection: nothing to pump — mint a ticket whose
                # (0, F) result is already on host (poll/result check the
                # results map before the chunk ledger, so this short-
                # circuit needs no pump cooperation)
                with self._lock:
                    self._check_pump()
                    if self._shutdown:
                        raise RuntimeError("service is shut down")
                    ticket = self._next_ticket
                    self._next_ticket += 1
                    self.stats["requests"] += 1
                    self.stats["filtered_requests"] += 1
                    self.stats["completed"] += 1
                    cs = self._class_stats[klass]
                    cs["requests"] += 1
                    cs["completed"] += 1
                    self._results[ticket] = np.zeros(
                        (0, self.plan.out_dim), np.float32)
                    self._cv.notify_all()
                return ticket
        elif rows is None:
            raise ValueError("need rows or where")
        rows = np.asarray(rows, dtype=np.int64).reshape(-1)
        if rows.size == 0:
            raise ValueError("empty request")
        lo, hi = int(rows.min()), int(rows.max())
        if lo < 0 or hi >= self.plan.n_rows:
            raise IndexError(f"row indices out of range [0, {self.plan.n_rows})")
        # routing, chunking and the O(chunk) alignment scan are pure
        # functions of the request — do them OUTSIDE the lock. A pump-side
        # rebalance may swap the routing table between that work and the
        # enqueue below; the generation check catches it and reroutes (a
        # chunk built against stale bounds would land on a shard that no
        # longer owns its rows)
        cap = self.buckets[-1]
        while True:
            gen = self._route_gen
            pieces, padded, aligned = [], 0, 0
            routed = self._route(rows, lo, hi)
            for shard, local, dest in routed:
                for start in range(0, local.shape[0], cap):
                    chunk = local[start:start + cap]
                    bucket = self._bucket(chunk.shape[0])
                    padded += bucket - chunk.shape[0]
                    if self.packed and self._aligned_range(chunk):
                        aligned += 1
                    d = start if dest is None else dest[start:start + cap]
                    pieces.append(_Chunk(0, chunk, chunk.shape[0], bucket,
                                         shard, d))
            with self._lock:
                self._check_pump()
                if self._shutdown:
                    raise RuntimeError("service is shut down")
                if self._route_gen != gen:
                    continue            # routing swapped mid-build: redo
                ticket = self._next_ticket
                self._next_ticket += 1
                now = time.perf_counter()
                self._submitted_at[ticket] = now
                if deadline_ms is not None:
                    self._deadlines[ticket] = now + deadline_ms / 1e3
                self.stats["requests"] += 1
                self.stats["rows"] += rows.size
                self.stats["padded_rows"] += padded
                self.stats["packed_ranges"] += aligned
                if filtered:
                    self.stats["filtered_requests"] += 1
                if len(routed) > 1:
                    self.stats["split_requests"] += 1
                self._chunks_total[ticket] = len(pieces)
                self._ticket_rows[ticket] = rows.size
                self._ticket_class[ticket] = klass
                cs = self._class_stats[klass]
                cs["requests"] += 1
                cs["rows"] += rows.size
                before = {}
                for ch in pieces:
                    ch.ticket = ticket
                    ch.t_enq = now
                    ch.klass = klass
                    q = self._queues[ch.shard]
                    before.setdefault(ch.shard, len(q))
                    q.append(ch)
                for s, n0 in before.items():
                    # wake discipline (each wake steals GIL time from XLA):
                    # the parked pump needs a wake when a shard queue goes
                    # empty -> nonempty (to start serving, or arm its linger
                    # timer), when this submit completed a coalescing
                    # group, or when it OUTRANKS the queue's current head —
                    # a lingering low-priority group must not make a
                    # fresh high-priority chunk wait out its hold; chunks
                    # landing mid-group otherwise ride the pending tick
                    q = self._queues[s]
                    n1 = len(q)
                    preempt = n0 > 0 and rc.priority > \
                        self._classes[q[0].klass].priority
                    if n0 == 0 or preempt or (n0 < self.coalesce <= n1):
                        self._work.notify_all()
                        break
                return ticket

    # -- bucketing ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        """Smallest static bucket >= n (largest bucket caps a chunk)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _slice_padded(self, rows: np.ndarray, bucket: int) -> np.ndarray:
        """Host work for one int32 chunk: fancy-index + right-pad to bucket."""
        rows = pad_rows_edge(rows, bucket)
        if self.sharded and not self.packed:
            return self._gather_sharded_codes(rows)
        return self.plan.host_codes(rows)

    def _gather_sharded_codes(self, rows: np.ndarray) -> np.ndarray:
        """Route rows to their owning IMCU partitions (partition-local
        slices) — the legacy int32 sharding, where only the HOST side is
        partitioned and one pump still serves every launch.

        Rows appended after plan compile (streaming inserts via
        ``FeaturePlan.refresh``) live past the last IMCU boundary and are
        served from the plan's own code matrix tail.
        """
        out = np.empty((len(self.plan.plans), rows.shape[0]), np.int32)
        tail_start = self._shard_bounds[-1][1]
        tail = rows >= tail_start
        if tail.any():
            out[:, tail] = self.plan.codes_matrix[:, rows[tail]]
        rows_in, (idx_in,) = rows[~tail], np.nonzero(~tail)
        shard_of = np.searchsorted(self._starts, rows_in, side="right") - 1
        for s in np.unique(shard_of):
            mask = shard_of == s
            local = rows_in[mask] - self._shard_bounds[s][0]
            out[:, idx_in[mask]] = self._shards[s].codes_matrix[:, local]
        return out

    @staticmethod
    def _aligned_range(rows: np.ndarray) -> bool:
        """True for a word-aligned contiguous run (the scan pattern) —
        tracked in ``stats['packed_ranges']``; served by the same unified
        indexed launch as arbitrary row sets. The O(1) prefix checks gate
        the O(n) scan: this runs on every submit."""
        if rows.shape[0] == 0 or int(rows[0]) % 32 or \
                int(rows[-1]) - int(rows[0]) != rows.shape[0] - 1:
            return False
        return bool((np.diff(rows) == 1).all())

    # -- the background pumps ---------------------------------------------------------
    def _coalesce_for(self, rc: RequestClass) -> int:
        """Effective coalescing depth for one class: the class's own when
        set, else the service-wide depth — capped at the service depth
        either way (launch buffers are sized ``(coalesce, bucket)``) and
        forced to 1 on unpacked plans (no coalesced launches there)."""
        if not self.packed:
            return 1
        c = rc.coalesce if rc.coalesce is not None else self.coalesce
        return max(1, min(c, self.coalesce))

    def _linger_for(self, rc: RequestClass) -> float:
        return rc.linger_us * 1e-6 if rc.linger_us is not None \
            else self._linger_s

    def _select_class(self, queue: deque, now: float):
        """Pick the request class shard ``queue`` serves next (lock held).

        Scores each class PRESENT in the queue by its oldest chunk:
        ``priority + waited / aging_s`` — static priority plus
        anti-starvation aging, so a starving ``background`` head
        eventually outranks a fresh ``interactive`` one and low-priority
        work always drains. Classes whose head chunk is still in retry
        backoff are not candidates. Returns ``(klass, head, 0.0)`` for
        the winner, or ``(None, None, hold)`` when every present class is
        backing off (``hold`` = seconds until the nearest backoff ends,
        the caller's wait bound). O(queue) with early exit once every
        registered class was seen.
        """
        heads: dict[str, _Chunk] = {}
        n_classes = len(self._classes)
        for ch in queue:
            if ch.klass not in heads:
                heads[ch.klass] = ch
                if len(heads) == n_classes:
                    break
        best = best_head = None
        best_eff = 0.0
        hold = None
        for name, ch in heads.items():
            if ch.not_before > now:
                h = ch.not_before - now
                hold = h if hold is None else min(hold, h)
                continue
            rc = self._classes[name]
            eff = rc.priority + (now - ch.t_enq) / rc.aging_s
            if best is None or eff > best_eff:
                best, best_head, best_eff = name, ch, eff
        if best is None:
            return None, None, hold if hold is not None else 0.0
        return best, best_head, 0.0

    def _linger_left(self, queue: deque, klass: str, head: _Chunk,
                     now: float) -> float:
        """Seconds the selected class's head launch group should stay
        open. 0 when the group is already full (the CLASS's coalesce
        depth of same-bucket chunks queued) or the head chunk has aged
        past the class's linger deadline — lingering trades a BOUNDED
        latency for fuller groups, it never holds work indefinitely."""
        rc = self._classes[klass]
        cap = self._coalesce_for(rc)
        n_match = 0
        for ch in queue:
            if ch.klass == klass and ch.bucket == head.bucket:
                n_match += 1
                if n_match >= cap:
                    return 0.0
        return head.t_enq + self._linger_for(rc) - now

    def _all_idle(self) -> bool:
        return not any(q or i or b for q, i, b in
                       zip(self._queues, self._inflights, self._busy))

    def _streams(self, s: int) -> int:
        """Launch streams serving shard s (1 + replicas). Each stream gets
        its own ``prefetch``-deep in-flight window: read fan-out scales a
        hot shard's aggregate window with its replica count."""
        return self._sharded_ex.n_streams(s) if self._sharded_ex else 1

    def _pick_action(self):
        """Choose the pump's next action (lock held).

        Returns ``("launch", shard)``, ``("retire", shard)``,
        ``("hostserve", shard)`` (queued work on a shard with no live
        stream — serve it from host words), ``("rebuild", shard)``
        (re-commit an orphaned shard's stream on a surviving device),
        ``("wait", timeout)`` or ``("exit", None)``. Preference order
        keeps every shard's launch stream busy: launch wherever a window
        has room and a group is ready; otherwise retire the OLDEST
        in-flight launch — from a full-window shard first (unblocks its
        stream), else any. Lingering shards (partial group, young head
        chunk) are skipped for launching but their deadline bounds the
        wait timeout, so fuller groups never cost unbounded latency.
        Rebuilds run when nothing is launchable or retirable — and only
        when a device actually survives, so a fully-dead mesh settles
        into pure host-serving instead of spinning.
        """
        held = self._paused and not self._shutdown
        linger_min = None
        now = time.perf_counter()
        for s in range(self._n_shards):
            queue = self._queues[s]
            if not queue or held:
                continue
            if s in self._needs_rebuild or s in self._offdevice:
                return "hostserve", s
            if len(self._inflights[s]) >= self.prefetch * self._streams(s):
                continue
            klass, head, hold = self._select_class(queue, now)
            if klass is None:
                # every queued class's head is backing off after a failed
                # launch: bound the wait like a linger deadline and skip
                linger_min = hold if linger_min is None \
                    else min(linger_min, hold)
                continue
            rc = self._classes[klass]
            if self._linger_for(rc) > 0 and self._coalesce_for(rc) > 1 \
                    and not self._shutdown and not self._flushes:
                left = self._linger_left(queue, klass, head, now)
                if left > 0:
                    linger_min = left if linger_min is None \
                        else min(linger_min, left)
                    continue
            return "launch", s
        # nothing launchable: retire the globally oldest in-flight entry,
        # preferring a shard whose full window is damming its queue
        oldest, oldest_full = None, None
        for s in range(self._n_shards):
            infl = self._inflights[s]
            if not infl:
                continue
            seq = infl[0][0]
            if oldest is None or seq < self._inflights[oldest][0][0]:
                oldest = s
            if len(infl) >= self.prefetch * self._streams(s) and (
                    oldest_full is None
                    or seq < self._inflights[oldest_full][0][0]):
                oldest_full = s
        if oldest_full is not None:
            return "retire", oldest_full
        if oldest is not None and linger_min is None:
            return "retire", oldest
        if self._needs_rebuild and not self._shutdown \
                and self._sharded_ex is not None:
            down = self._device_health.down
            if any(id(d) not in down
                   for d in self._sharded_ex.device_pool):
                return "rebuild", min(self._needs_rebuild)
        if self._promote_pending and not held and not self._shutdown \
                and self._sharded_ex is not None:
            # async promotion on a free beat: hottest pending miss first.
            # Never blocks a request — misses keep host-serving while the
            # re-put runs, and a failed attempt (no budget headroom yet)
            # just clears pending until the next miss re-marks it
            return "promote", max(self._promote_pending,
                                  key=lambda i: self._mon_ewma[i])
        if self._shutdown and self._all_idle() and not self._admin_q:
            return "exit", None
        return "wait", linger_min

    def _pump_main(self) -> None:
        """Pump SUPERVISOR (the thread target): run the pump loop, and
        when it dies of a pump-infrastructure exception — control logic,
        not a guarded launch — restart it with the ledger intact
        (:meth:`_recover_pump_locked` re-enqueues whatever the dying
        pump held mid-operation), up to ``FaultPolicy.pump_restarts``
        times. Past the budget the crash is terminal: ``_pump_error``
        poisons the service and every waiter is unblocked, exactly the
        pre-supervisor behavior."""
        while True:
            try:
                self._pump_loop()
                return
            except BaseException as e:
                with self._lock:
                    if self._pump_restarts_used >= \
                            self._policy.pump_restarts:
                        self._pump_error = e
                        self._fail_admin(e)
                        self._notify_everyone()
                        return
                    self._pump_restarts_used += 1
                    self.stats["pump_restarts"] += 1
                    self._recover_pump_locked()

    def _recover_pump_locked(self) -> None:
        """Restore the ledger's invariants after a pump crash (lock
        held): clear the busy markers the dying pump still held, and put
        back — at the head of its shard's queue, original order — any
        group it had taken for launch but not recorded in flight, plus
        the not-yet-distributed chunks of a retire it was mid-way
        through (parts already distributed stay distributed; the journal
        ``_retire_prog`` marks the boundary). Tickets, queues, in-flight
        windows and the admin queue all survive as-is; blocking entry
        points poll on 0.5 s ticks and behave identically across the
        restart."""
        self._busy = [0] * self._n_shards
        taken = self._pump_taken
        if taken is not None:
            s, group = taken
            for ch in reversed(group):
                self._queues[s].appendleft(ch)
            self._pump_taken = None
        retp = self._pump_retiring
        if retp is not None:
            s, fl = retp
            for i in range(len(fl.group) - 1, self._retire_prog - 1, -1):
                ch = fl.group[i]
                if ch.ticket in self._chunks_total:
                    self._queues[s].appendleft(ch)
            self._pump_retiring = None
        self._work.notify_all()

    def _pump_loop(self) -> None:
        """ONE multiplexing pump drains every shard's queue until shutdown:
        coalesce -> launch -> retire, with a ``prefetch``-deep in-flight
        window PER SHARD. The only thread that dispatches device work or
        blocks on device buffers; shards' launches are dispatched
        asynchronously onto their own devices, so independent shards
        compute concurrently while the pump runs ahead — one thread feeding
        N launch streams (threads-per-shard would fight it for the GIL;
        dispatch is the cheap part).

        Wake discipline: the pump only notifies ``_cv`` when a ticket's
        result actually landed and ``_idle`` when no shard has anything
        left to do — launching and window churn wake nobody, so client
        threads stay parked (and off the GIL) while the devices work.

        Fault isolation: the device-facing work — dispatching a launch and
        blocking on its buffer at retire — is guarded per launch group. An
        exception there routes through :meth:`_handle_launch_failure`
        (retry with backoff, replica failover, per-ticket ServeError) and
        the loop continues; the pump's own control logic raising lands in
        the supervisor (:meth:`_pump_main`) — restart with the ledger
        intact while the budget lasts, terminal after.
        """
        while True:
            with self._lock:
                while True:
                    # shard-set mutations happen HERE — the pump is the
                    # only launcher, and at this point no launch or
                    # retire is mid-flight, so a split/replica swap can
                    # never race a dispatch against stale routing
                    self._drain_admin()
                    action, arg = self._pick_action()
                    if action != "wait":
                        break
                    if self._all_idle():
                        self._idle.notify_all()
                    self._work.wait(timeout=arg)
                if action == "exit":
                    return
                s = arg
                if action == "hostserve":
                    # degraded/off-device mode — the shard has no live
                    # stream (device loss) or lives in a warm/cold tier.
                    # Retry backoffs are void (the host path cannot fail
                    # the way a launch did): take everything queued, then
                    # gather OUTSIDE the lock (thread-pool fan-out) and
                    # retire, journaled like a launch
                    for ch in self._queues[s]:
                        ch.not_before = 0.0
                    hjob = self._take_group(self._queues[s],
                                            time.perf_counter())
                    if not hjob:
                        if self._all_idle():
                            self._idle.notify_all()
                        continue
                    self._pump_taken = (s, hjob)
                    self._busy[s] += 1
                elif action == "rebuild":
                    self._rebuild_shard_locked(s)
                    continue
                elif action == "promote":
                    # pending is cleared WHATEVER the outcome: a promotion
                    # that could not fit leaves the shard warm/cold and the
                    # next tier miss re-marks it — no spinning on a full
                    # device, no lost promotions
                    self._try_promote_locked(s)
                    self._promote_pending.discard(s)
                    if self._all_idle():
                        self._idle.notify_all()
                    continue
                elif action == "launch":
                    job = self._take_group(self._queues[s],
                                           time.perf_counter())
                    if not job:
                        # the whole head group was evicted (failed or
                        # deadline-expired tickets) — nothing to launch
                        if self._all_idle():
                            self._idle.notify_all()
                        continue
                    self._pump_taken = (s, job)
                    ex, _stream = self._pick_stream(s, job[0].avoid)
                    if job[0].avoid and \
                            ex.stream_token not in job[0].avoid:
                        # a retry actually reached a stream it had not
                        # failed on yet: replica failover
                        self.stats["failovers"] += 1
                else:
                    job = None
                    _, fl = self._inflights[s].popleft()
                    self._pump_retiring = (s, fl)
                    self._retire_prog = 0
                if action != "hostserve":
                    self._busy[s] += 1
            if action == "hostserve":
                # gather + retire outside the lock (the pool does the
                # per-chunk host_features); crash-safe via _pump_taken
                self._host_serve(s, hjob)
                continue
            if job is not None:
                t0 = time.perf_counter()
                try:
                    dev, parts, nbytes, stall = self._launch(job, s, ex,
                                                             _stream)
                except Exception as e:
                    with self._lock:
                        self._handle_launch_failure(s, job, ex, e)
                        self._pump_taken = None
                        self._busy[s] -= 1
                        if self._all_idle():
                            self._idle.notify_all()
                    continue
                with self._lock:
                    self._seq += 1
                    self._inflights[s].append((self._seq, _Flight(
                        dev, parts, job, ex, t0,
                        ready_at=t0 + stall if stall else 0.0)))
                    self._pump_taken = None
                    self.stats["launches"] += 1
                    self.stats["batches"] += len(parts)
                    self.stats["bytes_h2d"] += nbytes
                    self.stats["shard_launches"][s] += 1
                    self.stats["shard_batches"][s] += len(parts)
                    self.stats["shard_bytes_h2d"][s] += nbytes
                    self.stats["max_inflight"] = max(
                        self.stats["max_inflight"],
                        sum(len(i) for i in self._inflights))
                    self._busy[s] -= 1
                    if self.rebalance_every and (
                            self.stats["launches"]
                            + self.stats["host_gathers"] - self._mon_mark
                            >= self.rebalance_every):
                        self._rebalance_locked()
            else:
                try:
                    arr, win_ex, dt, by_hedge = self._await_flight(s, fl)
                except Exception as e:
                    with self._lock:
                        self._handle_launch_failure(s, fl.group, fl.ex, e)
                        self._pump_retiring = None
                        self._busy[s] -= 1
                        if self._all_idle():
                            self._idle.notify_all()
                    continue
                with self._lock:
                    now = time.perf_counter()
                    self._observe_latency_locked(s, win_ex, dt, now)
                    if by_hedge:
                        # the primary lost a race against its own
                        # duplicate — that IS a straggler strike
                        self.stats["hedge_wins"] += 1
                        self._strike_locked(fl.ex, s, now)
                    if self._retire(arr, fl.parts):
                        self._cv.notify_all()
                    self._pump_retiring = None
                    self._busy[s] -= 1
                    if self._all_idle():
                        self._idle.notify_all()

    # -- hedged retire (speculative duplicate launches) -------------------------------
    @staticmethod
    def _buf_ready(buf) -> bool:
        """Non-blocking launch-buffer readiness (jax Arrays expose
        ``is_ready``; anything else is host data, ready by definition)."""
        r = getattr(buf, "is_ready", None)
        return True if r is None else bool(r())

    def _await_flight(self, s: int, fl: _Flight):
        """Block (outside the lock) until one of the flight's buffers is
        ready; returns ``(host array, winning executor, round-trip
        seconds, won_by_hedge)``.

        Fast path — no injected stall and hedging not armed — is the
        plain blocking ``np.asarray`` the pre-hedge pump did. Hedging
        arms only when the policy allows it, the shard has more than one
        stream, and its straggler detector is past warmup (an untrained
        EWMA would hedge compile time); the cutoff is
        :meth:`StragglerDetector.hedge_cutoff`. Once the wait crosses
        it, ONE duplicate launch of the same group is dispatched on a
        different healthy stream and both buffers race — first ready
        resolves the tickets, the loser is dropped unread (its buffer
        dies with the flight; nothing double-counts)."""
        det = self._stragglers[s]
        p = self._policy
        can_hedge = (p.hedge and self._sharded_ex is not None
                     and det.n > det.warmup
                     and self._sharded_ex.n_streams(s) > 1)
        if not can_hedge and fl.ready_at == 0.0:
            arr = np.asarray(fl.dev)      # blocks on device, unlocked
            return arr, fl.ex, time.perf_counter() - fl.t0, False
        cutoff = det.hedge_cutoff(p.hedge_factor, p.hedge_min_s)
        while True:
            now = time.perf_counter()
            if fl.hedge_dev is not None and now >= fl.hedge_ready_at \
                    and self._buf_ready(fl.hedge_dev):
                arr = np.asarray(fl.hedge_dev)
                return arr, fl.hedge_ex, now - fl.hedge_t0, True
            if now >= fl.ready_at and self._buf_ready(fl.dev):
                arr = np.asarray(fl.dev)
                return arr, fl.ex, now - fl.t0, False
            if can_hedge and not fl.hedge_done \
                    and now - fl.t0 >= cutoff:
                self._try_hedge(s, fl)
            time.sleep(2e-4)

    def _try_hedge(self, s: int, fl: _Flight) -> None:
        """Dispatch ONE speculative duplicate of the flight's group on a
        different healthy stream (pump thread, lock taken briefly for
        stream selection). At most one attempt per flight; a duplicate
        that fails to launch strikes ITS stream's breaker and the
        primary wait continues — hedging never makes an outcome worse.
        The duplicate's buffer layout matches ``fl.parts`` (same group,
        same buckets), so the retire path needs no translation."""
        fl.hedge_done = True
        avoid = frozenset({fl.ex.stream_token}) | fl.group[0].avoid
        with self._lock:
            now = time.perf_counter()
            alts = [e for e in self._healthy_streams(s, now)
                    if e.stream_token not in avoid]
            if not alts:
                return                    # nowhere healthy to hedge to
            ex2, st2 = self._pick_stream(s, avoid)
            if ex2.stream_token == fl.ex.stream_token:
                return
        t1 = time.perf_counter()
        try:
            dev2, _parts2, _nb2, stall2 = self._launch(fl.group, s,
                                                       ex2, st2)
        except Exception:
            with self._lock:
                self._strike_locked(ex2, s, time.perf_counter())
            return
        fl.hedge_ex = ex2
        fl.hedge_t0 = t1
        fl.hedge_ready_at = t1 + stall2 if stall2 else 0.0
        fl.hedge_dev = dev2
        with self._lock:
            self.stats["hedges"] += 1

    def _take_group(self, queue: deque, now: float) -> list[_Chunk]:
        """Pop one launch group: the :meth:`_select_class` winner's
        chunks, up to the CLASS's coalesce depth, sharing the class
        head's bucket shape (FIFO preserved within the class; other
        classes' chunks are skipped in place). Stops scanning once the
        group is full and splices the tail back in bulk, so a long
        queued burst costs O(Q) per tick, not O(Q) per chunk.

        The eviction point for dead work (lock held): chunks of already-
        failed tickets are dropped on sight, a chunk whose ticket's
        ``deadline_ms`` expired resolves it to :class:`DeadlineExceeded`
        and is dropped BEFORE launch, and the take stops at a selected-
        class chunk still in retry backoff (``not_before`` ahead of
        ``now``) — so the group may come back empty."""
        klass, _head, _hold = self._select_class(queue, now)
        if klass is None:
            return []
        rc = self._classes[klass]
        cap = self._coalesce_for(rc)
        group: list[_Chunk] = []
        rest: deque[_Chunk] = deque()
        bucket = None
        while queue:
            ch = queue[0]
            if ch.ticket in self._dead:
                queue.popleft()
                continue
            dl = self._deadlines.get(ch.ticket)
            if dl is not None and now > dl:
                queue.popleft()
                self._fail_ticket_locked(ch.ticket, DeadlineExceeded(
                    f"ticket {ch.ticket} missed its deadline before launch",
                    ticket=ch.ticket, shard=ch.shard), timeout=True)
                continue
            if len(group) >= cap:
                break
            if ch.klass != klass:
                rest.append(queue.popleft())
                continue
            if ch.not_before > now:
                break
            queue.popleft()
            if bucket is None:
                bucket = ch.bucket
            (group if ch.bucket == bucket else rest).append(ch)
        rest.extend(queue)
        queue.clear()
        queue.extend(rest)
        return group

    def _launch(self, group: list[_Chunk], s: int, ex, stream: int):
        """Dispatch ONE launch for a coalesced group on ``ex`` — the
        shard-``s`` stream :meth:`_pick_stream` chose (pump thread only).

        Packed plans: a flat (coalesce * bucket,) int32 SHARD-LOCAL index
        vector — padded to the full coalesce width so every launch shares
        one compiled shape per bucket — into the shard executor's indexed
        gather; host->device traffic is the indices alone. int32 plans:
        the classic stacked code slice for a single chunk. Either way the
        launch buffer is a flat (rows, F) array and each part records its
        chunk's row offset into it.

        The chaos hook fires first, BEFORE any dispatch: an injected fault
        or delay lands exactly where a real device error would, so it
        exercises the same recovery path. The hook's return value is the
        launch's injected STALL (simulated slow device compute) — passed
        through as the last element of the return tuple so the pump can
        gate the flight's retire readiness on it.
        """
        stall = 0.0
        if self._faults is not None:
            stall = self._faults.before_launch(s, stream,
                                               device=ex.device,
                                               klass=group[0].klass)
        bucket = group[0].bucket
        if self.packed:
            mat = np.empty((self.coalesce, bucket), np.int32)
            for i, ch in enumerate(group):
                mat[i] = pad_rows_edge(ch.rows, bucket)
            mat[len(group):] = mat[len(group) - 1]   # surplus lanes unread
            dev = ex._rows_future(mat.reshape(-1))
            parts = [(ch.ticket, ch.n, ch.dest, i * bucket)
                     for i, ch in enumerate(group)]
            return dev, parts, mat.nbytes, stall
        ch = group[0]
        codes = self._slice_padded(ch.rows, bucket)
        # np codes go straight into the jit'd gather — its argument
        # transfer is the one host->device code shipment
        dev = ex.gather_device(codes)
        return dev, [(ch.ticket, ch.n, ch.dest, 0)], int(codes.nbytes), \
            stall

    def _retire(self, arr: np.ndarray, parts: list) -> bool:
        """Distribute one retired launch buffer to its tickets (lock held);
        True if any ticket completed (its waiters need a wake).

        Single-chunk requests take the sliced piece directly (copied when
        small, so the result doesn't pin the whole coalesced launch buffer
        for its lifetime); multi-chunk requests assemble into a preallocated
        per-ticket (rows, F) buffer via each chunk's destination map — the
        request-order concatenation for routed/sharded splits.

        ``self._retire_prog`` journals how many leading parts are fully
        distributed (bumped as each part's bookkeeping completes): the
        pump supervisor re-enqueues exactly the rest of a crashed
        retire's group. Callers reset it to 0 per launch buffer.
        """
        landed = False
        for i in range(self._retire_prog, len(parts)):
            ticket, n, dest, off = parts[i]
            total = self._chunks_total.get(ticket)
            if total is None:
                # dropped by shutdown(drain=False)
                self._ticket_class.pop(ticket, None)
                self._retire_prog = i + 1
                continue
            piece = arr[off:off + n]
            if total == 1:
                # copy only when the piece is a SLIVER of the coalesced
                # launch buffer (a view would pin the whole (lanes*bucket,
                # F) array for the result's lifetime); a full group's lanes
                # collectively own the buffer anyway, and the copies are
                # GIL-held pump time — 8x bounds the pinning overhead
                if piece.size * 8 < arr.size:
                    piece = piece.copy()
                self._results[ticket] = piece
            else:
                buf = self._out_buf.get(ticket)
                if buf is None:
                    # width read at allocation time, NOT cached at
                    # construction, so a refresh() that grows a dictionary
                    # (wider out_dim) keeps the service serving. Refresh is
                    # not atomic w.r.t. IN-FLIGHT requests — a ticket whose
                    # chunks straddle a widening refresh would mix widths
                    # whatever the buffer shape (the pre-mesh concatenate
                    # had the same contract): drain() before refreshing
                    buf = np.empty((self._ticket_rows[ticket],
                                    self.plan.out_dim), arr.dtype)
                    self._out_buf[ticket] = buf
                if isinstance(dest, np.ndarray):
                    buf[dest] = piece
                else:
                    buf[dest:dest + n] = piece
                done = self._chunks_done.get(ticket, 0) + 1
                if done < total:
                    self._chunks_done[ticket] = done
                    self._retire_prog = i + 1
                    continue
                self._chunks_done.pop(ticket, None)
                self._results[ticket] = self._out_buf.pop(ticket)
            del self._chunks_total[ticket]
            self._ticket_rows.pop(ticket, None)
            self._deadlines.pop(ticket, None)
            landed = True
            t0 = self._submitted_at.pop(ticket, None)
            if t0 is not None:
                lat = time.perf_counter() - t0
                self.stats["latency_s_total"] += lat
                self.latencies.append(lat)
                self.stats["completed"] += 1
                self.stats["latency_samples_total"] += 1
                self._lat_hist.record(lat)
                cs = self._class_stats.get(
                    self._ticket_class.pop(ticket, "default"))
                if cs is not None:
                    cs["completed"] += 1
                    cs["hist"].record(lat)
            self._retire_prog = i + 1
        return landed

    # -- adaptive shard management ---------------------------------------------------
    def _drain_admin(self) -> None:
        """Run queued shard-set mutations (lock held, pump thread only)."""
        while self._admin_q:
            fn, ev, box = self._admin_q.popleft()
            try:
                box.append(fn())
            except BaseException as e:
                box.append(e)
            ev.set()

    def _fail_admin(self, err: BaseException) -> None:
        """Unblock admin waiters when the pump dies (lock held)."""
        while self._admin_q:
            _, ev, box = self._admin_q.popleft()
            box.append(err)
            ev.set()

    def _run_admin(self, fn):
        """Execute ``fn`` under the lock ON THE PUMP THREAD and return its
        result. The pump is the only thread that dispatches launches, so
        marshalling every shard-set mutation onto it makes mutation-vs-
        launch races impossible by construction; a mutation requested from
        the pump itself (the auto monitor) just runs inline."""
        if threading.current_thread() is self._pump:
            return fn()
        ev = threading.Event()
        box: list = []
        with self._lock:
            self._check_pump()
            if self._shutdown:
                raise RuntimeError("service is shut down")
            self._admin_q.append((fn, ev, box))
            self._work.notify_all()
        while not ev.wait(timeout=0.5):
            with self._lock:
                self._check_pump()
        if isinstance(box[0], BaseException):
            raise box[0]
        return box[0]

    def _require_mesh(self) -> None:
        if self._sharded_ex is None:
            raise RuntimeError("adaptive shard management needs a "
                               "sharded=True service over a packed plan")

    def _add_replica_locked(self, shard: int, device=None,
                            avoid: frozenset = frozenset()):
        """The ONE replica-add bookkeeping path (lock held, pump thread) —
        shared by the public mutator and the monitor policies so stats and
        wake discipline can never drift apart. ``avoid`` (device ids) keeps
        the failover policy from re-replicating ONTO a device whose stream
        breaker is open."""
        # never place on a DEAD device, whatever the caller avoids
        avoid = frozenset(avoid) | frozenset(self._device_health.down)
        ex = self._sharded_ex.add_replica(shard, device, avoid=avoid)
        self.stats["replicas_added"] += 1
        self._work.notify_all()         # the shard's window just widened
        return ex.device

    def _drop_replica_locked(self, shard: int):
        ex = self._sharded_ex.drop_replica(shard)
        self._discard_breaker_locked(ex)
        self.stats["replicas_dropped"] += 1
        return ex.device

    def add_replica(self, shard: int, device=None):
        """Replicate ``shard``'s resident word stream to ``device`` (default:
        the least-loaded serve device not already holding a copy) and fan
        reads out across the copies. Returns the replica's device. An
        explicitly configured ``max_replicas`` bounds this too (the
        monitor's device-count default applies only to the auto policy —
        an operator's explicit call may replicate on a single device)."""
        self._require_mesh()

        def op():
            if self.max_replicas is not None and \
                    len(self._sharded_ex.replicas[shard]) >= self.max_replicas:
                raise ValueError(f"shard {shard} already has "
                                 f"max_replicas={self.max_replicas} replicas")
            return self._add_replica_locked(shard, device)
        return self._run_admin(op)

    def drop_replica(self, shard: int):
        """Retire one replica of ``shard`` (in-flight launches finish; the
        routing change is immediate). Returns the dropped device."""
        self._require_mesh()
        return self._run_admin(lambda: self._drop_replica_locked(shard))

    def split_tail(self, cut: int | None = None, device=None) -> int:
        """Split the open tail shard at parent row ``cut`` (default: its
        word-aligned midpoint) and swap the routing table atomically —
        queued chunks of the old tail are re-routed (split in two when they
        straddle the cut) with their tickets, order, and linger deadlines
        intact. Returns the new shard's index."""
        self._require_mesh()
        return self._run_admin(lambda: self._apply_split_locked(cut, device))

    def rebalance(self) -> dict:
        """Run the load monitor's policy decisions NOW (on the pump thread)
        and return the actions taken: ``{'split': [(old, new, cut)],
        'replicated': [(shard, device)], 'dropped': [(shard, device)],
        'failover_replicated': [(shard, device)],
        'rebuilt': [(shard, device)]}``. Safe (a no-op) on unsharded
        services."""
        return self._run_admin(self._rebalance_locked)

    def _unhealthy_devices(self, now: float) -> set[int]:
        """Device ids currently behind an OPEN stream breaker (lock held)
        — placement to avoid when re-replicating for failover."""
        thr = self._policy.breaker_fails
        bad: set[int] = set(self._device_health.down)
        for s in range(self._n_shards):
            for ex in self._shard_streams(s):
                if self._breaker(ex).is_open(thr, now):
                    bad.add(id(ex.device))
        return bad

    def _rebalance_locked(self) -> dict:
        """Monitor tick (lock held, pump thread): update the per-shard
        request-rate EWMA from the ``shard_batches`` stats deltas, then
        apply the adaptive policies — split the tail shard past its row
        budget, replicate the hottest shard / shed replicas of cooled
        ones, and re-replicate shards whose streams went unhealthy
        (failover), and — first of all — emergency-rebuild shards that
        device loss left with no live stream. One action of each kind
        per tick keeps rebalancing incremental (the next tick
        re-evaluates against the moved load)."""
        actions: dict = {"split": [], "replicated": [], "dropped": [],
                         "failover_replicated": [], "rebuilt": [],
                         "demoted": [], "promoted": []}
        sx = self._sharded_ex
        if sx is None:
            return actions
        self.stats["rebalances"] += 1
        # host-gather groups count as monitor work too: a miss-heavy
        # workload (everything off-device) must still tick, or nothing
        # would ever promote
        self._mon_mark = self.stats["launches"] + self.stats["host_gathers"]
        sb = self.stats["shard_batches"]
        a = self._mon_alpha
        for s in range(len(sb)):
            # launched batches + host-served chunks: a warm/cold shard's
            # misses never bump shard_batches, but they ARE load — the
            # promotion ladder orders by exactly this heat
            total = sb[s] + self._host_served[s]
            delta = total - self._mon_last[s]
            self._mon_last[s] = total
            self._mon_ewma[s] = a * delta + (1 - a) * self._mon_ewma[s]
        # -- policy 1: tail re-shard under streaming growth --
        if self.row_budget is not None and sx.tail_rows() > self.row_budget:
            old = len(sx.shards) - 1
            start, _ = sx.shards[old].shard_bounds
            cut = start + max(32, self.row_budget // 32 * 32)
            new = self._apply_split_locked(cut)
            actions["split"].append((old, new, cut))
        # -- policy 4: emergency rebuild of shards with zero live streams --
        # a shard orphaned by device loss must get a fresh stream before
        # normal serving resumes (host gathers cover it meanwhile); runs
        # before the replication policies so they see the rebuilt set
        for s in sorted(set(self._needs_rebuild)):
            if self._rebuild_shard_locked(s):
                actions["rebuilt"].append((s, sx.devices[s]))
        now = time.perf_counter()
        sick = {s for s in range(self._n_shards)
                if len(self._healthy_streams(s, now))
                < len(self._shard_streams(s))}
        cap = self.max_replicas
        if cap is None:
            cap = len({id(d) for d in sx.device_pool}) - 1
        # -- policy 2: hot-shard replication / cold-shard shedding --
        ewma = self._mon_ewma
        mean = sum(ewma) / max(len(ewma), 1)
        if mean > 0 and len(ewma) > 1:
            # an orphaned (rebuild-pending) or off-device (warm/cold)
            # shard is host-served — its load picture is a PROMOTION
            # signal, not a replication one
            hot = max((s for s in range(len(ewma))
                       if s not in self._needs_rebuild
                       and s not in self._offdevice),
                      key=lambda s: ewma[s], default=None)
            # hot = hot_factor x the mean of the OTHER shards — including
            # the hot shard in the reference would make the threshold
            # unreachable whenever hot_factor >= n_shards (a 4-shard mesh
            # under 100% skew never exceeds 4x its own all-shard mean)
            if hot is not None:
                others = (sum(ewma) - ewma[hot]) / (len(ewma) - 1)
                if ewma[hot] > self.hot_factor * others \
                        and len(sx.replicas[hot]) < cap:
                    # a replica is stream bytes too: route placement
                    # around devices without budget headroom, and skip
                    # the action entirely when nowhere fits
                    bavoid = self._budget_avoid_locked(
                        sx.executors[hot].stream_nbytes())
                    if any(id(d) not in bavoid for d in sx.device_pool):
                        actions["replicated"].append(
                            (hot, self._add_replica_locked(
                                hot, avoid=bavoid)))
            for s in range(len(ewma)):
                # never shed a replica of a shard with an unhealthy
                # stream — the copies are its availability margin
                if s != hot and sx.replicas[s] and ewma[s] < mean \
                        and s not in sick:
                    actions["dropped"].append(
                        (s, self._drop_replica_locked(s)))
                    break
        # -- policy 3: failover re-replication around unhealthy streams --
        # a shard with an open breaker and < 2 healthy copies gets a fresh
        # replica on a device that is NOT itself behind an open breaker, so
        # retries have somewhere healthy to fail over to while the sick
        # stream rides out its cooldown
        if sick:
            bad = self._unhealthy_devices(now)
            for s in sorted(sick):
                # rebuild-pending shards are policy 4's problem — a
                # replica would not make host-serving any healthier;
                # off-device shards host-serve by design (stale breaker
                # state from before their demotion is not a failover
                # signal either)
                if s in self._needs_rebuild or s in self._offdevice:
                    continue
                if len(self._healthy_streams(s, now)) < 2 \
                        and len(sx.replicas[s]) < cap:
                    avoid = bad | self._budget_avoid_locked(
                        sx.executors[s].stream_nbytes())
                    actions["failover_replicated"].append(
                        (s, self._add_replica_locked(s, avoid=avoid)))
        # -- policies 5-7: the tiered-residency ladder --
        self._tier_policy_locked(actions)
        return actions

    def _apply_split_locked(self, cut: int | None = None,
                            device=None) -> int:
        """Tail split + atomic routing-table swap (lock held, pump thread).

        Executor-level swap first (new shard plan/stream committed, bisect
        bounds flipped, old tail closed), then the service side: one new
        launch queue / in-flight window / stats lane APPENDED (existing
        shard indices never move — stats continuity), old-tail queued
        chunks re-routed to whichever side of the cut owns their rows, and
        the route generation bumped so any submit that raced the swap
        rebuilds its chunks instead of enqueueing against stale bounds.
        """
        self._require_mesh()
        sx = self._sharded_ex
        old = len(sx.shards) - 1
        new = sx.split_tail(cut=cut, device=device)
        self._queues.append(deque())
        self._inflights.append(deque())
        self._busy.append(0)
        for k in ("shard_launches", "shard_batches", "shard_bytes_h2d"):
            self.stats[k].append(0)
        self._mon_ewma.append(0.0)
        self._mon_last.append(0)
        self._stream_rr.append(0)
        self._stragglers.append(self._new_straggler())
        # the fresh tail commits hot (splits happen on the open, appending
        # shard — always device-resident); if that overflows the device
        # budget the next tier-policy tick demotes the coldest resident
        self._tier.append("hot")
        self.stats["tier_hot"] += 1
        self._warm_ticks.append(0)
        self._host_served.append(0)
        self._n_shards += 1
        self.stats["shard_splits"] += 1
        self._reroute_after_split(old, new)
        self._route_gen += 1
        self._work.notify_all()         # the new queue may be launchable
        return new

    def _reroute_after_split(self, old: int, new: int) -> None:
        """Move queued old-tail chunks whose rows now belong to the new
        shard (lock held). A chunk straddling the cut splits into two —
        its ticket's chunk count grows by one, each piece keeps its output
        destinations, so the request retires complete and in order."""
        sx = self._sharded_ex
        cut_local = int(sx.shards[new]._start - sx.shards[old]._start)
        q = self._queues[old]
        if not q:
            return
        keep: deque = deque()
        moved: deque = deque()
        for ch in q:
            below = ch.rows < cut_local
            if below.all():
                keep.append(ch)
                continue
            if not below.any():
                ch.rows = ch.rows - cut_local
                ch.shard = new
                moved.append(ch)
                continue
            pos = (ch.dest + np.arange(ch.n)
                   if isinstance(ch.dest, (int, np.integer)) else ch.dest)
            ra, rb = ch.rows[below], ch.rows[~below] - cut_local
            ka = _Chunk(ch.ticket, ra, ra.shape[0],
                        self._bucket(ra.shape[0]), old, pos[below],
                        ch.t_enq, klass=ch.klass)
            kb = _Chunk(ch.ticket, rb, rb.shape[0],
                        self._bucket(rb.shape[0]), new, pos[~below],
                        ch.t_enq, klass=ch.klass)
            keep.append(ka)
            moved.append(kb)
            self._chunks_total[ch.ticket] += 1
            # keep the submit-time accounting honest: the two pieces pad
            # (and range-classify) differently than the chunk they replace
            self.stats["padded_rows"] += (ka.bucket - ka.n) + \
                (kb.bucket - kb.n) - (ch.bucket - ch.n)
            self.stats["packed_ranges"] += (
                int(self._aligned_range(ka.rows)) +
                int(self._aligned_range(kb.rows)) -
                int(self._aligned_range(ch.rows)))
        q.clear()
        q.extend(keep)
        self._queues[new].extend(moved)

    # -- tiered residency (HBM-hot / host-warm / RLE-cold ladder) ---------------------
    def _set_tier_locked(self, s: int, tier: str) -> None:
        """Flip one shard's tier label + the gauge stats + the off-device
        routing set (lock held). The ONE place tier state changes, so the
        gauges can never drift from the labels."""
        old = self._tier[s]
        if old == tier:
            return
        self.stats["tier_" + old] -= 1
        self.stats["tier_" + tier] += 1
        self._tier[s] = tier
        if tier == "hot":
            self._offdevice.discard(s)
        else:
            self._offdevice.add(s)

    def _budget_avoid_locked(self, need: int) -> frozenset:
        """Device ids WITHOUT headroom for ``need`` more stream bytes
        (empty when uncapped) — the placement-avoid set replica adds pass
        so read fan-out respects the same budget residency does."""
        sx = self._sharded_ex
        if sx is None or sx.hbm_budget_bytes is None:
            return frozenset()
        ledger = sx.budget_ledger()
        return frozenset(id(d) for d in sx.device_pool
                         if not ledger.fits(id(d), need))

    def _demote_shard_locked(self, s: int, tier: str = "warm") -> int:
        """Move shard ``s`` down the ladder (lock held, pump thread).
        Returns the device bytes freed.

        ``warm``: every replica is dropped and the primary's resident
        words are dereferenced (in-flight launches finish — they hold
        their operands; the buffer frees when the last reference drops).
        ``cold``: additionally the host packed copy compresses to RLE
        runs (:meth:`_PackedShardPlan.demote_cold`) — misses then decode
        runs on the fly, still bit-exact. The open tail shard cannot go
        cold (its row range is still growing under appends); demote it
        to warm or :meth:`split_tail` first. Queued and future requests
        for the shard serve through the host path the moment the tier
        flips (:meth:`_pick_action` routes off-device shards to
        hostserve before considering launches)."""
        sx = self._sharded_ex
        sp = sx.shards[s]
        if tier == "cold" and sp._last:
            raise ValueError("the open tail shard cannot go cold (its RLE "
                             "runs would close a still-appending range); "
                             "demote to 'warm' or split_tail() first")
        if self._tier[s] == "cold" and tier == "warm":
            # UP-ladder within the host tiers: restore the packed copy,
            # drop the runs — not a demotion, nothing device-side changes
            if sp.is_cold:
                sp.rehydrate()
                self.stats["rehydrations"] += 1
            self._set_tier_locked(s, "warm")
            return 0
        if self._tier[s] == tier:
            return 0
        while sx.replicas[s]:
            self._drop_replica_locked(s)
        freed = sx.executors[s].evict_words()
        if tier == "cold" and not sp.is_cold:
            sp.demote_cold()
        self._set_tier_locked(s, tier)
        self._warm_ticks[s] = 0
        # a demoted shard host-serves by DESIGN — it no longer needs the
        # emergency rebuild a device loss may have queued for it
        self._needs_rebuild.discard(s)
        self.stats["demotions"] += 1
        return freed

    def _promote_shard_locked(self, s: int) -> bool:
        """Re-commit shard ``s``'s resident word stream (lock held, pump
        thread) — the UP move of the ladder. Cold shards rehydrate their
        host packed copy from the RLE runs first; the device commit is
        the same version-keyed put a refresh uses, and when the shard's
        home device died it rebuilds on a survivor instead
        (:meth:`ShardedFeatureExecutor.rebuild_on`). False when no device
        survives — the shard stays host-served (a cold one has still
        moved up to warm: its packed copy is back)."""
        sx = self._sharded_ex
        if self._tier[s] == "hot":
            return True
        sp = sx.shards[s]
        if sp.is_cold:
            sp.rehydrate()
            self.stats["rehydrations"] += 1
            if self._tier[s] == "cold":
                self._set_tier_locked(s, "warm")
        ex = sx.executors[s]
        down = set(self._device_health.down)
        if ex.device is not None and id(ex.device) in down:
            try:
                sx.rebuild_on(s, lost=down)
            except ValueError:
                return False        # no surviving device — stay host-served
            self._discard_breaker_locked(ex)
        else:
            ex.ensure_range_capacity(sp.n_rows)
        self._set_tier_locked(s, "hot")
        self._warm_ticks[s] = 0
        self._promote_pending.discard(s)
        self.stats["promotions"] += 1
        self._work.notify_all()     # the shard's queue is launchable again
        return True

    def _try_promote_locked(self, s: int) -> bool:
        """Budget-respecting promotion (lock held, pump thread): displace
        COLDER resident shards (strictly lower EWMA — equal-heat shards
        never thrash) off the target device until ``s`` fits, then
        promote. False when the stream can never fit, nothing colder can
        be displaced, or no device survives."""
        sx = self._sharded_ex
        if sx is None or s in self._needs_rebuild:
            return False
        if self._tier[s] == "hot":
            return True                   # idempotent (a free-beat promote
                                          # may have beaten this call)
        budget = sx.hbm_budget_bytes
        if budget is not None:
            ex = sx.executors[s]
            need = ex.stream_nbytes()
            if need > budget:
                return False              # a stream that can NEVER fit
            dev_id = id(ex.device) if ex.device is not None else None
            if dev_id is not None and dev_id in self._device_health.down:
                # the promote will rebuild on the least-loaded survivor;
                # post-promotion enforcement settles any overshoot there
                dev_id = None
            guard = 0
            while dev_id is not None \
                    and not sx.budget_ledger().fits(dev_id, need):
                victims = [v for v in range(self._n_shards)
                           if v != s and self._tier[v] == "hot"
                           and self._mon_ewma[v] < self._mon_ewma[s]
                           and any(id(e.device) == dev_id
                                   and e.resident_bytes() > 0
                                   for e in sx.stream_executors(v))]
                guard += 1
                if not victims or guard > self._n_shards:
                    return False          # nothing colder to displace
                self._demote_shard_locked(
                    min(victims, key=lambda v: self._mon_ewma[v]), "warm")
        ok = self._promote_shard_locked(s)
        if ok and budget is not None:
            self._enforce_budget_locked()
        return ok

    def _enforce_budget_locked(self, actions: dict | None = None) -> None:
        """Settle every device back under the byte budget (lock held):
        demote the coldest (min-EWMA) hot shard holding a stream on an
        over-budget device, repeat until under. Ground truth comes from
        :meth:`ShardedFeatureExecutor.device_bytes` (live buffers, never
        a ledger), so transients from splits, rebuilds and replica adds
        all settle here."""
        sx = self._sharded_ex
        if sx is None or sx.hbm_budget_bytes is None:
            return
        budget = sx.hbm_budget_bytes
        for _ in range(4 * self._n_shards + 8):
            over = {d: b for d, b in sx.device_bytes().items() if b > budget}
            if not over:
                return
            dev_id = next(iter(over))
            victims = [v for v in range(self._n_shards)
                       if self._tier[v] == "hot"
                       and any(id(e.device) == dev_id
                               and e.resident_bytes() > 0
                               for e in sx.stream_executors(v))]
            if not victims:
                return
            v = min(victims, key=lambda x: self._mon_ewma[x])
            self._demote_shard_locked(v, "warm")
            if actions is not None:
                actions["demoted"].append((v, "warm"))

    def _tier_policy_locked(self, actions: dict) -> None:
        """The monitor's residency policies (lock held, pump thread), run
        at the end of every rebalance tick:

        - **budget enforcement** — settle over-budget devices (coldest
          resident demotes to warm);
        - **cold aging** — a warm, closed, non-rebuilding shard quiet for
          ``cold_after`` consecutive ticks compresses to RLE runs (the
          host packed copy is the next-biggest residency after HBM);
        - **promotion** — the hottest off-device shard with real load
          moves up, displacing colder residents under the budget (misses
          also promote sooner through the pump's free-beat promote arm —
          this tick-side policy catches load the beat missed)."""
        sx = self._sharded_ex
        if sx is None:
            return
        self._enforce_budget_locked(actions)
        for s in range(self._n_shards):
            if self._tier[s] != "warm" or s in self._needs_rebuild \
                    or sx.shards[s]._last:
                continue
            self._warm_ticks[s] += 1
            if self._warm_ticks[s] >= self.cold_after:
                self._demote_shard_locked(s, "cold")
                actions["demoted"].append((s, "cold"))
        cand = [s for s in self._offdevice
                if s not in self._needs_rebuild and self._mon_ewma[s] > 0]
        if cand:
            s = max(cand, key=lambda i: self._mon_ewma[i])
            if self._try_promote_locked(s):
                actions["promoted"].append(s)

    @property
    def tiers(self) -> list[str]:
        """Residency tier per shard: 'hot' / 'warm' / 'cold'."""
        with self._lock:
            return list(self._tier)

    def device_bytes(self) -> dict[int, int]:
        """LIVE resident word-stream bytes per device (``id(device)``
        keyed) — what the budget is enforced against. Empty for
        unsharded services."""
        with self._lock:
            return ({} if self._sharded_ex is None
                    else self._sharded_ex.device_bytes())

    def demote(self, shard: int, tier: str = "warm") -> int:
        """Manually move ``shard`` down the ladder ('warm' frees its
        device words, 'cold' additionally compresses the host copy to RLE
        runs). Runs on the pump like every shard-set mutation; returns
        the device bytes freed. Requests keep serving bit-exact through
        the host path throughout."""
        if tier not in ("warm", "cold"):
            raise ValueError(f"tier must be 'warm' or 'cold', got {tier!r}")
        self._require_mesh()
        return self._run_admin(lambda: self._demote_shard_locked(shard, tier))

    def promote(self, shard: int) -> bool:
        """Manually promote ``shard`` to the hot tier (budget-respecting:
        colder residents are displaced to warm when the device is full).
        Returns False when it cannot fit or no device survives — the
        shard keeps host-serving."""
        self._require_mesh()
        return self._run_admin(lambda: self._try_promote_locked(shard))

    # -- result retrieval ----------------------------------------------------------
    def poll(self, ticket: int) -> bool:
        """True once the ticket has RESOLVED — its result is on host, or it
        failed and :meth:`result` will raise its typed error. Non-blocking
        and dispatch-free: the pumps own all launching/retiring. Raises
        KeyError for unknown/already-collected tickets (like ``result``) so
        a poll loop can't spin forever on a bad ticket."""
        with self._lock:
            self._check_pump()
            if ticket in self._results or ticket in self._errors:
                return True
            if ticket not in self._chunks_total:
                raise KeyError(f"unknown or already-collected ticket {ticket}")
            return False

    def _queued_while_paused(self, ticket: int | None) -> bool:
        """True when blocking on this work would deadlock: the pumps are
        paused (and not shutting down, which overrides pause) and the
        awaited chunks are still queued — nothing will ever launch them
        until ``resume()``. Lock held."""
        if not self._paused or self._shutdown:
            return False
        if ticket is None:
            return any(self._queues)
        return any(ch.ticket == ticket for q in self._queues for ch in q)

    def result(self, ticket: int,
               timeout: float | None = None) -> np.ndarray:
        """Block until the ticket RESOLVES: return its features, or raise
        its typed error (:class:`ServeError`; :class:`DeadlineExceeded`
        when its ``deadline_ms`` expired — both consumed, like a result).

        Purely a wait: the pumps launch and retire; this just sleeps on
        the service condition until the ticket lands (or is unknown).
        ``timeout`` (seconds) bounds the wait itself — a builtin
        ``TimeoutError`` is raised when it elapses, and the ticket stays
        pending and retrievable. Raises RuntimeError instead of
        deadlocking if the service is paused with this ticket's chunks
        still unlaunched.
        """
        deadline = None if timeout is None \
            else time.perf_counter() + timeout
        with self._lock:
            # claim the ticket so a concurrent drain() can't sweep it away
            # between a pump landing it and this thread waking up
            self._claimed.add(ticket)
            try:
                while True:
                    self._check_pump()
                    if ticket in self._results:
                        return self._results.pop(ticket)
                    err = self._errors.pop(ticket, None)
                    if err is not None:
                        raise err
                    if ticket not in self._chunks_total:
                        raise KeyError(
                            f"unknown or already-collected ticket {ticket}")
                    if self._queued_while_paused(ticket):
                        raise RuntimeError(
                            f"ticket {ticket} is queued but the service is "
                            "paused — resume() before blocking on results")
                    wait = 0.5
                    if deadline is not None:
                        left = deadline - time.perf_counter()
                        if left <= 0:
                            raise TimeoutError(
                                f"result({ticket}) timed out after "
                                f"{timeout} s")
                        wait = min(wait, left)
                    self._cv.wait(timeout=wait)
            finally:
                self._claimed.discard(ticket)

    def drain(self, timeout: float | None = None) -> dict[int, np.ndarray]:
        """Wait for every pump to finish everything queued/in flight;
        return {ticket: features} collected — except tickets another thread
        is blocked on in result(), which stay theirs. Tickets that FAILED
        are not in the dict — their typed errors stay retrievable via
        :meth:`result`/:meth:`collect`. ``timeout`` (seconds) bounds the
        wait with a builtin ``TimeoutError`` (nothing is collected then).
        Raises RuntimeError instead of deadlocking if called while paused
        with chunks queued."""
        deadline = None if timeout is None \
            else time.perf_counter() + timeout
        with self._lock:
            try:
                # a drain wants everything NOW: partial groups stop
                # lingering while ANY drain is in progress (a counter, so
                # one drain finishing cannot un-flush a concurrent one)
                self._flushes += 1
                self._work.notify_all()
                while not self._all_idle():
                    self._check_pump()
                    if self._queued_while_paused(None):
                        raise RuntimeError("queue is held by pause() — "
                                           "resume() before drain()")
                    wait = 0.5
                    if deadline is not None:
                        left = deadline - time.perf_counter()
                        if left <= 0:
                            raise TimeoutError(
                                f"drain() timed out after {timeout} s")
                        wait = min(wait, left)
                    self._idle.wait(timeout=wait)
                self._check_pump()
            finally:
                self._flushes -= 1
            out = {t: r for t, r in self._results.items()
                   if t not in self._claimed}
            for t in out:
                del self._results[t]
            return out

    def collect(self, timeout: float | None = None) -> dict:
        """Drain, then return EVERYTHING that resolved: ``{ticket:
        features | ServeError}`` — completed tickets map to their arrays,
        failed ones to their typed errors (both consumed, retrieved once).
        The 'give me all outcomes, including what broke' retrieval a
        caller uses after a faulty period; check each value with
        ``isinstance(v, Exception)``. ``timeout`` as in :meth:`drain`."""
        out: dict = dict(self.drain(timeout))
        with self._lock:
            errs = {t: e for t, e in self._errors.items()
                    if t not in self._claimed}
            for t in errs:
                del self._errors[t]
        out.update(errs)
        return out

    # -- predicate pushdown queries (no pump involvement) -----------------------------
    def _pushdown_ex(self):
        if not self.packed:
            raise RuntimeError("predicate pushdown needs a packed plan "
                               "(resident word streams)")
        return self._sharded_ex if self._sharded_ex is not None \
            else self._executor

    def filtered_rows(self, where) -> np.ndarray:
        """Matching row indices via the device predicate scan (per shard on
        a mesh service, matches found where the data lives)."""
        return self._pushdown_ex().filtered_rows(where)

    def count_where(self, where) -> int:
        """SELECT COUNT(*) WHERE — one device scan + reduction per shard."""
        return self._pushdown_ex().count_where(where)

    def groupby_where(self, column: str, where):
        """GROUP BY column COUNT(*) WHERE — masked device histograms."""
        return self._pushdown_ex().groupby_where(column, where)

    def agg_where(self, where, column: str, agg: str = "count") -> float:
        """Masked count/sum/mean of ``column`` under a predicate."""
        return self._pushdown_ex().agg_where(where, column, agg)

    # -- streaming convenience -------------------------------------------------------
    def serve_stream(self, row_batches):
        """Featurize an iterator of row-index batches through the pumps.

        Yields (rows, features) in submission order while keeping up to
        ``prefetch`` launches in flight per shard on the pump side.
        """
        def gen():
            # the pumps run the prefetch-deep windows; this FIFO only stops
            # the producer racing ahead of the consumer
            pending: deque[tuple[np.ndarray, int]] = deque()
            for rows in row_batches:
                rows = np.asarray(rows)
                pending.append((rows, self.submit(rows)))
                if len(pending) > self.prefetch:
                    r, t = pending.popleft()
                    yield r, self.result(t)
            while pending:
                r, t = pending.popleft()
                yield r, self.result(t)
        return gen()

    # -- reporting --------------------------------------------------------------
    @property
    def classes(self) -> dict[str, RequestClass]:
        """The registered request classes (always includes 'default')."""
        return dict(self._classes)

    def latency_percentile(self, q: float,
                           klass: str | None = None) -> float:
        """The q-th per-ticket latency percentile in SECONDS from the
        streaming histogram — every completed ticket since construction,
        not the ``latencies`` deque's most-recent-8192 window (which is
        what ``np.percentile(svc.latencies, ...)`` silently reports once
        ``stats['latency_samples_total']`` exceeds the window).
        ``klass`` narrows to one request class."""
        with self._lock:
            h = self._lat_hist if klass is None \
                else self._class_stats[klass]["hist"]
            return h.percentile(q)

    def class_stats(self) -> dict[str, dict]:
        """Per-request-class serving picture: counts (requests /
        completed / failed / pending / rows) plus the class's streaming
        latency summary (p50/p99/min/max/mean ms over ALL its completed
        tickets). JSON-safe — what the front door's stats endpoint and
        the per-class SLO gates read."""
        with self._lock:
            out = {}
            for name, cs in self._class_stats.items():
                resolved = cs["completed"] + cs["failed"]
                out[name] = {
                    "requests": cs["requests"],
                    "completed": cs["completed"],
                    "failed": cs["failed"],
                    "pending": max(cs["requests"] - resolved, 0),
                    "rows": cs["rows"],
                    **cs["hist"].summary()}
            return out

    def reset_latency_window(self) -> None:
        """Start a fresh latency observation window: clears the
        bench-compat ``latencies`` deque, the streaming histograms
        (global and per class) and ``stats['latency_samples_total']``.
        The serving ledger (requests/completed/failed counters) is NOT
        touched — this resets what the percentiles COVER (post-warmup
        benching, scrape intervals), not what happened."""
        with self._lock:
            self.latencies.clear()
            self._lat_hist = LatencyHistogram()
            self.stats["latency_samples_total"] = 0
            for cs in self._class_stats.values():
                cs["hist"] = LatencyHistogram()

    def throughput_stats(self, wall_s: float) -> dict:
        rows = self.stats["rows"]
        done = self.stats["completed"]
        failed = self.stats["failed_tickets"]
        req = self.stats["requests"]
        resolved = done + failed
        wall_ok = wall_s > 0
        return {**self.stats, "wall_s": wall_s,
                # wall_s <= 0 cannot yield a rate: report 0.0 with the
                # flag set rather than float('inf'), which json.dump
                # renders as the non-standard Infinity token downstream
                # parsers reject
                "wall_s_invalid": not wall_ok,
                "rows_per_s": rows / wall_s if wall_ok else 0.0,
                "mean_latency_s": (self.stats["latency_s_total"] / done
                                   if done else 0.0),
                # the availability the chaos gates assert on: completed
                # over RESOLVED tickets (completed + failed) — calling
                # this mid-flight no longer counts still-pending work as
                # failures; `pending` reports it explicitly
                "pending": max(req - resolved, 0),
                "availability": done / resolved if resolved else 1.0,
                "pad_overhead": (self.stats["padded_rows"] /
                                 max(rows + self.stats["padded_rows"], 1))}
