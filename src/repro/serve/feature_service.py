"""FeatureService: async, double-buffered ADV feature serving.

The serving-side rendering of the paper's §6 pipeline: learned features are
served directly out of the data system ('codes in, features out'), not
exported and recomputed. A request names table rows; the service slices the
plan's stacked code matrix on the host, pads the batch to a static bucket
shape (the same trick :class:`repro.serve.engine.ServeEngine` uses for token
batches, so jit compiles once per bucket), ships ONE int32 code matrix to the
device, and runs the fused ADV gather — optionally the one-pass multi-table
Pallas kernel.

Dispatch is asynchronous and double-buffered: up to ``prefetch`` (>= 2)
device gathers are kept in flight, so host code-slicing + ``device_put`` for
request i+1 overlaps the device gather for request i. Results are retired to
host only when the in-flight window is full or the caller asks for them.

Partitioned serving: with ``sharded=True`` the service builds per-IMCU shard
plans (:meth:`FeaturePlan.imcu_shards`) and routes each request's rows to
their owning partitions, so only partition-local code streams are touched —
device ADV tables are shared across shards.

Packed serving: over a ``FeaturePlan(packed=True)`` the word streams are
DEVICE-resident (32/bits x smaller than the int32 matrix they replace) and a
request whose rows form a word-aligned contiguous range dispatches as a pure
device-side range gather — the fused unpack+gather kernel path — moving
nothing to the device but a start index. Up to ``coalesce`` queued range
chunks of the same bucket shape are served by ONE device launch
(:meth:`FeatureExecutor._multi_range_future`), amortizing launch overhead
across requests; ``poll``/``result``/``drain`` flush the coalescing buffer,
so partial groups never add more than one queue-depth of latency.
Arbitrary-row requests still work: they fall back to a per-batch host
word-gather (O(batch) words touched, the full int32 stream is never
materialized). ``stats['packed_ranges']`` / ``stats['bytes_h2d']`` report
how much traffic the fast path saved.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pipeline import FeatureExecutor, FeaturePipeline, FeaturePlan

DEFAULT_BUCKETS = (64, 256, 1024)


@dataclass
class FeatureRequest:
    """One queued featurization request (``rows`` are table row indices)."""
    rows: np.ndarray
    ticket: int
    submitted_at: float = field(default_factory=time.perf_counter)

    @property
    def n(self) -> int:
        return int(self.rows.shape[0])


class FeatureService:
    """Request-queue-driven feature serving over a compiled FeaturePlan."""

    def __init__(self, plan: FeaturePlan | FeaturePipeline, *,
                 use_kernel: bool = False, prefetch: int = 2,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 sharded: bool = False, coalesce: int = 4):
        if isinstance(plan, FeaturePipeline):
            plan = plan.plan
        if prefetch < 2:
            raise ValueError("FeatureService is double-buffered: prefetch >= 2")
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad bucket sizes {buckets!r}")
        self.plan = plan
        self.packed = plan.packed
        if self.packed and sharded:
            raise ValueError("sharded serving routes int32 slices; packed "
                             "plans serve ranges from device-resident words")
        self.prefetch = prefetch
        self.buckets = tuple(sorted(buckets))
        self.use_kernel = use_kernel
        self.sharded = sharded
        # ONE executor either way — device ADV tables are shared; sharding
        # only changes where the host code slices come from
        self._executor = FeatureExecutor(plan, use_kernel=use_kernel,
                                         prefetch=prefetch)
        if self._executor.kernel_active:
            # align buckets to the fused kernel's row tile, else every
            # bucket gets padded AGAIN to a bn multiple inside the kernel
            bn = plan.fused_tables().bn
            self.buckets = tuple(sorted(
                {-(-b // bn) * bn for b in self.buckets}))
        elif self.packed:
            # word-aligned buckets so range chunks slice on word boundaries
            self.buckets = tuple(sorted(
                {-(-b // 32) * 32 for b in self.buckets}))
        if self.packed:
            # one capacity put up front: any in-range request chunk can then
            # be served without mid-stream device re-puts
            self._executor.ensure_range_capacity(
                plan.n_rows + self.buckets[-1])
        if sharded:
            self._shard_bounds = plan.imcu_bounds()
            self._shards = plan.imcu_shards()
            self._starts = np.array([b[0] for b in self._shard_bounds])
        if coalesce < 1:
            raise ValueError("coalesce must be >= 1")
        self.coalesce = coalesce if self.packed else 1
        # one entry per dispatched LAUNCH: (device buffer, parts) where each
        # part is (ticket, n_valid_rows, chunk_idx, k) — k indexes into a
        # coalesced (K, bucket, F) buffer, None for a single-chunk buffer.
        # The prefetch window bounds launches, so an oversized request can't
        # pile unbounded output buffers on device.
        self._inflight: deque[tuple[jnp.ndarray, list]] = deque()
        # queued-but-unlaunched range chunks, per bucket shape:
        # bucket -> [(ticket, start_row, n_valid, chunk_idx), ...]
        self._range_buf: dict[int, list] = {}
        self._partial: dict[int, dict[int, np.ndarray]] = {}
        self._chunks_total: dict[int, int] = {}
        self._results: dict[int, np.ndarray] = {}
        self._next_ticket = 0
        self._submitted_at: dict[int, float] = {}
        self.stats = {"requests": 0, "rows": 0, "padded_rows": 0,
                      "batches": 0, "launches": 0, "max_inflight": 0,
                      "latency_s_total": 0.0, "completed": 0,
                      "packed_ranges": 0, "bytes_h2d": 0}

    # -- request intake -------------------------------------------------------------
    def submit(self, rows: np.ndarray) -> int:
        """Enqueue a featurization request; returns a ticket for the result.

        Dispatch happens immediately (async): the device starts gathering
        while the caller goes on to submit more work.
        """
        rows = np.asarray(rows, dtype=np.int64).reshape(-1)
        if rows.size == 0:
            raise ValueError("empty request")
        if rows.min() < 0 or rows.max() >= self.plan.n_rows:
            raise IndexError(f"row indices out of range [0, {self.plan.n_rows})")
        ticket = self._next_ticket
        self._next_ticket += 1
        req = FeatureRequest(rows=rows, ticket=ticket)
        self._submitted_at[ticket] = req.submitted_at
        self.stats["requests"] += 1
        self.stats["rows"] += rows.size
        self._dispatch(req)
        return ticket

    # -- bucketing ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        """Smallest static bucket >= n (largest bucket caps a chunk)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _slice_padded(self, rows: np.ndarray, bucket: int) -> np.ndarray:
        """Host work for one chunk: fancy-index + right-pad to bucket shape."""
        pad = bucket - rows.shape[0]
        if pad:
            # repeat the last row: always a valid index, rows sliced off later
            rows = np.concatenate([rows, np.full(pad, rows[-1])])
            self.stats["padded_rows"] += pad
        if self.sharded:
            return self._gather_sharded_codes(rows)
        # packed plans word-gather just these rows (no int32 stream exists)
        return self.plan.host_codes(rows)

    def _gather_sharded_codes(self, rows: np.ndarray) -> np.ndarray:
        """Route rows to their owning IMCU partitions (partition-local slices).

        Rows appended after plan compile (streaming inserts via
        ``FeaturePlan.refresh``) live past the last IMCU boundary and are
        served from the plan's own code matrix tail.
        """
        out = np.empty((len(self.plan.plans), rows.shape[0]), np.int32)
        tail_start = self._shard_bounds[-1][1]
        tail = rows >= tail_start
        if tail.any():
            out[:, tail] = self.plan.codes_matrix[:, rows[tail]]
        rows_in, (idx_in,) = rows[~tail], np.nonzero(~tail)
        shard_of = np.searchsorted(self._starts, rows_in, side="right") - 1
        for s in np.unique(shard_of):
            mask = shard_of == s
            local = rows_in[mask] - self._shard_bounds[s][0]
            out[:, idx_in[mask]] = self._shards[s].codes_matrix[:, local]
        return out

    # -- the async pump ----------------------------------------------------------
    @staticmethod
    def _aligned_range(rows: np.ndarray) -> bool:
        """True for a word-aligned contiguous run (the packed fast path)."""
        return (int(rows[0]) % 32 == 0
                and int(rows[-1]) - int(rows[0]) == rows.shape[0] - 1
                and bool((np.diff(rows) == 1).all()))

    def _dispatch(self, req: FeatureRequest) -> None:
        starts = list(range(0, req.n, self.buckets[-1]))
        self._chunks_total[req.ticket] = len(starts)
        for j, start in enumerate(starts):
            rows = req.rows[start:start + self.buckets[-1]]
            bucket = self._bucket(rows.shape[0])
            if self.packed and self._aligned_range(rows):
                # pure device-side range gather off the resident words: the
                # only host->device traffic is the start index. Queue the
                # chunk; a full coalescing group launches as ONE gather.
                buf = self._range_buf.setdefault(bucket, [])
                buf.append((req.ticket, int(rows[0]), rows.shape[0], j))
                self.stats["packed_ranges"] += 1
                self.stats["padded_rows"] += bucket - rows.shape[0]
                if len(buf) >= self.coalesce:
                    self._flush_bucket(bucket)
                continue
            if len(self._inflight) >= self.prefetch:
                self._retire_one()
            codes = self._slice_padded(rows, bucket)
            self.stats["bytes_h2d"] += int(codes.nbytes)
            dev = self._executor.gather_device(jax.device_put(codes))
            self._push_inflight(dev, [(req.ticket, rows.shape[0], j, None)])

    def _push_inflight(self, dev, parts: list) -> None:
        self._inflight.append((dev, parts))
        self.stats["batches"] += len(parts)
        self.stats["launches"] += 1
        self.stats["max_inflight"] = max(self.stats["max_inflight"],
                                         len(self._inflight))

    def _flush_bucket(self, bucket: int) -> None:
        """Launch one coalesced multi-range gather for a bucket's queue.

        The start vector is padded to the full ``coalesce`` width (repeating
        the last start; surplus outputs are simply never read) so every
        launch shares ONE compiled (K, bucket) shape — a partial group must
        not pay a fresh XLA trace.
        """
        buf = self._range_buf.pop(bucket, [])
        if not buf:
            return
        if len(self._inflight) >= self.prefetch:
            self._retire_one()
        starts = [c[1] for c in buf]
        starts += [starts[-1]] * (self.coalesce - len(starts))
        dev = self._executor._multi_range_future(np.array(starts), bucket)
        self._push_inflight(dev, [(t, n, j, k)
                                  for k, (t, _, n, j) in enumerate(buf)])

    def _flush_ranges(self) -> None:
        for bucket in list(self._range_buf):
            self._flush_bucket(bucket)

    def _retire_one(self) -> None:
        dev, parts = self._inflight.popleft()
        arr = np.asarray(dev)
        for ticket, n, j, k in parts:
            piece = (arr if k is None else arr[k])[:n]
            chunks = self._partial.setdefault(ticket, {})
            chunks[j] = piece
            if len(chunks) < self._chunks_total[ticket]:
                continue
            del self._partial[ticket]
            del self._chunks_total[ticket]
            ordered = [chunks[i] for i in range(len(chunks))]
            self._results[ticket] = (ordered[0] if len(ordered) == 1
                                     else np.concatenate(ordered, axis=0))
            t0 = self._submitted_at.pop(ticket, None)
            if t0 is not None:
                self.stats["latency_s_total"] += time.perf_counter() - t0
                self.stats["completed"] += 1

    def _pending(self, ticket: int) -> bool:
        return (any(t == ticket for _, parts in self._inflight
                    for t, _, _, _ in parts)
                or any(t == ticket for buf in self._range_buf.values()
                       for t, _, _, _ in buf))

    # -- result retrieval ----------------------------------------------------------
    def poll(self, ticket: int) -> bool:
        """True once the ticket's result is on host (non-blocking): queued
        range groups are launched and in-flight buffers that are already
        finished are retired first. Raises KeyError for unknown/already-
        collected tickets (like ``result``) so a poll loop can't spin
        forever on a bad ticket."""
        self._flush_ranges()
        while self._inflight and self._inflight[0][0].is_ready():
            self._retire_one()
        if ticket in self._results:
            return True
        if not self._pending(ticket):
            raise KeyError(f"unknown or already-collected ticket {ticket}")
        return False

    def result(self, ticket: int) -> np.ndarray:
        """Block until the ticket's features are on host and return them."""
        if ticket not in self._results and not self._pending(ticket):
            raise KeyError(f"unknown or already-collected ticket {ticket}")
        self._flush_ranges()
        while ticket not in self._results:
            self._retire_one()
        return self._results.pop(ticket)

    def drain(self) -> dict[int, np.ndarray]:
        """Retire everything in flight; return {ticket: features} collected."""
        self._flush_ranges()
        while self._inflight:
            self._retire_one()
        out, self._results = self._results, {}
        return out

    # -- streaming convenience -------------------------------------------------------
    def serve_stream(self, row_batches):
        """Featurize an iterator of row-index batches with the double buffer.

        Yields (rows, features) in submission order while keeping ``prefetch``
        batches in flight.
        """
        def gen():
            # submit() already runs the prefetch-deep double buffer; this
            # FIFO only stops the producer racing ahead of the consumer
            pending: deque[tuple[np.ndarray, int]] = deque()
            for rows in row_batches:
                rows = np.asarray(rows)
                pending.append((rows, self.submit(rows)))
                if len(pending) > self.prefetch:
                    r, t = pending.popleft()
                    yield r, self.result(t)
            while pending:
                r, t = pending.popleft()
                yield r, self.result(t)
        return gen()

    # -- reporting --------------------------------------------------------------
    def throughput_stats(self, wall_s: float) -> dict:
        rows = self.stats["rows"]
        done = self.stats["completed"]
        return {**self.stats, "wall_s": wall_s,
                "rows_per_s": rows / wall_s if wall_s > 0 else float("inf"),
                "mean_latency_s": (self.stats["latency_s_total"] / done
                                   if done else 0.0),
                "pad_overhead": (self.stats["padded_rows"] /
                                 max(rows + self.stats["padded_rows"], 1))}
