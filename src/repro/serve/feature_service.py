"""FeatureService: pump-driven, coalescing ADV feature serving.

The serving-side rendering of the paper's §6 pipeline: learned features are
served directly out of the data system ('codes in, features out'), not
exported and recomputed. A request names table rows; the service chunks it
to static bucket shapes (the same trick :class:`repro.serve.engine.ServeEngine`
uses for token batches, so jit compiles once per bucket) and queues the
chunks on ONE unified launch queue.

Serving architecture (request -> bucket -> unified coalescer -> pump ->
launch)::

    submit(rows) --chunk--> [unified launch queue] --group--> pump thread
                                                                 |
              results <-- retire (host) <-- in-flight ring <-- launch

A dedicated background pump thread drains the queue: per tick it pops up to
``coalesce`` queued chunks of the same bucket shape — aligned ranges and
arbitrary row sets alike — and serves the whole group with ONE device
launch. ``submit`` only enqueues; ``poll``/``result``/``drain`` only inspect
or wait for results. No caller ever dispatches device work, so many client
threads can submit/poll/result concurrently while exactly one thread talks
to the device.

Packed serving: over a ``FeaturePlan(packed=True)`` the word streams are
DEVICE-resident (32/bits x smaller than the int32 matrix they replace) and
EVERY chunk — word-aligned range or arbitrary row set — is served by the
indexed gather (:meth:`FeatureExecutor._rows_future`): the kernel computes
word index + bit offset in-kernel against the resident streams, so the
only host->device traffic is the padded (coalesce x bucket) int32 index
vector. ``stats['bytes_h2d']`` therefore reports INDEX bytes (4B x padded
rows, independent of column count), not code bytes; int32 plans still ship
(C, bucket) code slices and account those. ``stats['packed_ranges']`` counts
chunks that were word-aligned contiguous runs (the scan pattern), served by
the same unified launch as everything else.

The pump keeps up to ``prefetch`` (>= 2) launches in flight, retiring the
oldest when the window fills — device gathers for tick i+1 overlap the host
retire of tick i. Backpressure grows groups naturally: while the device
works, fresh chunks pile into the queue and the next tick coalesces more.
``pause``/``resume`` hold launches (queueing continues) so callers can force
maximal coalescing; ``shutdown`` (also via the context-manager protocol)
drains the queue and joins the pump thread. Services hold a live thread —
call :meth:`shutdown` (or use ``with``) when disposing of one.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.core.pipeline import (FeatureExecutor, FeaturePipeline,
                                 FeaturePlan, pad_rows_edge)

DEFAULT_BUCKETS = (64, 256, 1024)


@dataclass
class _Chunk:
    """One bucket-shaped slice of a request, queued for the pump."""
    ticket: int
    rows: np.ndarray        # raw (unpadded) row indices for this chunk
    n: int                  # valid rows (== rows.shape[0])
    j: int                  # chunk index within the request
    bucket: int             # static launch shape this chunk pads to


class FeatureService:
    """Request-queue-driven feature serving over a compiled FeaturePlan."""

    def __init__(self, plan: FeaturePlan | FeaturePipeline, *,
                 use_kernel: bool = False, prefetch: int = 2,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 sharded: bool = False, coalesce: int = 4):
        if isinstance(plan, FeaturePipeline):
            plan = plan.plan
        if prefetch < 2:
            raise ValueError("FeatureService is double-buffered: prefetch >= 2")
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad bucket sizes {buckets!r}")
        self.plan = plan
        self.packed = plan.packed
        if self.packed and sharded:
            raise ValueError("sharded serving routes int32 slices; packed "
                             "plans serve indexed gathers from "
                             "device-resident words")
        self.prefetch = prefetch
        self.buckets = tuple(sorted(buckets))
        self.use_kernel = use_kernel
        self.sharded = sharded
        # ONE executor either way — device ADV tables are shared; sharding
        # only changes where the host code slices come from
        self._executor = FeatureExecutor(plan, use_kernel=use_kernel,
                                         prefetch=prefetch)
        if self._executor.kernel_active:
            # align buckets to the fused kernel's row tile, else every
            # bucket gets padded AGAIN to a bn multiple inside the kernel
            bn = plan.fused_tables().bn
            self.buckets = tuple(sorted(
                {-(-b // bn) * bn for b in self.buckets}))
        elif self.packed:
            # word-aligned buckets keep the range iterator's discipline and
            # one compiled indexed shape per bucket
            self.buckets = tuple(sorted(
                {-(-b // 32) * 32 for b in self.buckets}))
        if sharded:
            self._shard_bounds = plan.imcu_bounds()
            self._shards = plan.imcu_shards()
            self._starts = np.array([b[0] for b in self._shard_bounds])
        if coalesce < 1:
            raise ValueError("coalesce must be >= 1")
        self.coalesce = coalesce if self.packed else 1
        # -- pump-shared state: everything below is guarded by _lock --
        # unified launch queue: every chunk of every request, FIFO
        self._queue: deque[_Chunk] = deque()
        # one entry per dispatched LAUNCH: (device buffer, parts) where each
        # part is (ticket, n_valid_rows, chunk_idx, row_off) — row_off is
        # the chunk's start row in the flat (rows, F) launch buffer
        self._inflight: deque[tuple[jnp.ndarray, list]] = deque()
        self._partial: dict[int, dict[int, np.ndarray]] = {}
        self._chunks_total: dict[int, int] = {}
        self._results: dict[int, np.ndarray] = {}
        self._claimed: set[int] = set()     # tickets a result() call waits on
        self._next_ticket = 0
        self._submitted_at: dict[int, float] = {}
        self._busy = 0              # launches/retires mid-flight in the pump
        self._paused = False
        self._shutdown = False
        self._pump_error: BaseException | None = None
        self.stats = {"requests": 0, "rows": 0, "padded_rows": 0,
                      "batches": 0, "launches": 0, "max_inflight": 0,
                      "latency_s_total": 0.0, "completed": 0,
                      "packed_ranges": 0, "bytes_h2d": 0}
        # three conditions over ONE lock, so each event wakes only the
        # threads that care (on small-core hosts a spurious wake steals GIL
        # time from the XLA compute the pump is trying to overlap):
        #   _work — the pump sleeps here; submit/pause/shutdown notify
        #   _cv   — result()/poll() waiters; notified when a ticket lands
        #   _idle — drain() waiters; notified when the pump goes fully idle
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._cv = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._pump = threading.Thread(target=self._pump_loop,
                                      name="feature-service-pump",
                                      daemon=True)
        self._pump.start()

    # -- lifecycle ------------------------------------------------------------------
    def __enter__(self) -> "FeatureService":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def shutdown(self, drain: bool = True) -> None:
        """Stop the pump thread and join it.

        ``drain=True`` (default) serves everything already queued first (an
        orderly drain — results stay retrievable via :meth:`result` /
        :meth:`drain`); ``drain=False`` discards queued-but-unlaunched
        chunks, forgetting their tickets. Idempotent.
        """
        with self._lock:
            if not drain:
                dropped = {ch.ticket for ch in self._queue}
                self._queue.clear()
                for t in dropped:
                    self._chunks_total.pop(t, None)
                    self._partial.pop(t, None)
                    self._submitted_at.pop(t, None)
            self._shutdown = True
            self._notify_everyone()
        self._pump.join()

    def _notify_everyone(self) -> None:
        """Wake every waiter class (lock held) — shutdown/error paths."""
        self._work.notify_all()
        self._cv.notify_all()
        self._idle.notify_all()

    def _check_pump(self) -> None:
        if self._pump_error is not None:
            raise RuntimeError("feature-service pump thread died") \
                from self._pump_error

    def pause(self) -> None:
        """Hold launches (submissions still queue) — lets a caller batch a
        burst of submits into maximally coalesced launches."""
        with self._lock:
            self._paused = True
            self._work.notify_all()

    def resume(self) -> None:
        with self._lock:
            self._paused = False
            self._work.notify_all()

    # -- request intake -------------------------------------------------------------
    def submit(self, rows: np.ndarray) -> int:
        """Enqueue a featurization request; returns a ticket for the result.

        Only queues: the background pump picks the chunks up, coalesces them
        with other queued work and launches — the caller goes on submitting
        while the device gathers.
        """
        rows = np.asarray(rows, dtype=np.int64).reshape(-1)
        if rows.size == 0:
            raise ValueError("empty request")
        if rows.min() < 0 or rows.max() >= self.plan.n_rows:
            raise IndexError(f"row indices out of range [0, {self.plan.n_rows})")
        # chunking and the O(chunk) alignment scan are pure functions of
        # the request — do them OUTSIDE the lock the pump contends for
        cap = self.buckets[-1]
        pieces, padded, aligned = [], 0, 0
        for j, start in enumerate(range(0, rows.shape[0], cap)):
            chunk = rows[start:start + cap]
            bucket = self._bucket(chunk.shape[0])
            padded += bucket - chunk.shape[0]
            if self.packed and self._aligned_range(chunk):
                aligned += 1
            pieces.append((chunk, chunk.shape[0], j, bucket))
        with self._lock:
            self._check_pump()
            if self._shutdown:
                raise RuntimeError("service is shut down")
            ticket = self._next_ticket
            self._next_ticket += 1
            self._submitted_at[ticket] = time.perf_counter()
            self.stats["requests"] += 1
            self.stats["rows"] += rows.size
            self.stats["padded_rows"] += padded
            self.stats["packed_ranges"] += aligned
            self._chunks_total[ticket] = len(pieces)
            for chunk, n, j, bucket in pieces:
                self._queue.append(_Chunk(ticket, chunk, n, j, bucket))
            self._work.notify_all()
        return ticket

    # -- bucketing ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        """Smallest static bucket >= n (largest bucket caps a chunk)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _slice_padded(self, rows: np.ndarray, bucket: int) -> np.ndarray:
        """Host work for one int32 chunk: fancy-index + right-pad to bucket."""
        rows = pad_rows_edge(rows, bucket)
        if self.sharded:
            return self._gather_sharded_codes(rows)
        return self.plan.host_codes(rows)

    def _gather_sharded_codes(self, rows: np.ndarray) -> np.ndarray:
        """Route rows to their owning IMCU partitions (partition-local slices).

        Rows appended after plan compile (streaming inserts via
        ``FeaturePlan.refresh``) live past the last IMCU boundary and are
        served from the plan's own code matrix tail.
        """
        out = np.empty((len(self.plan.plans), rows.shape[0]), np.int32)
        tail_start = self._shard_bounds[-1][1]
        tail = rows >= tail_start
        if tail.any():
            out[:, tail] = self.plan.codes_matrix[:, rows[tail]]
        rows_in, (idx_in,) = rows[~tail], np.nonzero(~tail)
        shard_of = np.searchsorted(self._starts, rows_in, side="right") - 1
        for s in np.unique(shard_of):
            mask = shard_of == s
            local = rows_in[mask] - self._shard_bounds[s][0]
            out[:, idx_in[mask]] = self._shards[s].codes_matrix[:, local]
        return out

    @staticmethod
    def _aligned_range(rows: np.ndarray) -> bool:
        """True for a word-aligned contiguous run (the scan pattern) —
        tracked in ``stats['packed_ranges']``; served by the same unified
        indexed launch as arbitrary row sets. The O(1) prefix checks gate
        the O(n) scan: this runs under the service lock on every submit."""
        if int(rows[0]) % 32 or \
                int(rows[-1]) - int(rows[0]) != rows.shape[0] - 1:
            return False
        return bool((np.diff(rows) == 1).all())

    # -- the background pump ---------------------------------------------------------
    def _pump_loop(self) -> None:
        """Drain the unified queue until shutdown: coalesce -> launch ->
        retire, with a ``prefetch``-deep in-flight window. The ONLY thread
        that dispatches device work or blocks on device buffers.

        Wake discipline: the pump only notifies ``_cv`` when a ticket's
        result actually landed and ``_idle`` when it has nothing left to do
        — launching and window churn wake nobody, so client threads stay
        parked (and off the GIL) while the device works.
        """
        try:
            while True:
                with self._lock:
                    while True:
                        # shutdown overrides pause so a drain always finishes
                        held = self._paused and not self._shutdown
                        can_launch = (bool(self._queue) and not held
                                      and len(self._inflight) < self.prefetch)
                        can_retire = bool(self._inflight) and (
                            len(self._inflight) >= self.prefetch
                            or not self._queue or held)
                        if can_launch or can_retire:
                            break
                        if self._shutdown and not self._queue \
                                and not self._inflight:
                            return
                        self._idle.notify_all()
                        self._work.wait()
                    if can_launch:
                        job = self._take_group()
                    else:
                        job = None
                        entry = self._inflight.popleft()
                    self._busy += 1
                if job is not None:
                    dev, parts, nbytes = self._launch(job)
                    with self._lock:
                        self._inflight.append((dev, parts))
                        self.stats["launches"] += 1
                        self.stats["batches"] += len(parts)
                        self.stats["bytes_h2d"] += nbytes
                        self.stats["max_inflight"] = max(
                            self.stats["max_inflight"], len(self._inflight))
                        self._busy -= 1
                else:
                    dev, parts = entry
                    arr = np.asarray(dev)       # blocks on device, unlocked
                    with self._lock:
                        if self._retire(arr, parts):
                            self._cv.notify_all()
                        self._busy -= 1
                        if not self._queue and not self._inflight:
                            self._idle.notify_all()
        except BaseException as e:            # pragma: no cover - defensive
            with self._lock:
                self._pump_error = e
                self._notify_everyone()

    def _take_group(self) -> list[_Chunk]:
        """Pop up to ``coalesce`` queued chunks sharing the head chunk's
        bucket shape (FIFO otherwise preserved) — one launch group. Stops
        scanning once the group is full and splices the tail back in bulk,
        so a long queued burst costs O(Q) per tick, not O(Q) per chunk."""
        bucket = self._queue[0].bucket
        group: list[_Chunk] = []
        rest: deque[_Chunk] = deque()
        while self._queue and len(group) < self.coalesce:
            ch = self._queue.popleft()
            (group if ch.bucket == bucket else rest).append(ch)
        rest.extend(self._queue)
        self._queue = rest
        return group

    def _launch(self, group: list[_Chunk]):
        """Dispatch ONE device launch for a coalesced group (pump thread).

        Packed plans: a flat (coalesce * bucket,) int32 index vector —
        padded to the full coalesce width so every launch shares one
        compiled shape — into the indexed gather; host->device traffic is
        the indices alone. int32 plans: the classic stacked code slice for
        a single chunk. Either way the launch buffer is a flat (rows, F)
        array and each part records its chunk's row offset into it.
        """
        bucket = group[0].bucket
        if self.packed:
            mat = np.empty((self.coalesce, bucket), np.int32)
            for i, ch in enumerate(group):
                mat[i] = pad_rows_edge(ch.rows, bucket)
            mat[len(group):] = mat[len(group) - 1]   # surplus lanes unread
            dev = self._executor._rows_future(mat.reshape(-1))
            parts = [(ch.ticket, ch.n, ch.j, i * bucket)
                     for i, ch in enumerate(group)]
            return dev, parts, mat.nbytes
        ch = group[0]
        codes = self._slice_padded(ch.rows, bucket)
        # np codes go straight into the jit'd gather — its argument
        # transfer is the one host->device code shipment
        dev = self._executor.gather_device(codes)
        return dev, [(ch.ticket, ch.n, ch.j, 0)], int(codes.nbytes)

    def _retire(self, arr: np.ndarray, parts: list) -> bool:
        """Distribute one retired launch buffer to its tickets (lock held);
        True if any ticket completed (its waiters need a wake)."""
        landed = False
        for ticket, n, j, off in parts:
            total = self._chunks_total.get(ticket)
            if total is None:
                continue                    # dropped by shutdown(drain=False)
            piece = arr[off:off + n]
            if piece.size * 2 < arr.size:
                # a small chunk of a big coalesced launch buffer: copy so
                # the result doesn't pin the whole (coalesce*bucket, F)
                # array for its lifetime (views keep the base alive)
                piece = piece.copy()
            chunks = self._partial.setdefault(ticket, {})
            chunks[j] = piece
            if len(chunks) < total:
                continue
            del self._partial[ticket]
            del self._chunks_total[ticket]
            ordered = [chunks[i] for i in range(len(chunks))]
            self._results[ticket] = (ordered[0] if len(ordered) == 1
                                     else np.concatenate(ordered, axis=0))
            landed = True
            t0 = self._submitted_at.pop(ticket, None)
            if t0 is not None:
                self.stats["latency_s_total"] += time.perf_counter() - t0
                self.stats["completed"] += 1
        return landed

    # -- result retrieval ----------------------------------------------------------
    def poll(self, ticket: int) -> bool:
        """True once the ticket's result is on host. Non-blocking and
        dispatch-free: the pump owns all launching/retiring. Raises KeyError
        for unknown/already-collected tickets (like ``result``) so a poll
        loop can't spin forever on a bad ticket."""
        with self._lock:
            self._check_pump()
            if ticket in self._results:
                return True
            if ticket not in self._chunks_total:
                raise KeyError(f"unknown or already-collected ticket {ticket}")
            return False

    def _queued_while_paused(self, ticket: int | None) -> bool:
        """True when blocking on this work would deadlock: the pump is
        paused (and not shutting down, which overrides pause) and the
        awaited chunks are still queued — nothing will ever launch them
        until ``resume()``. Lock held."""
        if not self._paused or self._shutdown:
            return False
        if ticket is None:
            return bool(self._queue)
        return any(ch.ticket == ticket for ch in self._queue)

    def result(self, ticket: int) -> np.ndarray:
        """Block until the ticket's features are on host and return them.

        Purely a wait: the pump launches and retires; this just sleeps on
        the service condition until the ticket lands (or is unknown).
        Raises RuntimeError instead of deadlocking if the service is
        paused with this ticket's chunks still unlaunched.
        """
        with self._lock:
            # claim the ticket so a concurrent drain() can't sweep it away
            # between the pump landing it and this thread waking up
            self._claimed.add(ticket)
            try:
                while True:
                    self._check_pump()
                    if ticket in self._results:
                        return self._results.pop(ticket)
                    if ticket not in self._chunks_total:
                        raise KeyError(
                            f"unknown or already-collected ticket {ticket}")
                    if self._queued_while_paused(ticket):
                        raise RuntimeError(
                            f"ticket {ticket} is queued but the service is "
                            "paused — resume() before blocking on results")
                    self._cv.wait(timeout=0.5)
            finally:
                self._claimed.discard(ticket)

    def drain(self) -> dict[int, np.ndarray]:
        """Wait for the pump to finish everything queued/in flight; return
        {ticket: features} collected — except tickets another thread is
        blocked on in result(), which stay theirs. Raises RuntimeError
        instead of deadlocking if called while paused with chunks queued."""
        with self._lock:
            while self._queue or self._inflight or self._busy:
                self._check_pump()
                if self._queued_while_paused(None):
                    raise RuntimeError("queue is held by pause() — "
                                       "resume() before drain()")
                self._idle.wait(timeout=0.5)
            self._check_pump()
            out = {t: r for t, r in self._results.items()
                   if t not in self._claimed}
            for t in out:
                del self._results[t]
            return out

    # -- streaming convenience -------------------------------------------------------
    def serve_stream(self, row_batches):
        """Featurize an iterator of row-index batches through the pump.

        Yields (rows, features) in submission order while keeping up to
        ``prefetch`` launches in flight on the pump side.
        """
        def gen():
            # the pump runs the prefetch-deep window; this FIFO only stops
            # the producer racing ahead of the consumer
            pending: deque[tuple[np.ndarray, int]] = deque()
            for rows in row_batches:
                rows = np.asarray(rows)
                pending.append((rows, self.submit(rows)))
                if len(pending) > self.prefetch:
                    r, t = pending.popleft()
                    yield r, self.result(t)
            while pending:
                r, t = pending.popleft()
                yield r, self.result(t)
        return gen()

    # -- reporting --------------------------------------------------------------
    def throughput_stats(self, wall_s: float) -> dict:
        rows = self.stats["rows"]
        done = self.stats["completed"]
        return {**self.stats, "wall_s": wall_s,
                "rows_per_s": rows / wall_s if wall_s > 0 else float("inf"),
                "mean_latency_s": (self.stats["latency_s_total"] / done
                                   if done else 0.0),
                "pad_overhead": (self.stats["padded_rows"] /
                                 max(rows + self.stats["padded_rows"], 1))}
