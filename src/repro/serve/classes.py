"""Request classes and latency accounting for the serving front door.

The multi-tenant front door (:mod:`repro.serve.frontend`) speaks in
**request classes**: named service levels (``interactive`` / ``batch`` /
``background`` are the presets) that bundle everything the serving stack
needs to treat one tenant's work differently from another's —

- a **priority** plus an **anti-starvation aging rate** that the pump's
  group selection scores queued work by (a ``background`` chunk outranks
  an ``interactive`` one once it has waited long enough, so low-priority
  work always drains),
- a per-class **coalescing policy** (``coalesce`` depth and ``linger_us``
  hold time — ``interactive`` launches immediately in singleton groups,
  ``batch`` lingers for fuller launches),
- a default **deadline_ms** applied to submits that do not pass their
  own, and
- the front door's **admission window** (``max_inflight`` outstanding
  requests admitted freely, ``queue_depth`` more admitted as queued
  work, anything past that rejected with a typed :class:`Overloaded`).

:class:`LatencyHistogram` is the streaming log-bucketed latency record
behind the per-class SLO gates — unlike the bench-compat
``FeatureService.latencies`` deque (a sliding 8192-sample window whose
``np.percentile`` silently reports the p99 of only the most RECENT
tickets on long runs), the histogram sees every completed ticket at a
fixed ~10% relative resolution, so its percentiles are unbiased however
long the service has been up.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class RequestClass:
    """One named service level (see module docstring).

    ``coalesce``/``linger_us`` of ``None`` inherit the service-wide
    settings; a class's ``coalesce`` is additionally capped at the
    service's (launch buffers are sized for the service-wide depth).
    ``aging_s`` is the anti-starvation rate: a queued chunk's effective
    priority is ``priority + waited_seconds / aging_s``, so every
    ``aging_s`` seconds of queue time is worth one priority level.
    """
    name: str
    priority: int = 1
    deadline_ms: float | None = None
    max_inflight: int = 64          # front-door window: admitted freely
    queue_depth: int = 256          # then this many more admitted queued
    coalesce: int | None = None     # None: service-wide depth
    linger_us: float | None = None  # None: service-wide linger
    aging_s: float = 0.25

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("request class needs a name")
        if self.priority < 0:
            raise ValueError("priority must be >= 0")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0")
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if self.queue_depth < 0:
            raise ValueError("queue_depth must be >= 0")
        if self.coalesce is not None and self.coalesce < 1:
            raise ValueError("coalesce must be >= 1")
        if self.linger_us is not None and self.linger_us < 0:
            raise ValueError("linger_us must be >= 0")
        if self.aging_s <= 0:
            raise ValueError("aging_s must be > 0")


def default_classes() -> tuple[RequestClass, ...]:
    """The preset three-tier ladder: ``interactive`` launches immediately
    (singleton groups, highest priority, tight deadline), ``batch``
    coalesces normally, ``background`` is the aged-up scavenger class
    (small admission window, no deadline — it may wait, never starve)."""
    return (
        RequestClass("interactive", priority=3, deadline_ms=5_000.0,
                     max_inflight=64, queue_depth=128, coalesce=1,
                     linger_us=0.0, aging_s=0.25),
        RequestClass("batch", priority=2, deadline_ms=30_000.0,
                     max_inflight=32, queue_depth=256, aging_s=0.5),
        RequestClass("background", priority=1, deadline_ms=None,
                     max_inflight=16, queue_depth=512, aging_s=0.5),
    )


class Overloaded(RuntimeError):
    """Typed admission rejection from the front door: the request class's
    outstanding work is past ``max_inflight + queue_depth``.

    Carries the saturation picture (``klass``, ``tenant``, ``outstanding``
    against ``bound``) and a ``retry_after_s`` hint — the front door's
    estimate of when a slot should free up (from the class's observed p50
    latency), so a well-behaved client backs off instead of hammering.
    Nothing was enqueued: an Overloaded submit left no ticket behind.
    """

    def __init__(self, msg: str, *, klass: str | None = None,
                 tenant: str | None = None, outstanding: int = 0,
                 bound: int = 0, retry_after_s: float = 0.0):
        super().__init__(msg)
        self.klass = klass
        self.tenant = tenant
        self.outstanding = outstanding
        self.bound = bound
        self.retry_after_s = retry_after_s


class LatencyHistogram:
    """Streaming log-bucketed latency histogram (see module docstring).

    Buckets are geometric: ``buckets_per_decade`` per factor of 10
    between ``lo_s`` and ``hi_s`` (defaults: 24 per decade over 1 us ..
    1000 s, 216 buckets, ~10% bucket width), values outside clamp to the
    edge buckets. ``record`` is O(1) and allocation-free — cheap enough
    to run under the service lock on every retire. ``percentile`` walks
    the cumulative counts and returns the geometric midpoint of the
    target bucket, clamped to the exact observed min/max so the tails
    never report a value outside what was actually seen. Not internally
    locked: the owner serializes access (the service mutates it under
    its own lock).
    """

    def __init__(self, lo_s: float = 1e-6, hi_s: float = 1e3,
                 buckets_per_decade: int = 24):
        if lo_s <= 0 or hi_s <= lo_s:
            raise ValueError("need 0 < lo_s < hi_s")
        if buckets_per_decade < 1:
            raise ValueError("buckets_per_decade must be >= 1")
        self._lo = lo_s
        self._log_lo = math.log10(lo_s)
        self._bpd = buckets_per_decade
        self._n = int(math.ceil(
            (math.log10(hi_s) - self._log_lo) * buckets_per_decade))
        self.counts = np.zeros(self._n, np.int64)
        self.count = 0
        self.total_s = 0.0
        self.min_s = math.inf
        self.max_s = 0.0

    def _index(self, s: float) -> int:
        if s <= self._lo:
            return 0
        i = int((math.log10(s) - self._log_lo) * self._bpd)
        return min(i, self._n - 1)

    def record(self, s: float) -> None:
        self.counts[self._index(s)] += 1
        self.count += 1
        self.total_s += s
        if s < self.min_s:
            self.min_s = s
        if s > self.max_s:
            self.max_s = s

    def merge(self, other: "LatencyHistogram") -> None:
        if other._n != self._n or other._lo != self._lo:
            raise ValueError("histogram layouts differ")
        self.counts += other.counts
        self.count += other.count
        self.total_s += other.total_s
        self.min_s = min(self.min_s, other.min_s)
        self.max_s = max(self.max_s, other.max_s)

    def percentile(self, q: float) -> float:
        """The q-th percentile in SECONDS over every recorded sample
        (0.0 when empty). Resolution is one bucket (~10% relative at the
        default layout); exact at the extremes (observed min/max)."""
        if not 0 <= q <= 100:
            raise ValueError("q must be in [0, 100]")
        if self.count == 0:
            return 0.0
        if q == 0:
            return self.min_s
        if q >= 100:
            return self.max_s
        target = max(1, math.ceil(q / 100.0 * self.count))
        cum = 0
        for i in range(self._n):
            c = int(self.counts[i])
            if c == 0:
                continue
            cum += c
            if cum >= target:
                mid = 10.0 ** (self._log_lo + (i + 0.5) / self._bpd)
                return min(max(mid, self.min_s), self.max_s)
        return self.max_s

    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0

    def summary(self) -> dict:
        """JSON-safe snapshot for stats endpoints (milliseconds)."""
        empty = self.count == 0
        return {"samples": self.count,
                "mean_ms": self.mean_s * 1e3,
                "p50_ms": self.percentile(50) * 1e3,
                "p99_ms": self.percentile(99) * 1e3,
                "min_ms": 0.0 if empty else self.min_s * 1e3,
                "max_ms": self.max_s * 1e3}
