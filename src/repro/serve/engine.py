"""Batched serving engine: continuous prefill + decode over a fixed-shape
request batch.

Static shapes throughout (TPU-friendly): the engine owns a (B, max_len)
slot array; requests are right-padded into slots, prefilled together, and
decoded step-by-step with per-slot stop tracking. Sampling is greedy or
temperature-based. The KV/recurrent cache pytree comes from
models.lm.init_serve_state and is reused across batches (no realloc).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig


@dataclass
class Request:
    prompt: np.ndarray                 # (len,) int32 token ids
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stop early
    out_tokens: list[int] = field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int,
                 max_len: int, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.b = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, s, batch: lm.prefill(cfg, p, s, batch))
        self._decode = jax.jit(
            lambda p, s, t: lm.decode_step(cfg, p, s, t))

    def _sample(self, logits):
        logits = logits[..., :self.cfg.vocab]
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    def run_batch(self, requests: list[Request]) -> list[Request]:
        """Serve up to ``batch_size`` requests of equal prompt length."""
        if len(requests) > self.b:
            raise ValueError("batch too large")
        plen = len(requests[0].prompt)
        if any(len(r.prompt) != plen for r in requests):
            raise ValueError("engine batches equal-length prompts "
                             "(bucket upstream)")
        prompts = np.zeros((self.b, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i] = r.prompt
        state = lm.init_serve_state(self.cfg, self.b, max_len=self.max_len)
        logits, state = self._prefill(self.params, state,
                                      {"tokens": jnp.asarray(prompts)})
        tok = self._sample(logits[:, -1:])
        max_new = max(r.max_new_tokens for r in requests)
        done = np.zeros(self.b, bool)
        for step in range(max_new):
            tok_np = np.asarray(tok[:, 0])
            for i, r in enumerate(requests):
                if not done[i] and step < r.max_new_tokens:
                    t = int(tok_np[i])
                    r.out_tokens.append(t)
                    if t == r.eos_id:
                        done[i] = True
            if done[:len(requests)].all():
                break
            if int(state["pos"]) >= self.max_len:
                break
            logits, state = self._decode(self.params, state, tok)
            tok = self._sample(logits)
        return requests

    def throughput_stats(self, requests: list[Request],
                         wall_s: float) -> dict:
        new = sum(len(r.out_tokens) for r in requests)
        # wall_s <= 0 cannot yield a rate: 0.0 + flag, not float('inf')
        # (json.dump renders inf as the non-standard Infinity token)
        wall_ok = wall_s > 0
        return {"requests": len(requests), "new_tokens": new,
                "wall_s_invalid": not wall_ok,
                "tok_per_s": new / wall_s if wall_ok else 0.0}
