from repro.serve.engine import ServeEngine, Request
from repro.serve.faults import (DeadlineExceeded, DeviceDown, DeviceHealth,
                                FaultInjector, FaultPolicy, InjectedFault,
                                ServeError, StreamBreaker)
from repro.serve.feature_service import FeatureService

__all__ = ["ServeEngine", "Request", "FeatureService", "FaultInjector",
           "FaultPolicy", "ServeError", "DeadlineExceeded", "InjectedFault",
           "StreamBreaker", "DeviceDown", "DeviceHealth"]
