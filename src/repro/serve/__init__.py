from repro.serve.classes import (LatencyHistogram, Overloaded, RequestClass,
                                 default_classes)
from repro.serve.engine import ServeEngine, Request
from repro.serve.faults import (DeadlineExceeded, DeviceDown, DeviceHealth,
                                FaultInjector, FaultPolicy, InjectedFault,
                                ServeError, StreamBreaker)
from repro.serve.feature_service import FeatureService
from repro.serve.frontend import FeatureFrontend

__all__ = ["ServeEngine", "Request", "FeatureService", "FeatureFrontend",
           "RequestClass", "Overloaded", "LatencyHistogram",
           "default_classes", "FaultInjector",
           "FaultPolicy", "ServeError", "DeadlineExceeded", "InjectedFault",
           "StreamBreaker", "DeviceDown", "DeviceHealth"]
