from repro.serve.engine import ServeEngine, Request
from repro.serve.feature_service import FeatureService, FeatureRequest

__all__ = ["ServeEngine", "Request", "FeatureService", "FeatureRequest"]
