from repro.serve.engine import ServeEngine, Request
from repro.serve.feature_service import FeatureService

__all__ = ["ServeEngine", "Request", "FeatureService"]
