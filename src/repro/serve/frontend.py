"""Multi-tenant front door for :class:`~repro.serve.FeatureService`.

The production request boundary the ROADMAP's front-door item asks for:
many concurrent analysis consumers reach ONE pump-driven service through
per-tenant **request classes** with admission control, backpressure, and
per-class tail-latency accounting — instead of every caller holding the
raw executor (the in-database-AI framing: the data system mediates the
workload, NeurDB-style, rather than handing out engines).

Division of labor with the service:

- the **service** owns the pump: per-class priority scheduling with
  anti-starvation aging, per-class coalescing/linger, per-class latency
  histograms, typed per-ticket errors (all added alongside this module —
  construct the service with ``classes=`` and the frontend reads them);
- the **frontend** owns the boundary: per-class admission windows
  (``max_inflight`` outstanding admitted freely, ``queue_depth`` more
  admitted as queued work, then typed :class:`Overloaded` rejection with
  a retry-after hint — queue growth is BOUNDED by construction), per-
  tenant attribution, an asyncio-friendly ``featurize`` coroutine, and a
  dict-based request/response handler (:meth:`handle`) as the network-
  style edge. Phase 2 (see ROADMAP) puts a real socket transport and
  cross-process tenants in front of ``handle``; in-process it already
  defines the wire contract.

Outstanding work is counted submit -> resolution-retrieval: a ticket
occupies its class's window until the caller (or ``collect``) retrieves
its result or typed error. That makes the window END-TO-END flow
control — a consumer that submits but never collects saturates its own
class and gets Overloaded, instead of growing an unbounded uncollected-
results heap inside the service.
"""
from __future__ import annotations

import asyncio
import threading

import numpy as np

from repro.serve.classes import Overloaded, RequestClass, default_classes
from repro.serve.faults import ServeError
from repro.serve.feature_service import FeatureService


class FeatureFrontend:
    """The front door over one :class:`FeatureService` (see module doc).

    The service must carry the request classes (``FeatureService(...,
    classes=...)``); :meth:`for_plan` builds both in one call. Thread-
    safe: admission state lives under its own lock (never held across
    service calls), tickets remain plain service tickets — mixing
    frontend and direct service access works, but only frontend-submitted
    tickets are admission-tracked.
    """

    def __init__(self, service: FeatureService, *,
                 default_klass: str | None = None):
        classes = {n: rc for n, rc in service.classes.items()
                   if n != "default"}
        if not classes:
            raise ValueError(
                "service has no request classes — construct it with "
                "classes= (e.g. default_classes()) before fronting it")
        self.service = service
        self._classes = classes
        if default_klass is None:
            default_klass = max(classes,
                                key=lambda n: classes[n].priority)
        if default_klass not in classes:
            raise ValueError(f"unknown default class {default_klass!r}")
        self.default_klass = default_klass
        self._lock = threading.Lock()
        self._outstanding = {n: 0 for n in classes}
        self._tickets: dict[int, tuple[str, str]] = {}  # -> (klass, tenant)
        self._admission = {n: {"admitted": 0, "admitted_queued": 0,
                               "rejected": 0}
                           for n in classes}
        self._tenants: dict[str, dict] = {}

    @classmethod
    def for_plan(cls, plan, *,
                 classes: tuple[RequestClass, ...] | None = None,
                 default_klass: str | None = None,
                 **service_kw) -> "FeatureFrontend":
        """Build service + frontend in one call (the
        :func:`default_classes` presets when ``classes`` is omitted);
        ``service_kw`` passes through to :class:`FeatureService`."""
        svc = FeatureService(plan, classes=classes or default_classes(),
                             **service_kw)
        return cls(svc, default_klass=default_klass)

    # -- lifecycle -------------------------------------------------------------------
    def __enter__(self) -> "FeatureFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def shutdown(self, drain: bool = True) -> None:
        self.service.shutdown(drain=drain)

    # -- admission -------------------------------------------------------------------
    def _retry_after(self, rc: RequestClass, outstanding: int) -> float:
        """Backoff hint for an Overloaded rejection: the class's observed
        p50 latency (floored at 1 ms pre-warmup) scaled by how many
        window-widths deep the backlog is — a rough when-will-a-slot-free
        estimate, not a promise."""
        p50 = self.service.latency_percentile(50, rc.name)
        depth = max(1.0, (outstanding - rc.max_inflight + 1)
                    / max(rc.max_inflight, 1))
        return max(p50, 1e-3) * depth

    def submit(self, rows: np.ndarray | None = None, *,
               klass: str | None = None, tenant: str = "anon",
               where=None, deadline_ms: float | None = None) -> int:
        """Admission-controlled :meth:`FeatureService.submit`.

        Admits while the class's outstanding count (submitted minus
        retrieved) is under ``max_inflight + queue_depth`` — past
        ``max_inflight`` the admit is counted as QUEUED, so backpressure
        is visible before rejection starts — and raises
        :class:`Overloaded` with a ``retry_after_s`` hint at the bound.
        Never drops a ticket: a rejected submit enqueued nothing, an
        admitted one returns a normal service ticket (collect it via
        this frontend so the window frees).
        """
        if klass is None:
            klass = self.default_klass
        rc = self._classes.get(klass)
        if rc is None:
            raise ValueError(f"unknown request class {klass!r} "
                             f"(registered: {sorted(self._classes)})")
        bound = rc.max_inflight + rc.queue_depth
        with self._lock:
            out = self._outstanding[klass]
            ten = self._tenants.setdefault(
                tenant, {"requests": 0, "admitted": 0, "rejected": 0})
            ten["requests"] += 1
            if out >= bound:
                self._admission[klass]["rejected"] += 1
                ten["rejected"] += 1
                reject = Overloaded(
                    f"class {klass!r} saturated: {out} outstanding >= "
                    f"window {rc.max_inflight} + queue depth "
                    f"{rc.queue_depth}", klass=klass, tenant=tenant,
                    outstanding=out, bound=bound,
                    retry_after_s=0.0)
            else:
                reject = None
                # reserve the slot before releasing the lock: concurrent
                # submits each see their own reservation, so the bound
                # holds even mid-service-call
                self._outstanding[klass] = out + 1
        if reject is not None:
            # the hint reads service stats — computed outside our lock
            reject.retry_after_s = self._retry_after(rc, out)
            raise reject
        try:
            ticket = self.service.submit(rows, where=where, klass=klass,
                                         deadline_ms=deadline_ms)
        except BaseException:
            with self._lock:
                self._outstanding[klass] -= 1
            raise
        with self._lock:
            self._tickets[ticket] = (klass, tenant)
            adm = self._admission[klass]
            adm["admitted"] += 1
            if out >= rc.max_inflight:
                adm["admitted_queued"] += 1
            ten["admitted"] += 1
        return ticket

    def _release(self, ticket: int) -> None:
        """A frontend-submitted ticket RESOLVED and its outcome was
        retrieved: free its admission slot (idempotent)."""
        with self._lock:
            entry = self._tickets.pop(ticket, None)
            if entry is not None:
                self._outstanding[entry[0]] -= 1

    # -- retrieval -------------------------------------------------------------------
    def poll(self, ticket: int) -> bool:
        return self.service.poll(ticket)

    def result(self, ticket: int,
               timeout: float | None = None) -> np.ndarray:
        """:meth:`FeatureService.result` + admission release: the slot
        frees when the ticket's outcome (features or typed error) is
        retrieved. A plain wait ``timeout`` expiring does NOT free the
        slot — the ticket is still outstanding."""
        try:
            out = self.service.result(ticket, timeout=timeout)
        except (ServeError, KeyError):
            # resolved-to-error (DeadlineExceeded included) or unknown/
            # already-collected: either way it no longer occupies a slot
            self._release(ticket)
            raise
        self._release(ticket)
        return out

    def collect(self, timeout: float | None = None) -> dict:
        """Drain + retrieve everything resolved (features or typed
        errors, like :meth:`FeatureService.collect`), freeing the
        admission slots of every frontend ticket retrieved."""
        out = self.service.collect(timeout)
        for t in out:
            self._release(t)
        return out

    async def featurize(self, rows: np.ndarray | None = None, *,
                        klass: str | None = None, tenant: str = "anon",
                        where=None, deadline_ms: float | None = None,
                        poll_s: float = 0.002) -> np.ndarray:
        """Async request/response: admission-controlled submit, then an
        await-friendly poll until the ticket resolves (the event loop
        stays free — no thread is parked in ``result``). Raises
        :class:`Overloaded` immediately when the class is saturated;
        typed :class:`ServeError` when the ticket fails."""
        ticket = self.submit(rows, klass=klass, tenant=tenant,
                             where=where, deadline_ms=deadline_ms)
        while not self.service.poll(ticket):
            await asyncio.sleep(poll_s)
        return self.result(ticket, timeout=1.0)

    # -- the network-style edge ------------------------------------------------------
    def handle(self, req: dict) -> dict:
        """One request/response exchange over plain dicts — the wire
        contract a phase-2 socket transport serializes. Ops:

        - ``{"op": "featurize", "rows": [...], "klass": ..., "tenant":
          ..., "deadline_ms": ...}`` -> ``{"ok": True, "ticket": t}``, or
          ``{"ok": False, "error": "overloaded", "retry_after_ms": ...}``
        - ``{"op": "result", "ticket": t, "timeout": s}`` -> ``{"ok":
          True, "features": ndarray}`` | ``{"ok": False, "error":
          "serve_error" | "timeout" | "unknown_ticket", "detail": ...}``
        - ``{"op": "stats"}`` -> ``{"ok": True, "stats": ...}``

        Responses are JSON-safe except the ``features`` payload (an
        ndarray — the transport picks its own array encoding).
        """
        op = req.get("op", "featurize")
        try:
            if op == "featurize":
                rows = req.get("rows")
                ticket = self.submit(
                    None if rows is None else np.asarray(rows),
                    klass=req.get("klass"),
                    tenant=req.get("tenant", "anon"),
                    where=req.get("where"),
                    deadline_ms=req.get("deadline_ms"))
                return {"ok": True, "ticket": ticket}
            if op == "result":
                feats = self.result(req["ticket"],
                                    timeout=req.get("timeout"))
                return {"ok": True, "features": feats}
            if op == "stats":
                return {"ok": True, "stats": self.stats()}
            return {"ok": False, "error": "bad_request",
                    "detail": f"unknown op {op!r}"}
        except Overloaded as e:
            return {"ok": False, "error": "overloaded",
                    "klass": e.klass, "tenant": e.tenant,
                    "retry_after_ms": e.retry_after_s * 1e3}
        except ServeError as e:
            return {"ok": False, "error": "serve_error", "detail": str(e)}
        except TimeoutError as e:
            return {"ok": False, "error": "timeout", "detail": str(e)}
        except KeyError as e:
            return {"ok": False, "error": "unknown_ticket",
                    "detail": str(e)}
        except (ValueError, IndexError, RuntimeError) as e:
            return {"ok": False, "error": "bad_request", "detail": str(e)}

    # -- reporting -------------------------------------------------------------------
    def stats(self) -> dict:
        """JSON-safe front-door picture: per class — admission counts,
        current outstanding, and the service's per-class serving stats
        (p50/p99 over ALL completed tickets); per tenant — request/
        admit/reject counts; plus ``availability_admitted``, completed
        over resolved across every class (the >= 1.0 bit-exact SLO gate
        for admitted work — rejected submits never enter it)."""
        svc_classes = self.service.class_stats()
        with self._lock:
            classes = {}
            done = failed = 0
            for name in self._classes:
                svc = svc_classes.get(name, {})
                done += svc.get("completed", 0)
                failed += svc.get("failed", 0)
                classes[name] = {**self._admission[name],
                                 "outstanding": self._outstanding[name],
                                 **svc}
            resolved = done + failed
            return {"classes": classes,
                    "tenants": {t: dict(v)
                                for t, v in self._tenants.items()},
                    "availability_admitted":
                        done / resolved if resolved else 1.0}
