from repro.configs.registry import get_config, reduced, ARCH_IDS
from repro.configs.shapes import SHAPES, ShapeSpec, input_specs, applicable

__all__ = ["get_config", "reduced", "ARCH_IDS", "SHAPES", "ShapeSpec",
           "input_specs", "applicable"]
