"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1 per group), matrix-memory
recurrence, sub-quadratic (long_500k runs). [arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv=4,
    d_ff=0,                  # no separate MLP; mLSTM up-projection instead
    vocab=50304,
    ssm_expand=2,            # d_inner = 4096
    qk_dim_ratio=0.5,        # dk = d_inner/2 per official mLSTM
    conv_width=4,
    slstm_group=8,           # pattern: 7 mLSTM + 1 sLSTM
    pure_dp=True,            # 1.3B: TP-16 drowns in activation collectives;
                             # DP-256 + ZeRO-3 is 12x better (EXPERIMENTS §Perf)
)
