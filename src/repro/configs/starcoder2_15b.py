"""starcoder2-15b — dense code model, GQA kv=4, RoPE.
[arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=4,
    d_ff=24576,
    vocab=49152,
    mlp_style="gelu",     # StarCoder2 uses a standard (non-gated) GELU MLP
    rope_theta=1e5,
    grad_accum=2,         # 24k-wide GELU MLP: halve activation liveness
)
