"""llava-next-mistral-7b — Mistral-7B backbone; vision frontend is a STUB
(input_specs supplies precomputed patch embeddings; anyres tiling happens
upstream). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32000,
    rope_theta=1e6,
    frontend="vision",
    frontend_dim=1024,       # CLIP-L hidden size (stub embeddings)
    n_patches=576,
)
