"""seamless-m4t-large-v2 — enc-dec; audio frontend is a STUB (input_specs
supplies precomputed frame embeddings). The 256k vocab makes this the
strongest ADV/dictionary-sharding case (DESIGN.md §5).
[arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,             # text decoder
    enc_layers=24,           # speech encoder (conformer frontend stubbed)
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=256206,
    frontend="audio",
    frontend_dim=160,        # fbank features (stub)
    rope_theta=1e4,
)
