"""hymba-1.5b — parallel attention + SSD(Mamba-2) heads per block; SWA
except first/middle/last layers; sub-quadratic (long_500k runs).
[arXiv:2411.13676; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv=5,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_expand=2,            # d_inner = 3200
    conv_width=4,
    sliding_window=1024,
    n_full_attn=3,           # first / middle / last stay full attention
    pure_dp=True,            # same finding as xlstm (EXPERIMENTS §Perf)
    notes="meta tokens omitted (backbone per brief)",
)
