"""moonshot-v1-16b-a3b — Moonlight-style MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,
    vocab=163840,
    n_experts=64,
    top_k=6,
    moe_every=1,
    shared_expert=True,           # Moonlight keeps shared expert(s)
    capacity_factor=1.25,
    rope_theta=5e4,
    force_fsdp=True,         # fits decode/prefill on 16GB (EXPERIMENTS §Perf)
    grad_accum=2,
    notes="all-MoE stack per brief; shared expert as in Moonlight/DeepSeek-V3 lineage",
)
