"""llama4-maverick-400b-a17b — interleaved MoE, 128 experts top-1 + shared.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

moe_every=2 (alternating dense/MoE) reproduces the published ~400B total /
~17B active split with the brief's 48L/5120d/8192ff/128e numbers.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    moe_every=2,
    shared_expert=True,
    capacity_factor=1.25,
    rope_theta=5e5,
    grad_accum=4,            # activation liveness (EXPERIMENTS §Perf)
    notes="early-fusion multimodality is a frontend stub per brief; "
          "text backbone only",
)
