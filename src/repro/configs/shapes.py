"""Assigned input shapes × per-arch input specs (ShapeDtypeStructs only).

train_*  lowers train_step; prefill_* lowers the prefill pass; decode_* and
long_*  lower serve_step (one token against a seq_len-deep cache/state).
long_500k is sub-quadratic-only: skipped for pure full-attention archs
(DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "skipped(full-attention)"
    return True, ""


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    For train/prefill this is the token batch (+ stub frontend embeddings);
    for decode it's the single-token batch (the serve state is built
    separately via eval_shape of init_serve_state).
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _i32((b, s)), "labels": _i32((b, s))}
        if cfg.family == "vlm":
            batch["patch_embeds"] = _f32((b, cfg.n_patches, cfg.frontend_dim))
        if cfg.family == "audio":
            batch["frames"] = _f32((b, s, cfg.frontend_dim))
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _i32((b, s))}
        if cfg.family == "vlm":
            batch["patch_embeds"] = _f32((b, cfg.n_patches, cfg.frontend_dim))
        if cfg.family == "audio":
            batch["frames"] = _f32((b, s, cfg.frontend_dim))
        return batch
    if shape.kind == "decode":
        return {"tokens": _i32((b, 1))}
    raise ValueError(shape.kind)
