"""minicpm-2b — dense llama-like, MHA (kv=36), tied embeddings, WSD
schedule (train.schedule.wsd). [arXiv:2404.06395; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv=36,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
    rope_theta=1e4,
    notes="WSD LR schedule is the arch's training signature; see "
          "repro.train.schedule.wsd",
)
