"""Architecture registry: --arch <id> resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig
from repro.models.blocks import block_pattern

ARCH_IDS = [
    "moonshot-v1-16b-a3b",
    "llama4-maverick-400b-a17b",
    "glm4-9b",
    "qwen2-7b",
    "minicpm-2b",
    "starcoder2-15b",
    "xlstm-1.3b",
    "hymba-1.5b",
    "llava-next-mistral-7b",
    "seamless-m4t-large-v2",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests: 2 pattern groups,
    tiny widths, odd vocab (exercises padding), generous MoE capacity
    (so prefill/decode equivalence holds with no token drops)."""
    pat_len = len(block_pattern(cfg))
    heads = 4
    kv = heads if cfg.n_kv == cfg.n_heads else 2
    return dataclasses.replace(
        cfg,
        n_layers=2 * pat_len,
        d_model=64,
        n_heads=heads,
        n_kv=kv,
        d_head=16,
        d_ff=96 if cfg.d_ff else 0,
        vocab=519,
        vocab_pad_multiple=64,
        n_experts=8 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 3) if cfg.top_k else 0,
        capacity_factor=8.0 if cfg.n_experts else cfg.capacity_factor,
        ssm_state=8 if cfg.ssm_state else 0,
        sliding_window=4 if cfg.sliding_window else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        frontend_dim=24 if cfg.frontend_dim else 0,
        n_patches=4 if cfg.n_patches else 0,
        dtype="float32",
        remat="none",
    )
