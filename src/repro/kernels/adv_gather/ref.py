"""Pure-jnp oracle for adv_gather."""
import jax.numpy as jnp


def adv_gather_ref(codes: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """out[i, :] = table[codes[i], :]"""
    return jnp.take(table, codes, axis=0)
