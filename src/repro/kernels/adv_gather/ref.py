"""Pure-jnp oracles for adv_gather."""
import jax.numpy as jnp

from repro.kernels.bitunpack.ref import bitunpack_divisor_ref


def adv_gather_ref(codes: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """out[i, :] = table[codes[i], :] (OOB codes clamp to the table edge)."""
    return jnp.take(table, codes, axis=0, mode="clip")


def adv_gather_multi_ref(codes: jnp.ndarray, tables) -> jnp.ndarray:
    """Per-table take + concatenate: out[i] = concat_c tables[c][codes[c, i]].

    ``codes`` is (C, N) int32 with codes[c] indexing tables[c]. This is the
    unfused XLA rendering of the multi-table gather-concat the fused Pallas
    kernel performs in one pass. OOB codes clamp (matching the fused path)
    rather than NaN-fill.
    """
    return jnp.concatenate(
        [jnp.take(t, codes[c], axis=0, mode="clip")
         for c, t in enumerate(tables)],
        axis=-1)


def adv_gather_packed_ref(windows, dbs, tables, n: int) -> jnp.ndarray:
    """Split/unfused XLA rendering of the packed fast path.

    ``windows[c]`` is column c's device-width (dbs[c] | 32) packed words for
    the batch; each column is unpacked with the gather-free divisor recipe
    and gathered from its own table — the reference the fused one-pass
    Pallas kernel must match exactly, and the fallback ops.py uses when the
    block-diagonal super-table would blow the VMEM budget.
    """
    return jnp.concatenate(
        [jnp.take(t, bitunpack_divisor_ref(w, db, n), axis=0, mode="clip")
         for w, db, t in zip(windows, dbs, tables)],
        axis=-1)
