"""Pure-jnp oracles for adv_gather."""
import jax.numpy as jnp

from repro.kernels.bitunpack.ref import bitunpack_divisor_ref


def adv_gather_ref(codes: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """out[i, :] = table[codes[i], :] (OOB codes clamp to the table edge)."""
    return jnp.take(table, codes, axis=0, mode="clip")


def adv_gather_multi_ref(codes: jnp.ndarray, tables) -> jnp.ndarray:
    """Per-table take + concatenate: out[i] = concat_c tables[c][codes[c, i]].

    ``codes`` is (C, N) int32 with codes[c] indexing tables[c]. This is the
    unfused XLA rendering of the multi-table gather-concat the fused Pallas
    kernel performs in one pass. OOB codes clamp (matching the fused path)
    rather than NaN-fill.
    """
    return jnp.concatenate(
        [jnp.take(t, codes[c], axis=0, mode="clip")
         for c, t in enumerate(tables)],
        axis=-1)


def adv_gather_packed_rows_ref(words, dbs, tables,
                               rows: jnp.ndarray) -> jnp.ndarray:
    """Split/unfused XLA rendering of the random-row packed gather.

    ``words[c]`` is column c's FULL device-width (dbs[c] | 32) resident word
    stream; ``rows`` are arbitrary table row indices. Per column: gather the
    owning word (``row // s``), shift/mask out the field (divisor widths
    never straddle words), then gather from the column's table — the
    device-side mirror of ``bitpack.packed_gather`` and the oracle (and
    VMEM-budget fallback) for the fused ``adv_gather_packed_rows`` kernel.
    """
    rows = jnp.asarray(rows, jnp.int32)
    outs = []
    for w, db, t in zip(words, dbs, tables):
        s = 32 // db
        wv = jnp.take(jnp.asarray(w, jnp.uint32), rows // s, mode="clip")
        fields = wv >> ((rows % s).astype(jnp.uint32) * jnp.uint32(db))
        if db < 32:
            fields = fields & jnp.uint32((1 << db) - 1)
        outs.append(jnp.take(t, fields.astype(jnp.int32), axis=0,
                             mode="clip"))
    return jnp.concatenate(outs, axis=-1)


def adv_gather_packed_ref(windows, dbs, tables, n: int) -> jnp.ndarray:
    """Split/unfused XLA rendering of the packed fast path.

    ``windows[c]`` is column c's device-width (dbs[c] | 32) packed words for
    the batch; each column is unpacked with the gather-free divisor recipe
    and gathered from its own table — the reference the fused one-pass
    Pallas kernel must match exactly, and the fallback ops.py uses when the
    block-diagonal super-table would blow the VMEM budget.
    """
    return jnp.concatenate(
        [jnp.take(t, bitunpack_divisor_ref(w, db, n), axis=0, mode="clip")
         for w, db, t in zip(windows, dbs, tables)],
        axis=-1)
