from repro.kernels.adv_gather import ops, ref
from repro.kernels.adv_gather.ops import adv_gather

__all__ = ["ops", "ref", "adv_gather"]
