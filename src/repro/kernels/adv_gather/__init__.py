from repro.kernels.adv_gather import ops, ref
from repro.kernels.adv_gather.ops import (adv_gather, adv_gather_fused,
                                          fuse_tables, FusedTables)

__all__ = ["ops", "ref", "adv_gather", "adv_gather_fused", "fuse_tables",
           "FusedTables"]
