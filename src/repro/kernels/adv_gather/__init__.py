from repro.kernels.adv_gather import ops, ref
from repro.kernels.adv_gather.ops import (adv_gather, adv_gather_fused,
                                          adv_gather_packed,
                                          adv_gather_packed_split,
                                          adv_gather_packed_rows,
                                          adv_gather_packed_rows_split,
                                          autotune_packed, autotune_fused,
                                          autotune_packed_rows,
                                          fused_kernel_fits,
                                          packed_kernel_fits,
                                          fuse_tables, FusedTables)

__all__ = ["ops", "ref", "adv_gather", "adv_gather_fused",
           "adv_gather_packed", "adv_gather_packed_split",
           "adv_gather_packed_rows", "adv_gather_packed_rows_split",
           "autotune_packed", "autotune_fused", "autotune_packed_rows",
           "fused_kernel_fits", "packed_kernel_fits",
           "fuse_tables", "FusedTables"]
