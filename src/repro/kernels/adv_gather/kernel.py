"""ADV gather Pallas kernel: out[i, :] = table[codes[i], :] (paper §6.3).

TPU adaptation (DESIGN.md §2): dictionaries are small (K ≤ 2**19 per IMCU,
typically ≪), so the ADV table is pinned in VMEM while code blocks stream
from HBM. The gather itself is executed as a one-hot × table matmul on the
MXU — the one-hot matrix lives only in VREG/VMEM for one (BN × BK) tile and
is never materialized in HBM, which is exactly the paper's 'look it up,
don't recompute/materialize it' insight mapped onto systolic hardware.

Grid: (N/BN, K/BK). The K axis is innermost and accumulates into the same
output tile (out index_map ignores k), the standard Pallas revisiting
pattern. MXU alignment: BN, BK, F padded to multiples of 128 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adv_gather_kernel(codes_ref, table_ref, out_ref, *, bk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                      # (1, BN) int32
    tbl = table_ref[...]                        # (BK, F) f32
    bn = codes.shape[1]
    # one-hot tile for codes that fall in this K block: (BN, BK)
    local = codes.reshape(bn, 1) - k * bk
    col = jax.lax.broadcasted_iota(jnp.int32, (bn, tbl.shape[0]), 1)
    onehot = (local == col).astype(tbl.dtype)
    out_ref[...] += jnp.dot(onehot, tbl,
                            preferred_element_type=out_ref.dtype)


def _adv_gather_multi_kernel(codes_ref, table_ref, out_ref, *, bk: int):
    """Fused multi-table gather-concat (one pass, paper §6 'single step').

    ``table_ref`` tiles a block-diagonal super-table: column c's (K_c, F_c)
    ADV table occupies rows [row_off_c, row_off_c+K_c) and cols
    [col_off_c, col_off_c+F_c). ``codes_ref`` holds C pre-offset code rows
    (code + row_off_c), so the C one-hot tiles sum into one *multi-hot*
    (BN, BK) matrix — column-disjoint blocks make the single matmul produce
    the concatenated feature row for all C source tables at once.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                      # (C, BN) int32, pre-offset
    tbl = table_ref[...]                        # (BK, F_total) f32
    c_count, bn = codes.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bn, tbl.shape[0]), 1)
    multihot = jnp.zeros((bn, tbl.shape[0]), tbl.dtype)
    for c in range(c_count):                    # static unroll over columns
        local = codes[c].reshape(bn, 1) - k * bk
        multihot += (local == col).astype(tbl.dtype)
    out_ref[...] += jnp.dot(multihot, tbl,
                            preferred_element_type=out_ref.dtype)


def _adv_gather_packed_kernel(words_ref, row_off_ref, limits_ref, table_ref,
                              out_ref, *, bk: int, dbs: tuple,
                              word_offs: tuple):
    """Fused unpack -> clamp -> multi-hot gather: int32 codes never exist.

    ``words_ref`` holds every column's device-width (bits | 32) packed words
    concatenated into one stream; column c's words start at ``word_offs[c]``
    and are packed at ``dbs[c]`` bits. Each grid step unpacks just the BN-row
    window it gathers (the bitunpack shift/mask recipe — fields never
    straddle words at divisor widths, so the unpack is lane-parallel), clamps
    to the column's cardinality, shifts into the block-diagonal super-table's
    row space, and accumulates the multi-hot x table matmul. The unpacked
    codes live only in VREGs for one tile — neither host RAM nor HBM ever
    holds a 32-bit code stream.
    """
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tbl = table_ref[...]                        # (BK, F_total) f32
    bn = out_ref.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (bn, tbl.shape[0]), 1)
    multihot = jnp.zeros((bn, tbl.shape[0]), tbl.dtype)
    for c, db in enumerate(dbs):                # static unroll over columns
        s = 32 // db
        nw = bn // s                            # words per BN-row window
        w = words_ref[:, pl.ds(word_offs[c] + i * nw, nw)]   # (1, NW) u32
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (nw, s), 1) \
            * jnp.uint32(db)
        fields = w.reshape(nw, 1) >> shifts     # (NW, S) word-major
        if db < 32:
            fields = fields & jnp.uint32((1 << db) - 1)
        codes = fields.reshape(bn, 1).astype(jnp.int32)
        codes = jnp.clip(codes, 0, limits_ref[c, 0]) + row_off_ref[c, 0]
        multihot += ((codes - k * bk) == col).astype(tbl.dtype)
    out_ref[...] += jnp.dot(multihot, tbl,
                            preferred_element_type=out_ref.dtype)


def _adv_gather_packed_rows_kernel(rows_ref, words_ref, row_off_ref,
                                   limits_ref, table_ref, out_ref, *,
                                   bk: int, dbs: tuple, word_offs: tuple):
    """Random-row variant of the packed kernel: indices in, features out.

    ``rows_ref`` holds a BN-row tile of arbitrary table row indices. For each
    column c the kernel computes the word index (``row // s``, s = 32/db,
    fields never straddle words at divisor widths) and bit offset
    (``(row % s) * db``) against the RESIDENT word stream, extracts the
    field, clamps, shifts into the block-diagonal super-table's row space and
    accumulates the multi-hot x table matmul — one pass, int32 code streams
    never exist, and the only per-launch host->device traffic is the index
    vector itself (4B x N, independent of column count).

    The in-kernel word gather (``jnp.take``) is exact in interpret mode; a
    real-TPU lowering needs a DMA-based gather (ROADMAP: validate on TPU).
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tbl = table_ref[...]                        # (BK, F_total) f32
    rows = rows_ref[...][0]                     # (BN,) int32
    words = words_ref[...][0]                   # (W,) uint32, all columns
    bn = rows.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (bn, tbl.shape[0]), 1)
    multihot = jnp.zeros((bn, tbl.shape[0]), tbl.dtype)
    for c, db in enumerate(dbs):                # static unroll over columns
        s = 32 // db
        w = jnp.take(words, word_offs[c] + rows // s)       # (BN,) u32
        fields = w >> ((rows % s).astype(jnp.uint32) * jnp.uint32(db))
        if db < 32:
            fields = fields & jnp.uint32((1 << db) - 1)
        codes = fields.astype(jnp.int32)
        codes = jnp.clip(codes, 0, limits_ref[c, 0]) + row_off_ref[c, 0]
        multihot += ((codes.reshape(bn, 1) - k * bk) == col).astype(tbl.dtype)
    out_ref[...] += jnp.dot(multihot, tbl,
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n", "bn", "bk", "dbs", "word_offs",
                                    "interpret"))
def adv_gather_packed_rows_pallas(rows: jnp.ndarray, words: jnp.ndarray,
                                  row_offsets: jnp.ndarray,
                                  card_limits: jnp.ndarray,
                                  table: jnp.ndarray, n: int, bn: int = 256,
                                  bk: int = 512, dbs: tuple = (),
                                  word_offs: tuple = (),
                                  interpret: bool = True) -> jnp.ndarray:
    """rows (n,) int32 arbitrary row indices, words (W,) uint32 resident
    streams, table (K_total, F_total) block-diagonal -> (n, F_total).

    Preconditions (enforced by ops.py): n % bn == 0, K_total % bk == 0,
    every row index covered by column c's stream at word_offs[c].
    """
    c_count = row_offsets.shape[0]
    k_rows, f = table.shape
    w = words.shape[0]
    grid = (n // bn, k_rows // bk)
    return pl.pallas_call(
        functools.partial(_adv_gather_packed_rows_kernel, bk=bk, dbs=dbs,
                          word_offs=word_offs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, k: (0, i)),
            pl.BlockSpec((1, w), lambda i, k: (0, 0)),
            pl.BlockSpec((c_count, 1), lambda i, k: (0, 0)),
            pl.BlockSpec((c_count, 1), lambda i, k: (0, 0)),
            pl.BlockSpec((bk, f), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), table.dtype),
        interpret=interpret,
    )(rows.reshape(1, n), words.reshape(1, w), row_offsets, card_limits,
      table)


@functools.partial(jax.jit,
                   static_argnames=("n", "bn", "bk", "dbs", "word_offs",
                                    "interpret"))
def adv_gather_packed_pallas(words: jnp.ndarray, row_offsets: jnp.ndarray,
                             card_limits: jnp.ndarray, table: jnp.ndarray,
                             n: int, bn: int = 256, bk: int = 512,
                             dbs: tuple = (), word_offs: tuple = (),
                             interpret: bool = True) -> jnp.ndarray:
    """words (W,) uint32 (all columns' device-width streams concatenated),
    table (K_total, F_total) block-diagonal -> (n, F_total) features.

    Preconditions (enforced by ops.py): n % bn == 0, bn % 32 == 0 (so every
    window is word-aligned for every divisor width), K_total % bk == 0,
    column c's stream covers n * dbs[c] / 32 words from word_offs[c].
    The whole word stream stays resident across grid steps — it is 32/db x
    smaller than the int32 codes it replaces.
    """
    c_count = row_offsets.shape[0]
    k_rows, f = table.shape
    w = words.shape[0]
    grid = (n // bn, k_rows // bk)
    return pl.pallas_call(
        functools.partial(_adv_gather_packed_kernel, bk=bk, dbs=dbs,
                          word_offs=word_offs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w), lambda i, k: (0, 0)),
            pl.BlockSpec((c_count, 1), lambda i, k: (0, 0)),
            pl.BlockSpec((c_count, 1), lambda i, k: (0, 0)),
            pl.BlockSpec((bk, f), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), table.dtype),
        interpret=interpret,
    )(words.reshape(1, w), row_offsets, card_limits, table)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "interpret"))
def adv_gather_multi_pallas(codes: jnp.ndarray, table: jnp.ndarray,
                            bn: int = 256, bk: int = 512,
                            interpret: bool = True) -> jnp.ndarray:
    """codes (C, N) int32 pre-offset into block-diagonal rows, table
    (K_total, F_total) -> (N, F_total) concatenated features.

    Preconditions (enforced by ops.py): N % bn == 0, K_total % bk == 0,
    F_total % 128 == 0 on real TPU; every codes[c, i] lands inside block c.
    """
    c_count, n = codes.shape
    k_rows, f = table.shape
    grid = (n // bn, k_rows // bk)
    return pl.pallas_call(
        functools.partial(_adv_gather_multi_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c_count, bn), lambda i, k: (0, i)),
            pl.BlockSpec((bk, f), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), table.dtype),
        interpret=interpret,
    )(codes, table)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "interpret"))
def adv_gather_pallas(codes: jnp.ndarray, table: jnp.ndarray,
                      bn: int = 256, bk: int = 512,
                      interpret: bool = True) -> jnp.ndarray:
    """codes (N,) int32, table (K, F) float -> (N, F).

    Preconditions (enforced by ops.py): N % bn == 0, K % bk == 0,
    F % 128 == 0 on real TPU.
    """
    n = codes.shape[0]
    k_rows, f = table.shape
    grid = (n // bn, k_rows // bk)
    return pl.pallas_call(
        functools.partial(_adv_gather_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, k: (0, i)),
            pl.BlockSpec((bk, f), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), table.dtype),
        interpret=interpret,
    )(codes.reshape(1, n), table)
