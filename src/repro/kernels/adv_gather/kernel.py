"""ADV gather Pallas kernel: out[i, :] = table[codes[i], :] (paper §6.3).

TPU adaptation (DESIGN.md §2): dictionaries are small (K ≤ 2**19 per IMCU,
typically ≪), so the ADV table is pinned in VMEM while code blocks stream
from HBM. The gather itself is executed as a one-hot × table matmul on the
MXU — the one-hot matrix lives only in VREG/VMEM for one (BN × BK) tile and
is never materialized in HBM, which is exactly the paper's 'look it up,
don't recompute/materialize it' insight mapped onto systolic hardware.

Grid: (N/BN, K/BK). The K axis is innermost and accumulates into the same
output tile (out index_map ignores k), the standard Pallas revisiting
pattern. MXU alignment: BN, BK, F padded to multiples of 128 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adv_gather_kernel(codes_ref, table_ref, out_ref, *, bk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                      # (1, BN) int32
    tbl = table_ref[...]                        # (BK, F) f32
    bn = codes.shape[1]
    # one-hot tile for codes that fall in this K block: (BN, BK)
    local = codes.reshape(bn, 1) - k * bk
    col = jax.lax.broadcasted_iota(jnp.int32, (bn, tbl.shape[0]), 1)
    onehot = (local == col).astype(tbl.dtype)
    out_ref[...] += jnp.dot(onehot, tbl,
                            preferred_element_type=out_ref.dtype)


def _adv_gather_multi_kernel(codes_ref, table_ref, out_ref, *, bk: int):
    """Fused multi-table gather-concat (one pass, paper §6 'single step').

    ``table_ref`` tiles a block-diagonal super-table: column c's (K_c, F_c)
    ADV table occupies rows [row_off_c, row_off_c+K_c) and cols
    [col_off_c, col_off_c+F_c). ``codes_ref`` holds C pre-offset code rows
    (code + row_off_c), so the C one-hot tiles sum into one *multi-hot*
    (BN, BK) matrix — column-disjoint blocks make the single matmul produce
    the concatenated feature row for all C source tables at once.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                      # (C, BN) int32, pre-offset
    tbl = table_ref[...]                        # (BK, F_total) f32
    c_count, bn = codes.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bn, tbl.shape[0]), 1)
    multihot = jnp.zeros((bn, tbl.shape[0]), tbl.dtype)
    for c in range(c_count):                    # static unroll over columns
        local = codes[c].reshape(bn, 1) - k * bk
        multihot += (local == col).astype(tbl.dtype)
    out_ref[...] += jnp.dot(multihot, tbl,
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "interpret"))
def adv_gather_multi_pallas(codes: jnp.ndarray, table: jnp.ndarray,
                            bn: int = 256, bk: int = 512,
                            interpret: bool = True) -> jnp.ndarray:
    """codes (C, N) int32 pre-offset into block-diagonal rows, table
    (K_total, F_total) -> (N, F_total) concatenated features.

    Preconditions (enforced by ops.py): N % bn == 0, K_total % bk == 0,
    F_total % 128 == 0 on real TPU; every codes[c, i] lands inside block c.
    """
    c_count, n = codes.shape
    k_rows, f = table.shape
    grid = (n // bn, k_rows // bk)
    return pl.pallas_call(
        functools.partial(_adv_gather_multi_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c_count, bn), lambda i, k: (0, i)),
            pl.BlockSpec((bk, f), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), table.dtype),
        interpret=interpret,
    )(codes, table)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "interpret"))
def adv_gather_pallas(codes: jnp.ndarray, table: jnp.ndarray,
                      bn: int = 256, bk: int = 512,
                      interpret: bool = True) -> jnp.ndarray:
    """codes (N,) int32, table (K, F) float -> (N, F).

    Preconditions (enforced by ops.py): N % bn == 0, K % bk == 0,
    F % 128 == 0 on real TPU.
    """
    n = codes.shape[0]
    k_rows, f = table.shape
    grid = (n // bn, k_rows // bk)
    return pl.pallas_call(
        functools.partial(_adv_gather_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, k: (0, i)),
            pl.BlockSpec((bk, f), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), table.dtype),
        interpret=interpret,
    )(codes.reshape(1, n), table)
