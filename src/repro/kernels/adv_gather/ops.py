"""Public jit'd wrappers for the ADV gather kernels.

Handles padding to MXU-aligned block shapes and falls back to the XLA gather
(`ref`) for huge-K tables where one-hot tiling is wasteful (K > 64k — e.g.
full LM vocabularies, which are sharded and gathered natively instead,
see repro.models.lm).

Three entry points:

- :func:`adv_gather` — single (K, F) table, code vector of any shape.
- :func:`fuse_tables` + :func:`adv_gather_fused` — C tables fused into one
  block-diagonal super-table resident on device; per-batch work is ONE kernel
  pass over a (C, N) code matrix producing the concatenated (N, ΣF) features,
  instead of C ``take`` calls + a ``concatenate``. The super-table costs
  ΣK × ΣF floats (vs Σ K_c·F_c unfused), the price of the single-matmul
  layout — ``FusedTables.nbytes`` reports it so planners can budget.
- :func:`adv_gather_packed` — the packed fast path: per-column device-width
  packed word windows go straight into a fused unpack→clamp→multi-hot-gather
  kernel, so int32 code streams never exist on host or device. Guarded by
  :func:`fused_kernel_fits` (ΣK×ΣF VMEM budget): oversized plans fall back
  to :func:`adv_gather_packed_split` (device unpack + per-table gathers —
  still packed transfer, just unfused compute). :func:`autotune_packed`
  sweeps (bn, bk, bw) block shapes and caches the winner per workload shape.
- :func:`adv_gather_packed_rows` — random-row packed gather: a device vector
  of arbitrary row indices goes into a kernel that computes word index + bit
  offset against the RESIDENT word streams, then unpack→clamp→multi-hot
  gather in the same pass. Host->device traffic per call is the index vector
  (4B × N), independent of column count; the same VMEM budget falls back to
  :func:`adv_gather_packed_rows_split`. :func:`autotune_fused` is the int32
  fused kernel's (bn, bk) sweep, ported from the packed path.
"""
from __future__ import annotations

import timeit
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.adv_gather.kernel import (adv_gather_pallas,
                                             adv_gather_multi_pallas,
                                             adv_gather_packed_pallas,
                                             adv_gather_packed_rows_pallas)
from repro.kernels.adv_gather.ref import (adv_gather_ref, adv_gather_multi_ref,
                                          adv_gather_packed_ref,
                                          adv_gather_packed_rows_ref)

MAX_ONEHOT_K = 1 << 16
# fused block-diagonal super-table must fit comfortably in VMEM (~16MB/core)
PACKED_VMEM_BUDGET = 16 << 20


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def adv_gather(table: jnp.ndarray, codes: jnp.ndarray,
               bn: int = 256, bk: int = 512,
               interpret: bool = True) -> jnp.ndarray:
    """Gather ADV rows for a code vector of any shape; returns (*codes.shape, F)."""
    shape = codes.shape
    flat = codes.reshape(-1).astype(jnp.int32)
    k, f = table.shape
    if k > MAX_ONEHOT_K:
        out = adv_gather_ref(flat, table)
        return out.reshape(*shape, f)
    # clamp OOB codes so both paths agree (the one-hot kernel would
    # otherwise emit silent all-zero rows where the ref path clips)
    flat = jnp.clip(flat, 0, k - 1)
    n = flat.shape[0]
    n_pad = _pad_to(max(n, 1), bn)
    k_pad = _pad_to(k, bk)
    f_pad = _pad_to(f, 128)
    flat_p = jnp.pad(flat, (0, n_pad - n))
    table_p = jnp.pad(table, ((0, k_pad - k), (0, f_pad - f)))
    out = adv_gather_pallas(flat_p, table_p, bn=bn, bk=bk,
                            interpret=interpret)
    return out[:n, :f].reshape(*shape, f)


# -- fused multi-table gather-concat ---------------------------------------------


@dataclass(frozen=True)
class FusedTables:
    """Block-diagonal super-table + code offsets for the fused kernel.

    Built once at plan-compile time and kept device-resident (the paper's
    'created once, easily amortized'); per-batch traffic is codes only.
    """
    table: jnp.ndarray            # (K_pad, F_pad) block-diagonal, on device
    row_offsets: jnp.ndarray      # (C, 1) int32 — code shift per source table
    card_limits: jnp.ndarray      # (C, 1) int32 — K_c - 1, for OOB clamping
    dims: tuple[int, ...]         # per-table feature width F_c
    cards: tuple[int, ...]        # per-table cardinality K_c
    bn: int
    bk: int

    @property
    def n_tables(self) -> int:
        return len(self.dims)

    @property
    def out_dim(self) -> int:
        return int(sum(self.dims))

    @property
    def nbytes(self) -> int:
        return int(self.table.size) * self.table.dtype.itemsize


def fuse_tables(tables, bn: int = 256, bk: int = 512,
                dtype=jnp.float32) -> FusedTables:
    """Pack C (K_c, F_c) host tables into one device-resident block diagonal."""
    tables = [np.asarray(t, np.float32) for t in tables]
    cards = tuple(int(t.shape[0]) for t in tables)
    dims = tuple(int(t.shape[1]) for t in tables)
    k_total, f_total = sum(cards), sum(dims)
    k_pad = _pad_to(max(k_total, 1), bk)
    f_pad = _pad_to(max(f_total, 1), 128)
    host = np.zeros((k_pad, f_pad), np.float32)
    row_offsets = np.zeros((len(tables), 1), np.int32)
    r = c = 0
    for i, t in enumerate(tables):
        row_offsets[i, 0] = r
        host[r:r + t.shape[0], c:c + t.shape[1]] = t
        r += t.shape[0]
        c += t.shape[1]
    limits = np.asarray(cards, np.int32)[:, None] - 1
    return FusedTables(table=jnp.asarray(host, dtype),
                       row_offsets=jnp.asarray(row_offsets),
                       card_limits=jnp.asarray(limits),
                       dims=dims, cards=cards, bn=bn, bk=bk)


def place_fused(fused: FusedTables, device) -> FusedTables:
    """Copy of ``fused`` with its device arrays committed to ``device``.

    Mesh-sharded serving replicates the block-diagonal super-table to every
    shard's device (the tables are K-row sized — 'created once, easily
    amortized' — while the word streams stay partitioned): per-shard
    launches then run entirely against device-local operands, never pulling
    the table across the mesh. Idempotent: when the super-table already
    lives wholly on ``device`` (a hot-shard replica landing where another
    shard — or the plan itself — placed it) the same object is returned, so
    adaptive replication never duplicates the table on one device.
    """
    try:
        if fused.table.devices() == {device}:
            return fused
    except Exception:       # pragma: no cover - non-committed/tracer arrays
        pass
    import dataclasses
    return dataclasses.replace(
        fused,
        table=jax.device_put(fused.table, device),
        row_offsets=jax.device_put(fused.row_offsets, device),
        card_limits=jax.device_put(fused.card_limits, device))


def gather_fused_parts(table: jnp.ndarray, row_offsets: jnp.ndarray,
                       codes: jnp.ndarray, out_dim: int,
                       card_limits: jnp.ndarray | None = None,
                       bn: int = 256, bk: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """Functional core of :func:`adv_gather_fused`.

    Takes the super-table and offsets as plain arrays so callers can jit
    over them as *arguments* (refreshed tables flow through; only shape
    changes retrace) instead of baking them in as trace-time constants.
    ``card_limits`` ((C, 1) int32, = K_c - 1) clamps out-of-range codes to
    their own table's block, matching ``jnp.take``'s clamp semantics — an
    unclamped OOB code would silently gather from the NEXT table's rows.
    """
    n = codes.shape[1]
    codes = codes.astype(jnp.int32)
    if card_limits is not None:
        codes = jnp.clip(codes, 0, card_limits)
    shifted = codes + row_offsets
    n_pad = _pad_to(max(n, 1), bn)
    # padded lanes re-point at block 0 row 0; their output rows are sliced off
    shifted = jnp.pad(shifted, ((0, 0), (0, n_pad - n)))
    out = adv_gather_multi_pallas(shifted, table, bn=bn, bk=bk,
                                  interpret=interpret)
    return out[:n, :out_dim]


def adv_gather_fused(fused: FusedTables, codes: jnp.ndarray,
                     interpret: bool = True) -> jnp.ndarray:
    """codes (C, N) int32 (codes[c] indexes source table c) -> (N, ΣF)."""
    c_count = codes.shape[0]
    if c_count != fused.n_tables:
        raise ValueError(f"expected {fused.n_tables} code rows, got {c_count}")
    return gather_fused_parts(fused.table, fused.row_offsets, codes,
                              fused.out_dim, card_limits=fused.card_limits,
                              bn=fused.bn, bk=fused.bk, interpret=interpret)


# -- packed fast path: unpack fused into the gather -------------------------------


def fused_kernel_fits(cards, dims,
                      budget: int = PACKED_VMEM_BUDGET) -> bool:
    """VMEM-budget guard for every fused block-diagonal kernel.

    The super-table costs ΣK × ΣF f32; past ~16MB it no longer fits in VMEM
    alongside the code/word tiles, so callers must split into unfused
    per-table gathers. Originally the packed path's guard; the int32 fused
    gather-concat kernel shares the exact same layout and therefore the
    exact same budget (the ported ROADMAP item).
    """
    sk, sf = sum(cards), sum(dims)
    return sk <= MAX_ONEHOT_K and 4 * sk * sf <= budget


# back-compat name from PR 2, when only the packed path was guarded
packed_kernel_fits = fused_kernel_fits


def adv_gather_packed(windows, dbs, fused_table: jnp.ndarray,
                      row_offsets: jnp.ndarray, card_limits: jnp.ndarray,
                      n: int, out_dim: int, bn: int = 256, bk: int = 512,
                      bw: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Fused unpack+gather: packed word windows -> (n, out_dim) features.

    ``windows[c]`` holds column c's device-width (``dbs[c]`` | 32) packed
    words covering the batch; int32 codes are materialized nowhere — the
    kernel unpacks each (bn)-row tile in VREGs. ``bn`` must be a multiple of
    32 so every tile is word-aligned at every divisor width; ``bw`` pads the
    concatenated word stream to lane-aligned width.
    """
    if bn % 32:
        raise ValueError(f"bn must be a multiple of 32, got {bn}")
    if len(windows) != len(dbs):
        raise ValueError("one device width per window required")
    n_pad = _pad_to(max(n, 1), bn)
    parts, offs, off = [], [], 0
    for win, db in zip(windows, dbs):
        if 32 % db:
            raise ValueError(f"device width {db} does not divide 32")
        need = n_pad * db // 32
        w = jnp.asarray(win, jnp.uint32)[:need]     # over-provisioned slice
        if w.shape[0] < need:
            w = jnp.pad(w, (0, need - w.shape[0]))
        parts.append(w)
        offs.append(off)
        off += need
    flat = jnp.concatenate(parts) if parts else jnp.zeros(0, jnp.uint32)
    flat = jnp.pad(flat, (0, _pad_to(max(off, 1), bw) - off))
    out = adv_gather_packed_pallas(flat, row_offsets, card_limits,
                                   fused_table, n=n_pad, bn=bn, bk=bk,
                                   dbs=tuple(dbs), word_offs=tuple(offs),
                                   interpret=interpret)
    return out[:n, :out_dim]


def adv_gather_packed_rows(flat_words: jnp.ndarray, word_offs, dbs,
                           fused_table: jnp.ndarray,
                           row_offsets: jnp.ndarray,
                           card_limits: jnp.ndarray, rows: jnp.ndarray,
                           out_dim: int, bn: int = 256, bk: int = 512,
                           interpret: bool = True) -> jnp.ndarray:
    """Random-row fused unpack+gather: row indices -> (len(rows), out_dim).

    ``flat_words`` concatenates every column's resident device-width word
    stream (column c's words start at ``word_offs[c]``, packed at ``dbs[c]``
    bits); ``rows`` is a device vector of arbitrary table row indices. The
    kernel computes word index + bit offset in-kernel, so the per-call
    host->device traffic is the 4B × N index vector — int32 code streams
    never exist, for ANY access pattern, not just aligned ranges.
    """
    if len(word_offs) != len(dbs):
        raise ValueError("one word offset per device width required")
    for db in dbs:
        if 32 % db:
            raise ValueError(f"device width {db} does not divide 32")
    rows = jnp.asarray(rows, jnp.int32).reshape(-1)
    n = rows.shape[0]
    n_pad = _pad_to(max(n, 1), bn)
    if n_pad > n:
        # repeat the last row: always a valid index, outputs sliced off
        rows = jnp.pad(rows, (0, n_pad - n), mode="edge")
    out = adv_gather_packed_rows_pallas(rows, flat_words, row_offsets,
                                        card_limits, fused_table, n=n_pad,
                                        bn=bn, bk=bk, dbs=tuple(dbs),
                                        word_offs=tuple(word_offs),
                                        interpret=interpret)
    return out[:n, :out_dim]


def adv_gather_packed_rows_split(flat_words: jnp.ndarray, word_offs, dbs,
                                 tables, rows: jnp.ndarray) -> jnp.ndarray:
    """Unfused fallback for the random-row path: word gather + field
    extract + XLA table gathers, all on device, index-only transfer.

    Op-count-minimal XLA rendering (CPU per-op overhead dominates small
    batches, so the per-column shift/mask pipeline of the oracle would cost
    ~9 ops × C): ONE gather pulls every column's words from the
    concatenated resident stream via a (C, N) word-index matrix, then one
    broadcasted shift/mask extracts all fields at once — per-column work is
    just the final table take. Bit-exact vs
    :func:`adv_gather_packed_rows_ref`; used when ΣK×ΣF exceeds the VMEM
    budget or ΣK exceeds the one-hot tiling guard.
    """
    rows = jnp.asarray(rows, jnp.int32).reshape(-1)
    if not dbs:
        return jnp.zeros((rows.shape[0], 0), jnp.float32)
    # per-column constants, broadcast over the row axis (s = 32/db is a
    # power of two, so // and % become shift and mask)
    log2s = np.array([(32 // db).bit_length() - 1 for db in dbs], np.int32)
    sub_mask = np.array([(32 // db) - 1 for db in dbs], np.int32)
    dbv = np.array(dbs, np.uint32)
    field_mask = np.array([(1 << db) - 1 if db < 32 else 0xFFFFFFFF
                           for db in dbs], np.uint32)
    offv = np.array(word_offs, np.int32)
    widx = offv[:, None] + (rows[None, :] >> log2s[:, None])     # (C, N)
    w = jnp.take(flat_words, widx.reshape(-1),
                 mode="clip").reshape(len(dbs), -1)
    sub = (rows[None, :] & sub_mask[:, None]).astype(jnp.uint32)
    codes = ((w >> (sub * dbv[:, None])) & field_mask[:, None]) \
        .astype(jnp.int32)
    # stop XLA CPU from fusing the extraction into every table gather —
    # the re-fused loop de-vectorizes and costs ~4x the two plain stages
    codes = jax.lax.optimization_barrier(codes)
    return jnp.concatenate(
        [jnp.take(t, codes[c], axis=0, mode="clip")
         for c, t in enumerate(tables)], axis=-1)


def adv_gather_packed_split(windows, dbs, tables, n: int) -> jnp.ndarray:
    """Unfused fallback: per-column device unpack + XLA gather + concat.

    Same packed host->device transfer as the fused kernel (the bytes win is
    preserved); only the compute is split — used when ΣK×ΣF exceeds the
    VMEM budget or ΣK exceeds the one-hot tiling guard.
    """
    return adv_gather_packed_ref(windows, dbs, tables, n)


# one winner per workload signature — the sweep is pure wall-clock timing of
# the real call, so it is only worth paying once per (dbs, n, table) shape
_PACKED_TUNE_CACHE: dict[tuple, tuple[int, int, int]] = {}
PACKED_BLOCK_CANDIDATES = ((128, 512, 512), (256, 256, 512), (256, 512, 512),
                           (256, 512, 1024), (512, 512, 512))


def autotune_packed(windows, dbs, fused: FusedTables, n: int,
                    candidates=PACKED_BLOCK_CANDIDATES, repeats: int = 3,
                    interpret: bool = True) -> tuple[int, int, int]:
    """Sweep (bn, bk, bw) for the fused packed kernel; return the fastest.

    Invalid candidates (bn not word-aligned, bk that does not tile the
    already-padded super-table) are skipped. Results are cached per
    (dbs, n, table-shape) so a serving plan pays the sweep once.
    """
    key = (tuple(dbs), n, tuple(fused.table.shape))
    hit = _PACKED_TUNE_CACHE.get(key)
    if hit is not None:
        return hit
    best, best_t = (fused.bn, fused.bk, 512), float("inf")
    for bn, bk, bw in candidates:
        if bn % 32 or fused.table.shape[0] % bk:
            continue

        def call(bn=bn, bk=bk, bw=bw):
            adv_gather_packed(windows, dbs, fused.table, fused.row_offsets,
                              fused.card_limits, n, fused.out_dim, bn=bn,
                              bk=bk, bw=bw,
                              interpret=interpret).block_until_ready()
        call()                                     # compile outside the clock
        t = min(timeit.repeat(call, number=1, repeat=repeats))
        if t < best_t:
            best, best_t = (bn, bk, bw), t
    _PACKED_TUNE_CACHE[key] = best
    return best


# the int32 fused kernel has no word-stream width to tune, so candidates are
# (bn, bk) pairs — the same row/table tilings the packed sweep explores
_FUSED_TUNE_CACHE: dict[tuple, tuple[int, int]] = {}
FUSED_BLOCK_CANDIDATES = ((128, 512), (256, 256), (256, 512), (512, 512))
_ROWS_TUNE_CACHE: dict[tuple, tuple[int, int]] = {}


def autotune_packed_rows(flat_words, word_offs, dbs, fused: FusedTables,
                         n: int, candidates=FUSED_BLOCK_CANDIDATES,
                         repeats: int = 3,
                         interpret: bool = True) -> tuple[int, int]:
    """Sweep (bn, bk) for the random-row packed kernel; return the fastest.

    Times :func:`adv_gather_packed_rows` ITSELF (its in-kernel word gather
    has a different cost profile than the range kernel's contiguous
    windows, so the range sweep's winner does not transfer). Cached per
    (dbs, n, table-shape); uses row 0 repeated — gather cost in interpret
    mode is index-value independent.
    """
    key = (tuple(dbs), n, tuple(fused.table.shape))
    hit = _ROWS_TUNE_CACHE.get(key)
    if hit is not None:
        return hit
    rows = jnp.zeros(n, jnp.int32)
    best, best_t = (fused.bn, fused.bk), float("inf")
    for bn, bk in candidates:
        if bn % 32 or fused.table.shape[0] % bk:
            continue

        def call(bn=bn, bk=bk):
            adv_gather_packed_rows(flat_words, word_offs, dbs, fused.table,
                                   fused.row_offsets, fused.card_limits,
                                   rows, fused.out_dim, bn=bn, bk=bk,
                                   interpret=interpret).block_until_ready()
        call()                                     # compile outside the clock
        t = min(timeit.repeat(call, number=1, repeat=repeats))
        if t < best_t:
            best, best_t = (bn, bk), t
    _ROWS_TUNE_CACHE[key] = best
    return best


def autotune_fused(codes: jnp.ndarray, fused: FusedTables, n: int,
                   candidates=FUSED_BLOCK_CANDIDATES, repeats: int = 3,
                   interpret: bool = True) -> tuple[int, int]:
    """Sweep (bn, bk) for the int32 fused gather-concat kernel (the packed
    path's autotune, ported per the ROADMAP item); return the fastest.

    ``codes`` is a representative (C, n) int32 batch used purely for wall-
    clock timing. Invalid candidates (bk that does not tile the padded
    super-table) are skipped; results are cached per (C, n, table-shape) so
    a serving plan pays the sweep once per bucket shape.
    """
    codes = jnp.asarray(codes, jnp.int32)
    key = (codes.shape[0], n, tuple(fused.table.shape))
    hit = _FUSED_TUNE_CACHE.get(key)
    if hit is not None:
        return hit
    best, best_t = (fused.bn, fused.bk), float("inf")
    for bn, bk in candidates:
        if fused.table.shape[0] % bk:
            continue

        def call(bn=bn, bk=bk):
            gather_fused_parts(fused.table, fused.row_offsets, codes,
                               fused.out_dim, card_limits=fused.card_limits,
                               bn=bn, bk=bk,
                               interpret=interpret).block_until_ready()
        call()                                     # compile outside the clock
        t = min(timeit.repeat(call, number=1, repeat=repeats))
        if t < best_t:
            best, best_t = (bn, bk), t
    _FUSED_TUNE_CACHE[key] = best
    return best
