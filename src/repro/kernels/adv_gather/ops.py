"""Public jit'd wrappers for the ADV gather kernels.

Handles padding to MXU-aligned block shapes and falls back to the XLA gather
(`ref`) for huge-K tables where one-hot tiling is wasteful (K > 64k — e.g.
full LM vocabularies, which are sharded and gathered natively instead,
see repro.models.lm).

Three entry points:

- :func:`adv_gather` — single (K, F) table, code vector of any shape.
- :func:`fuse_tables` + :func:`adv_gather_fused` — C tables fused into one
  block-diagonal super-table resident on device; per-batch work is ONE kernel
  pass over a (C, N) code matrix producing the concatenated (N, ΣF) features,
  instead of C ``take`` calls + a ``concatenate``. The super-table costs
  ΣK × ΣF floats (vs Σ K_c·F_c unfused), the price of the single-matmul
  layout — ``FusedTables.nbytes`` reports it so planners can budget.
- :func:`adv_gather_packed` — the packed fast path: per-column device-width
  packed word windows go straight into a fused unpack→clamp→multi-hot-gather
  kernel, so int32 code streams never exist on host or device. Guarded by
  :func:`packed_kernel_fits` (ΣK×ΣF VMEM budget): oversized plans fall back
  to :func:`adv_gather_packed_split` (device unpack + per-table gathers —
  still packed transfer, just unfused compute). :func:`autotune_packed`
  sweeps (bn, bk, bw) block shapes and caches the winner per workload shape.
"""
from __future__ import annotations

import timeit
from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.kernels.adv_gather.kernel import (adv_gather_pallas,
                                             adv_gather_multi_pallas,
                                             adv_gather_packed_pallas)
from repro.kernels.adv_gather.ref import (adv_gather_ref, adv_gather_multi_ref,
                                          adv_gather_packed_ref)

MAX_ONEHOT_K = 1 << 16
# fused block-diagonal super-table must fit comfortably in VMEM (~16MB/core)
PACKED_VMEM_BUDGET = 16 << 20


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def adv_gather(table: jnp.ndarray, codes: jnp.ndarray,
               bn: int = 256, bk: int = 512,
               interpret: bool = True) -> jnp.ndarray:
    """Gather ADV rows for a code vector of any shape; returns (*codes.shape, F)."""
    shape = codes.shape
    flat = codes.reshape(-1).astype(jnp.int32)
    k, f = table.shape
    if k > MAX_ONEHOT_K:
        out = adv_gather_ref(flat, table)
        return out.reshape(*shape, f)
    # clamp OOB codes so both paths agree (the one-hot kernel would
    # otherwise emit silent all-zero rows where the ref path clips)
    flat = jnp.clip(flat, 0, k - 1)
    n = flat.shape[0]
    n_pad = _pad_to(max(n, 1), bn)
    k_pad = _pad_to(k, bk)
    f_pad = _pad_to(f, 128)
    flat_p = jnp.pad(flat, (0, n_pad - n))
    table_p = jnp.pad(table, ((0, k_pad - k), (0, f_pad - f)))
    out = adv_gather_pallas(flat_p, table_p, bn=bn, bk=bk,
                            interpret=interpret)
    return out[:n, :f].reshape(*shape, f)


# -- fused multi-table gather-concat ---------------------------------------------


@dataclass(frozen=True)
class FusedTables:
    """Block-diagonal super-table + code offsets for the fused kernel.

    Built once at plan-compile time and kept device-resident (the paper's
    'created once, easily amortized'); per-batch traffic is codes only.
    """
    table: jnp.ndarray            # (K_pad, F_pad) block-diagonal, on device
    row_offsets: jnp.ndarray      # (C, 1) int32 — code shift per source table
    card_limits: jnp.ndarray      # (C, 1) int32 — K_c - 1, for OOB clamping
    dims: tuple[int, ...]         # per-table feature width F_c
    cards: tuple[int, ...]        # per-table cardinality K_c
    bn: int
    bk: int

    @property
    def n_tables(self) -> int:
        return len(self.dims)

    @property
    def out_dim(self) -> int:
        return int(sum(self.dims))

    @property
    def nbytes(self) -> int:
        return int(self.table.size) * self.table.dtype.itemsize


def fuse_tables(tables, bn: int = 256, bk: int = 512,
                dtype=jnp.float32) -> FusedTables:
    """Pack C (K_c, F_c) host tables into one device-resident block diagonal."""
    tables = [np.asarray(t, np.float32) for t in tables]
    cards = tuple(int(t.shape[0]) for t in tables)
    dims = tuple(int(t.shape[1]) for t in tables)
    k_total, f_total = sum(cards), sum(dims)
    k_pad = _pad_to(max(k_total, 1), bk)
    f_pad = _pad_to(max(f_total, 1), 128)
    host = np.zeros((k_pad, f_pad), np.float32)
    row_offsets = np.zeros((len(tables), 1), np.int32)
    r = c = 0
    for i, t in enumerate(tables):
        row_offsets[i, 0] = r
        host[r:r + t.shape[0], c:c + t.shape[1]] = t
        r += t.shape[0]
        c += t.shape[1]
    limits = np.asarray(cards, np.int32)[:, None] - 1
    return FusedTables(table=jnp.asarray(host, dtype),
                       row_offsets=jnp.asarray(row_offsets),
                       card_limits=jnp.asarray(limits),
                       dims=dims, cards=cards, bn=bn, bk=bk)


def gather_fused_parts(table: jnp.ndarray, row_offsets: jnp.ndarray,
                       codes: jnp.ndarray, out_dim: int,
                       card_limits: jnp.ndarray | None = None,
                       bn: int = 256, bk: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """Functional core of :func:`adv_gather_fused`.

    Takes the super-table and offsets as plain arrays so callers can jit
    over them as *arguments* (refreshed tables flow through; only shape
    changes retrace) instead of baking them in as trace-time constants.
    ``card_limits`` ((C, 1) int32, = K_c - 1) clamps out-of-range codes to
    their own table's block, matching ``jnp.take``'s clamp semantics — an
    unclamped OOB code would silently gather from the NEXT table's rows.
    """
    n = codes.shape[1]
    codes = codes.astype(jnp.int32)
    if card_limits is not None:
        codes = jnp.clip(codes, 0, card_limits)
    shifted = codes + row_offsets
    n_pad = _pad_to(max(n, 1), bn)
    # padded lanes re-point at block 0 row 0; their output rows are sliced off
    shifted = jnp.pad(shifted, ((0, 0), (0, n_pad - n)))
    out = adv_gather_multi_pallas(shifted, table, bn=bn, bk=bk,
                                  interpret=interpret)
    return out[:n, :out_dim]


def adv_gather_fused(fused: FusedTables, codes: jnp.ndarray,
                     interpret: bool = True) -> jnp.ndarray:
    """codes (C, N) int32 (codes[c] indexes source table c) -> (N, ΣF)."""
    c_count = codes.shape[0]
    if c_count != fused.n_tables:
        raise ValueError(f"expected {fused.n_tables} code rows, got {c_count}")
    return gather_fused_parts(fused.table, fused.row_offsets, codes,
                              fused.out_dim, card_limits=fused.card_limits,
                              bn=fused.bn, bk=fused.bk, interpret=interpret)


# -- packed fast path: unpack fused into the gather -------------------------------


def packed_kernel_fits(cards, dims,
                       budget: int = PACKED_VMEM_BUDGET) -> bool:
    """VMEM-budget guard for the fused packed kernel.

    The block-diagonal super-table costs ΣK × ΣF f32; past ~16MB it no
    longer fits in VMEM alongside the code windows, so callers must split
    into unfused per-table gathers (:func:`adv_gather_packed_split`).
    """
    sk, sf = sum(cards), sum(dims)
    return sk <= MAX_ONEHOT_K and 4 * sk * sf <= budget


def adv_gather_packed(windows, dbs, fused_table: jnp.ndarray,
                      row_offsets: jnp.ndarray, card_limits: jnp.ndarray,
                      n: int, out_dim: int, bn: int = 256, bk: int = 512,
                      bw: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Fused unpack+gather: packed word windows -> (n, out_dim) features.

    ``windows[c]`` holds column c's device-width (``dbs[c]`` | 32) packed
    words covering the batch; int32 codes are materialized nowhere — the
    kernel unpacks each (bn)-row tile in VREGs. ``bn`` must be a multiple of
    32 so every tile is word-aligned at every divisor width; ``bw`` pads the
    concatenated word stream to lane-aligned width.
    """
    if bn % 32:
        raise ValueError(f"bn must be a multiple of 32, got {bn}")
    if len(windows) != len(dbs):
        raise ValueError("one device width per window required")
    n_pad = _pad_to(max(n, 1), bn)
    parts, offs, off = [], [], 0
    for win, db in zip(windows, dbs):
        if 32 % db:
            raise ValueError(f"device width {db} does not divide 32")
        need = n_pad * db // 32
        w = jnp.asarray(win, jnp.uint32)[:need]     # over-provisioned slice
        if w.shape[0] < need:
            w = jnp.pad(w, (0, need - w.shape[0]))
        parts.append(w)
        offs.append(off)
        off += need
    flat = jnp.concatenate(parts) if parts else jnp.zeros(0, jnp.uint32)
    flat = jnp.pad(flat, (0, _pad_to(max(off, 1), bw) - off))
    out = adv_gather_packed_pallas(flat, row_offsets, card_limits,
                                   fused_table, n=n_pad, bn=bn, bk=bk,
                                   dbs=tuple(dbs), word_offs=tuple(offs),
                                   interpret=interpret)
    return out[:n, :out_dim]


def adv_gather_packed_split(windows, dbs, tables, n: int) -> jnp.ndarray:
    """Unfused fallback: per-column device unpack + XLA gather + concat.

    Same packed host->device transfer as the fused kernel (the bytes win is
    preserved); only the compute is split — used when ΣK×ΣF exceeds the
    VMEM budget or ΣK exceeds the one-hot tiling guard.
    """
    return adv_gather_packed_ref(windows, dbs, tables, n)


# one winner per workload signature — the sweep is pure wall-clock timing of
# the real call, so it is only worth paying once per (dbs, n, table) shape
_PACKED_TUNE_CACHE: dict[tuple, tuple[int, int, int]] = {}
PACKED_BLOCK_CANDIDATES = ((128, 512, 512), (256, 256, 512), (256, 512, 512),
                           (256, 512, 1024), (512, 512, 512))


def autotune_packed(windows, dbs, fused: FusedTables, n: int,
                    candidates=PACKED_BLOCK_CANDIDATES, repeats: int = 3,
                    interpret: bool = True) -> tuple[int, int, int]:
    """Sweep (bn, bk, bw) for the fused packed kernel; return the fastest.

    Invalid candidates (bn not word-aligned, bk that does not tile the
    already-padded super-table) are skipped. Results are cached per
    (dbs, n, table-shape) so a serving plan pays the sweep once.
    """
    key = (tuple(dbs), n, tuple(fused.table.shape))
    hit = _PACKED_TUNE_CACHE.get(key)
    if hit is not None:
        return hit
    best, best_t = (fused.bn, fused.bk, 512), float("inf")
    for bn, bk, bw in candidates:
        if bn % 32 or fused.table.shape[0] % bk:
            continue

        def call(bn=bn, bk=bk, bw=bw):
            adv_gather_packed(windows, dbs, fused.table, fused.row_offsets,
                              fused.card_limits, n, fused.out_dim, bn=bn,
                              bk=bk, bw=bw,
                              interpret=interpret).block_until_ready()
        call()                                     # compile outside the clock
        t = min(timeit.repeat(call, number=1, repeat=repeats))
        if t < best_t:
            best, best_t = (bn, bk, bw), t
    _PACKED_TUNE_CACHE[key] = best
    return best
