"""Public jit'd wrapper for the ADV gather kernel.

Handles padding to MXU-aligned block shapes and falls back to the XLA gather
(`ref`) for huge-K tables where one-hot tiling is wasteful (K > 64k — e.g.
full LM vocabularies, which are sharded and gathered natively instead,
see repro.models.lm).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.adv_gather.kernel import adv_gather_pallas
from repro.kernels.adv_gather.ref import adv_gather_ref

_MAX_ONEHOT_K = 1 << 16


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def adv_gather(table: jnp.ndarray, codes: jnp.ndarray,
               bn: int = 256, bk: int = 512,
               interpret: bool = True) -> jnp.ndarray:
    """Gather ADV rows for a code vector of any shape; returns (*codes.shape, F)."""
    shape = codes.shape
    flat = codes.reshape(-1).astype(jnp.int32)
    k, f = table.shape
    if k > _MAX_ONEHOT_K:
        out = adv_gather_ref(flat, table)
        return out.reshape(*shape, f)
    n = flat.shape[0]
    n_pad = _pad_to(max(n, 1), bn)
    k_pad = _pad_to(k, bk)
    f_pad = _pad_to(f, 128)
    flat_p = jnp.pad(flat, (0, n_pad - n))
    table_p = jnp.pad(table, ((0, k_pad - k), (0, f_pad - f)))
    out = adv_gather_pallas(flat_p, table_p, bn=bn, bk=bk,
                            interpret=interpret)
    return out[:n, :f].reshape(*shape, f)
