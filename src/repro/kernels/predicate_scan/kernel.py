"""Predicate-scan Pallas kernel: filters evaluated on resident packed words.

The paper's featurization story runs selection in code space ("simple
calculations on small integers"); this kernel pushes that selection all the
way into the packed residency layer built by the gather kernels. Each grid
step unpacks a BN-row window of every predicate column straight from the
device-width (bits | 32) word streams — the bitunpack shift/mask recipe,
fields never straddle words at divisor widths — evaluates the per-column
term and AND/OR-combines across columns, writing one selection-bitmap tile.
int32 code streams never exist on host or device; the bitmap feeds
device-side compaction and then ``adv_gather_packed_rows``, so a filtered
serve is one device pipeline.

Term forms (static per compiled predicate, unrolled like the gather
kernels' column loops):

- ``kind 0`` — contiguous code range ``[lo, hi]``: two VPU compares.
  Equality and (on sorted dictionaries) value ranges compile to this.
- ``kind 1`` — arbitrary code set via a K-entry LUT probe: IN-sets and
  unsorted-dictionary ranges. The probe (``jnp.take``) is exact in
  interpret mode; a real-TPU lowering needs a DMA-based gather — the same
  ROADMAP caveat as the random-row packed gather kernel.

Grid: (N/BN,). The whole word stream stays resident across grid steps (it
is 32/db x smaller than the codes it encodes); range bounds ride in a tiny
(T, 2) block and every LUT in one flat (1, L) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _predicate_scan_kernel(words_ref, bounds_ref, lut_ref, out_ref, *,
                           cols: tuple, kinds: tuple, dbs: tuple,
                           word_offs: tuple, lut_offs: tuple,
                           lut_lens: tuple, combine: str):
    i = pl.program_id(0)
    bn = out_ref.shape[1]
    acc = None
    for t, c in enumerate(cols):                # static unroll over terms
        db = dbs[c]
        s = 32 // db
        nw = bn // s                            # words per BN-row window
        w = words_ref[:, pl.ds(word_offs[c] + i * nw, nw)]   # (1, NW) u32
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (nw, s), 1) \
            * jnp.uint32(db)
        fields = w.reshape(nw, 1) >> shifts     # (NW, S) word-major
        if db < 32:
            fields = fields & jnp.uint32((1 << db) - 1)
        codes = fields.reshape(1, bn).astype(jnp.int32)
        if kinds[t] == 0:                       # contiguous code range
            m = (codes >= bounds_ref[t, 0]) & (codes <= bounds_ref[t, 1])
        else:                                   # K-entry LUT probe
            lut = lut_ref[...][0]
            idx = jnp.minimum(codes.reshape(bn), lut_lens[t] - 1)
            m = (jnp.take(lut, lut_offs[t] + idx) != 0).reshape(1, bn)
        if acc is None:
            acc = m
        else:
            acc = (acc & m) if combine == "and" else (acc | m)
    out_ref[...] = acc.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("n", "bn", "cols", "kinds", "dbs",
                                    "word_offs", "lut_offs", "lut_lens",
                                    "combine", "interpret"))
def predicate_scan_pallas(words: jnp.ndarray, bounds: jnp.ndarray,
                          lut: jnp.ndarray, n: int, bn: int = 1024,
                          cols: tuple = (), kinds: tuple = (),
                          dbs: tuple = (), word_offs: tuple = (),
                          lut_offs: tuple = (), lut_lens: tuple = (),
                          combine: str = "and",
                          interpret: bool = True) -> jnp.ndarray:
    """words (W,) uint32 concatenated streams, bounds (T, 2) int32 range
    rows, lut (L,) int32 concatenated LUTs -> (n,) int32 selection bitmap.

    Preconditions (enforced by ops.py): n % bn == 0, bn % 32 == 0 (every
    window word-aligned at every divisor width), column c's stream covers
    n * dbs[c] / 32 words from word_offs[c], at least one term.
    """
    w = words.shape[0]
    t = bounds.shape[0]
    l = lut.shape[0]
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_predicate_scan_kernel, cols=cols, kinds=kinds,
                          dbs=dbs, word_offs=word_offs, lut_offs=lut_offs,
                          lut_lens=lut_lens, combine=combine),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((t, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, l), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(words.reshape(1, w), bounds, lut.reshape(1, l)).reshape(n)
