from repro.kernels.predicate_scan import ops, ref
from repro.kernels.predicate_scan.ops import (ScanTerm, pack_terms,
                                              predicate_scan,
                                              predicate_scan_split,
                                              predicate_scan_split_count,
                                              compact_rows, masked_counts)

__all__ = ["ops", "ref", "ScanTerm", "pack_terms", "predicate_scan",
           "predicate_scan_split", "predicate_scan_split_count",
           "compact_rows", "masked_counts"]
