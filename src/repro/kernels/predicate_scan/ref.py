"""Numpy host oracles for the predicate-scan kernel.

The reference works on the SAME device-width packed word streams the kernel
scans (not on pre-decoded codes), so a test that compares against it checks
the whole unpack-and-compare pipeline bit-exactly, word straddles included.
"""
from __future__ import annotations

import numpy as np

from repro.columnar.bitpack import unpack_bits


def term_mask_ref(codes: np.ndarray, term) -> np.ndarray:
    """Evaluate one compiled code-space term over an int32 code vector.

    ``term`` needs ``kind`` (0 = range, 1 = LUT), ``lo``/``hi`` and ``lut``
    attributes — the :class:`repro.kernels.predicate_scan.ops.ScanTerm`
    shape, duck-typed so the oracle stays import-free of the ops layer.
    """
    if term.kind == 0:
        return (codes >= term.lo) & (codes <= term.hi)
    lut = np.asarray(term.lut)
    return lut[np.minimum(codes, lut.shape[0] - 1)] != 0


def predicate_scan_ref(words_list, dbs, terms, n: int,
                       combine: str = "and") -> np.ndarray:
    """Host oracle: unpack each referenced column's word stream and combine
    the per-term masks. ``words_list[c]`` is column c's device-width packed
    words (``dbs[c]`` bits); returns the (n,) bool selection mask."""
    if not terms:
        raise ValueError("need at least one predicate term")
    if combine not in ("and", "or"):
        raise ValueError(f"unknown combinator {combine!r}")
    acc = None
    codes_cache: dict[int, np.ndarray] = {}
    for t in terms:
        codes = codes_cache.get(t.col)
        if codes is None:
            codes = unpack_bits(np.asarray(words_list[t.col], np.uint32),
                                dbs[t.col], n)
            codes_cache[t.col] = codes
        m = term_mask_ref(codes, t)
        if acc is None:
            acc = m
        else:
            acc = (acc & m) if combine == "and" else (acc | m)
    return acc


def compact_rows_ref(mask: np.ndarray) -> np.ndarray:
    """Host oracle for bitmap compaction: ascending matching row indices."""
    return np.flatnonzero(np.asarray(mask)).astype(np.int32)


def masked_counts_ref(codes: np.ndarray, mask: np.ndarray,
                      k: int) -> np.ndarray:
    """Host oracle for the dict-aware masked aggregate: per-code counts of
    rows where ``mask`` — sum/mean of the column then follow from K
    dictionary entries (counts · values), never the N-row stream."""
    codes = np.asarray(codes)
    return np.bincount(codes[np.asarray(mask, bool)],
                       minlength=k).astype(np.int32)[:k]
