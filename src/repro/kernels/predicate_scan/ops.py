"""Public wrappers for the predicate-scan kernel, device bitmap compaction
and dict-aware masked aggregates.

Two evaluation paths over the SAME resident word streams, mirroring the
gather kernels' split/fused discipline:

- :func:`predicate_scan` — the fused Pallas kernel: per-term word windows
  are sliced (and zero-padded) out of the resident flat stream on device,
  then one kernel pass unpacks + compares + combines per BN-row tile.
- :func:`predicate_scan_split` — the op-count-minimal XLA rendering used by
  default on CPU (interpret-mode Pallas is Python-speed): ONE broadcast
  shift/mask unpack per referenced column directly against the resident
  flat stream, then vectorized compares / LUT takes, all inside one jit —
  bit-exact vs :func:`repro.kernels.predicate_scan.ref.predicate_scan_ref`.

Downstream pieces of the pushdown pipeline:

- :func:`compact_rows` — device-side bitmap -> row-index compaction with a
  static output shape (the pad-to-static-bucket contract), feeding
  ``adv_gather_packed_rows`` so "scan -> compact -> gather" never leaves
  the device.
- :func:`masked_counts` — masked per-code histogram over a column's
  resident words (the ``kernels/hist`` machinery with a mask lane):
  count/sum/mean of the column under a predicate then follow from K
  dictionary entries, never the N-row value stream.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.predicate_scan.kernel import predicate_scan_pallas
from repro.kernels.hist.ops import masked_hist


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ScanTerm:
    """One column's compiled code-space predicate term.

    ``kind`` 0 matches the contiguous code range ``[lo, hi]`` (pure VPU
    compares on device — an empty range, ``hi < lo``, matches nothing);
    kind 1 matches where ``lut[code] != 0`` (arbitrary IN-sets; ``lut`` has
    one entry per dictionary code). Produced by
    :func:`repro.columnar.query.compile_predicate`.
    """
    col: int
    kind: int
    lo: int = 0
    hi: int = -1
    lut: np.ndarray | None = field(default=None, compare=False)


def _pack_terms(terms, dbs):
    """Split a term list into the kernel's static structure + traced data.

    Returns (bounds (T, 2) int32, flat_lut (L,) int32, statics) where
    statics = (cols, kinds, lut_offs, lut_lens) — identically-shaped
    predicates (same columns/kinds, different constants) share one compile.
    """
    if not terms:
        raise ValueError("need at least one predicate term")
    cols, kinds, lut_offs, lut_lens = [], [], [], []
    bounds = np.zeros((len(terms), 2), np.int32)
    luts, off = [], 0
    for t, term in enumerate(terms):
        if not 0 <= term.col < len(dbs):
            raise ValueError(f"term column {term.col} outside plan "
                             f"(C={len(dbs)})")
        cols.append(term.col)
        kinds.append(term.kind)
        if term.kind == 0:
            bounds[t] = (term.lo, term.hi)
            lut_offs.append(0)
            lut_lens.append(1)
        else:
            lut = np.asarray(term.lut, np.int32).reshape(-1)
            if lut.shape[0] == 0:
                raise ValueError("LUT term needs a K-entry table")
            luts.append(lut)
            lut_offs.append(off)
            lut_lens.append(lut.shape[0])
            off += lut.shape[0]
    flat_lut = (np.concatenate(luts) if luts else np.zeros(1, np.int32))
    return (jnp.asarray(bounds), jnp.asarray(flat_lut),
            (tuple(cols), tuple(kinds), tuple(lut_offs), tuple(lut_lens)))


def predicate_scan(flat_words: jnp.ndarray, word_offs, dbs, terms, n: int,
                   combine: str = "and", bn: int = 1024,
                   interpret: bool = True) -> jnp.ndarray:
    """Fused Pallas scan: resident flat stream + compiled terms -> (n,)
    bool selection mask.

    Only the referenced columns' windows enter the kernel stream — sliced
    (statically) from the resident flat words and zero-padded to the tile
    quantum on device, so padding rows decode to code 0 and their mask
    lanes are sliced off with the rest of [n, n_pad).
    """
    if bn % 32:
        raise ValueError(f"bn must be a multiple of 32, got {bn}")
    if combine not in ("and", "or"):
        raise ValueError(f"unknown combinator {combine!r}")
    bounds, flat_lut, (cols, kinds, lut_offs, lut_lens) = \
        _pack_terms(terms, dbs)
    n_pad = _pad_to(max(n, 1), bn)
    used = sorted(set(cols))
    remap = {c: i for i, c in enumerate(used)}
    parts, offs, off = [], [], 0
    for c in used:
        db = dbs[c]
        need = n_pad * db // 32
        w = jnp.asarray(flat_words, jnp.uint32)[word_offs[c]:
                                                word_offs[c] + need]
        if w.shape[0] < need:
            w = jnp.pad(w, (0, need - w.shape[0]))
        parts.append(w)
        offs.append(off)
        off += need
    stream = jnp.concatenate(parts)
    mask = predicate_scan_pallas(
        stream, bounds, flat_lut, n=n_pad, bn=bn,
        cols=tuple(remap[c] for c in cols), kinds=kinds,
        dbs=tuple(dbs[c] for c in used), word_offs=tuple(offs),
        lut_offs=lut_offs, lut_lens=lut_lens, combine=combine,
        interpret=interpret)
    return mask[:n] != 0


def _scan_body(flat_words, bounds, flat_lut, *, word_offs, dbs, n, cols,
               kinds, lut_offs, lut_lens, combine):
    """XLA split scan: whole-stream broadcast unpack + vectorized terms.

    Few large fused ops (CPU per-op overhead dominates tile loops), one
    unpack per referenced column even when several terms share it. The
    resident stream covers _pad32(n) rows per column (the executor's
    capacity quantum), so the static slices never cross column segments.
    """
    acc = None
    codes_cache = {}
    for t, c in enumerate(cols):
        codes = codes_cache.get(c)
        if codes is None:
            db = dbs[c]
            s = 32 // db
            nw = (n + s - 1) // s
            w = flat_words[word_offs[c]:word_offs[c] + nw]
            shifts = (jnp.arange(s, dtype=jnp.uint32) * jnp.uint32(db))
            fields = w[:, None] >> shifts[None, :]          # (NW, S)
            if db < 32:
                fields = fields & jnp.uint32((1 << db) - 1)
            codes = fields.reshape(-1)[:n].astype(jnp.int32)
            codes_cache[c] = codes
        if kinds[t] == 0:
            m = (codes >= bounds[t, 0]) & (codes <= bounds[t, 1])
        else:
            idx = jnp.minimum(codes, lut_lens[t] - 1)
            m = jnp.take(flat_lut, lut_offs[t] + idx, mode="clip") != 0
        acc = m if acc is None else \
            ((acc & m) if combine == "and" else (acc | m))
    return acc


_SCAN_STATICS = ("word_offs", "dbs", "n", "cols", "kinds", "lut_offs",
                 "lut_lens", "combine")
_scan_split = functools.partial(jax.jit,
                                static_argnames=_SCAN_STATICS)(_scan_body)


@functools.partial(jax.jit, static_argnames=_SCAN_STATICS)
def _scan_split_count(flat_words, bounds, flat_lut, *, word_offs, dbs, n,
                      cols, kinds, lut_offs, lut_lens, combine):
    """Scan + popcount in ONE launch: the match count (the compaction's
    static launch shape) rides along with the mask, so the filtered-serving
    hot path syncs one scalar without a separate eager reduction dispatch."""
    mask = _scan_body(flat_words, bounds, flat_lut, word_offs=word_offs,
                      dbs=dbs, n=n, cols=cols, kinds=kinds,
                      lut_offs=lut_offs, lut_lens=lut_lens, combine=combine)
    return mask, jnp.sum(mask.astype(jnp.int32))


def pack_terms(terms, dbs):
    """Pre-pack a term list for repeated scans: (bounds, flat_lut, statics)
    with the data halves already on device. A deployed filter family scans
    on every request — re-shipping two small arrays per call is pure
    dispatch overhead, so executors cache this per compiled predicate."""
    return _pack_terms(terms, dbs)


def predicate_scan_split(flat_words: jnp.ndarray, word_offs, dbs, terms,
                         n: int, combine: str = "and",
                         packed=None) -> jnp.ndarray:
    """Unfused fallback/CPU default: same resident stream, same (n,) bool
    mask, rendered as one jit of broadcast unpacks + compares."""
    if combine not in ("and", "or"):
        raise ValueError(f"unknown combinator {combine!r}")
    bounds, flat_lut, (cols, kinds, lut_offs, lut_lens) = \
        packed if packed is not None else _pack_terms(terms, dbs)
    return _scan_split(flat_words, bounds, flat_lut,
                       word_offs=tuple(word_offs), dbs=tuple(dbs), n=n,
                       cols=cols, kinds=kinds, lut_offs=lut_offs,
                       lut_lens=lut_lens, combine=combine)


def predicate_scan_split_count(flat_words: jnp.ndarray, word_offs, dbs,
                               terms, n: int, combine: str = "and",
                               packed=None):
    """Split scan variant returning (mask, match-count) from one launch."""
    if combine not in ("and", "or"):
        raise ValueError(f"unknown combinator {combine!r}")
    bounds, flat_lut, (cols, kinds, lut_offs, lut_lens) = \
        packed if packed is not None else _pack_terms(terms, dbs)
    return _scan_split_count(flat_words, bounds, flat_lut,
                             word_offs=tuple(word_offs), dbs=tuple(dbs),
                             n=n, cols=cols, kinds=kinds, lut_offs=lut_offs,
                             lut_lens=lut_lens, combine=combine)


@functools.partial(jax.jit, static_argnames=("cap", "fill"))
def compact_rows(mask: jnp.ndarray, cap: int, fill: int = 0) -> jnp.ndarray:
    """Device-side bitmap compaction: ascending matching row indices as a
    static-shape (cap,) int32 vector.

    Entries past the true match count hold ``fill`` — a valid row index,
    per the pad-to-static-bucket contract — so the vector can feed the
    indexed gather directly and callers slice the valid prefix off the
    OUTPUT, exactly like ``pad_rows_edge`` on the host side.

    Rendered as cumsum + searchsorted (the j-th match is the first row
    whose running count reaches j+1) rather than ``jnp.nonzero``: the
    all-gather form avoids XLA:CPU's element-at-a-time scatter lowering,
    which costs ~8x more at serving shapes.
    """
    c = jnp.cumsum(mask.astype(jnp.int32))
    rows = jnp.searchsorted(c, jnp.arange(1, cap + 1, dtype=jnp.int32),
                            side="left")
    return jnp.where(rows < mask.shape[0], rows, fill).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("off", "db", "n", "k"))
def _masked_counts_split(flat_words, mask, *, off, db, n, k):
    """XLA masked histogram against the resident words: one broadcast
    unpack + one segment-sum scatter-add."""
    s = 32 // db
    nw = (n + s - 1) // s
    w = flat_words[off:off + nw]
    shifts = (jnp.arange(s, dtype=jnp.uint32) * jnp.uint32(db))
    fields = w[:, None] >> shifts[None, :]
    if db < 32:
        fields = fields & jnp.uint32((1 << db) - 1)
    codes = fields.reshape(-1)[:n].astype(jnp.int32)
    hits = mask.astype(jnp.int32)
    return jnp.zeros(k, jnp.int32).at[codes].add(hits, mode="drop")


def masked_counts(flat_words: jnp.ndarray, off: int, db: int,
                  mask: jnp.ndarray, k: int, n: int,
                  use_kernel: bool = False,
                  interpret: bool = True) -> jnp.ndarray:
    """Masked GROUP BY over a resident column: (k,) int32 per-code counts
    of the rows where ``mask`` is set.

    This is the dict-aware aggregate core: ``counts @ values`` gives the
    masked sum, ``counts.sum()`` the masked count, their ratio the mean —
    K dictionary entries of tail work, never an N-row value decode.
    ``use_kernel=True`` routes the histogram through the masked
    ``kernels/hist`` Pallas kernel (the count-metadata build kernel with a
    mask lane); the default is the one-jit XLA scatter-add.
    """
    mask = jnp.asarray(mask).reshape(-1)[:n]
    if use_kernel:
        s = 32 // db
        nw = (n + s - 1) // s
        w = jnp.asarray(flat_words, jnp.uint32)[off:off + nw]
        shifts = (jnp.arange(s, dtype=jnp.uint32) * jnp.uint32(db))
        fields = w[:, None] >> shifts[None, :]
        if db < 32:
            fields = fields & jnp.uint32((1 << db) - 1)
        codes = fields.reshape(-1)[:n].astype(jnp.int32)
        return masked_hist(codes, mask, k, interpret=interpret)
    return _masked_counts_split(flat_words, mask, off=off, db=db, n=n, k=k)
