"""Histogram / count-metadata build Pallas kernel (paper §6.2).

Builds the per-dictionary-entry counts from a code stream: the operation a
columnar DB runs at load time so that later stats queries never scan rows.

Grid: (K/BK, N/BN) — N innermost so each (1, BK) count tile stays resident in
VMEM while code blocks stream past it; per block the partial histogram is a
compare-against-iota matrix reduced over the code axis (VPU work, no MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(codes_ref, out_ref, *, bk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                       # (1, BN) int32
    k0 = pl.program_id(0) * bk
    bn = codes.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) + k0
    hits = (rows == codes).astype(jnp.int32)     # (BK, BN)
    out_ref[...] += hits.sum(axis=1, keepdims=True).reshape(1, bk)


@functools.partial(jax.jit, static_argnames=("k", "bn", "bk", "interpret"))
def hist_pallas(codes: jnp.ndarray, k: int, bn: int = 1024, bk: int = 512,
                interpret: bool = True) -> jnp.ndarray:
    """codes (N,) int32 in [0, k) -> counts (k,) int32.

    Preconditions (ops.py): N % bn == 0, k % bk == 0.
    """
    n = codes.shape[0]
    grid = (k // bk, n // bn)
    return pl.pallas_call(
        functools.partial(_hist_kernel, bk=bk),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((1, bk), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.int32),
        interpret=interpret,
    )(codes.reshape(1, n)).reshape(k)


def _masked_hist_kernel(codes_ref, mask_ref, out_ref, *, bk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                       # (1, BN) int32
    mask = mask_ref[...]                         # (1, BN) int32
    k0 = pl.program_id(0) * bk
    bn = codes.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) + k0
    hits = ((rows == codes) & (mask > 0)).astype(jnp.int32)   # (BK, BN)
    out_ref[...] += hits.sum(axis=1, keepdims=True).reshape(1, bk)


@functools.partial(jax.jit, static_argnames=("k", "bn", "bk", "interpret"))
def masked_hist_pallas(codes: jnp.ndarray, mask: jnp.ndarray, k: int,
                       bn: int = 1024, bk: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """codes (N,) int32 in [0, k), mask (N,) int32 -> counts (k,) int32 of
    the codes whose mask lane is nonzero — the predicate-pushdown aggregate
    core: the count tile stays resident while code AND selection-bitmap
    blocks stream past it together.

    Preconditions (ops.py): N % bn == 0, k % bk == 0.
    """
    n = codes.shape[0]
    grid = (k // bk, n // bn)
    return pl.pallas_call(
        functools.partial(_masked_hist_kernel, bk=bk),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bn), lambda i, j: (0, j)),
                  pl.BlockSpec((1, bn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((1, bk), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.int32),
        interpret=interpret,
    )(codes.reshape(1, n), mask.reshape(1, n)).reshape(k)
