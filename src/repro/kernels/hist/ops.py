"""Public wrapper for the count-metadata histogram kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.hist.kernel import hist_pallas, masked_hist_pallas


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def hist(codes: jnp.ndarray, k: int, bn: int = 1024, bk: int = 512,
         interpret: bool = True) -> jnp.ndarray:
    """Count occurrences of each code in [0, k)."""
    flat = codes.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    n_pad = _pad_to(max(n, 1), bn)
    k_pad = _pad_to(k, bk)
    flat_p = jnp.pad(flat, (0, n_pad - n), constant_values=-1)  # no lane hit
    out = hist_pallas(flat_p, k_pad, bn=bn, bk=bk, interpret=interpret)
    return out[:k]


def masked_hist(codes: jnp.ndarray, mask: jnp.ndarray, k: int,
                bn: int = 1024, bk: int = 512,
                interpret: bool = True) -> jnp.ndarray:
    """Count occurrences of each code in [0, k) where ``mask`` is set —
    the histogram a predicate-pushdown aggregate runs over a selection
    bitmap instead of the whole column."""
    flat = codes.reshape(-1).astype(jnp.int32)
    m = mask.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    n_pad = _pad_to(max(n, 1), bn)
    k_pad = _pad_to(k, bk)
    flat_p = jnp.pad(flat, (0, n_pad - n), constant_values=-1)  # no lane hit
    m_p = jnp.pad(m, (0, n_pad - n))                            # mask=0 pad
    out = masked_hist_pallas(flat_p, m_p, k_pad, bn=bn, bk=bk,
                             interpret=interpret)
    return out[:k]
