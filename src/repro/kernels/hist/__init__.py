from repro.kernels.hist import ops, ref
from repro.kernels.hist.ops import hist

__all__ = ["ops", "ref", "hist"]
