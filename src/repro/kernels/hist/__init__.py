from repro.kernels.hist import ops, ref
from repro.kernels.hist.ops import hist, masked_hist

__all__ = ["ops", "ref", "hist", "masked_hist"]
