"""Pure-jnp oracle for hist."""
import jax.numpy as jnp


def hist_ref(codes: jnp.ndarray, k: int) -> jnp.ndarray:
    return jnp.bincount(codes, length=k).astype(jnp.int32)


def masked_hist_ref(codes: jnp.ndarray, mask: jnp.ndarray,
                    k: int) -> jnp.ndarray:
    return jnp.bincount(jnp.where(mask, codes, k), length=k + 1)[:k] \
        .astype(jnp.int32)
