"""Pure-jnp oracle for hist."""
import jax.numpy as jnp


def hist_ref(codes: jnp.ndarray, k: int) -> jnp.ndarray:
    return jnp.bincount(codes, length=k).astype(jnp.int32)
