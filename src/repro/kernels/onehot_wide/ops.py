"""Public wrapper for the fused one-hot wide layer."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.onehot_wide.kernel import onehot_wide_pallas
from repro.kernels.onehot_wide.ref import onehot_wide_ref


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def onehot_wide(codes: jnp.ndarray, w: jnp.ndarray,
                bn: int = 256, bk: int = 512,
                interpret: bool = True) -> jnp.ndarray:
    """codes (C, N) int32, w (C, K, F) -> (N, F) wide-layer output."""
    c, n = codes.shape
    _, k, f = w.shape
    n_pad = _pad_to(max(n, 1), bn)
    k_pad = _pad_to(k, bk)
    f_pad = _pad_to(f, 128)
    # pad codes with an out-of-range index so padded rows hit no one-hot lane
    codes_p = jnp.pad(codes.astype(jnp.int32), ((0, 0), (0, n_pad - n)),
                      constant_values=-1)
    w_p = jnp.pad(w, ((0, 0), (0, k_pad - k), (0, f_pad - f)))
    out = onehot_wide_pallas(codes_p, w_p, bn=bn, bk=bk, interpret=interpret)
    return out[:n, :f]
