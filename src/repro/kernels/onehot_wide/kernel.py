"""Fused one-hot wide layer (paper §6.1.3 + Wide&Deep context).

Computes  out[n, :] = sum_c  W[c, codes[c, n], :]  for C categorical columns —
the wide part of a Wide&Deep model — without ever materializing the (N, ΣK)
one-hot design matrix in HBM. Each grid step turns one (BN,) code block of one
column into a VREG-resident one-hot tile and feeds the MXU, accumulating into
the same (BN, F) output tile across columns and K-blocks.

Grid: (N/BN, C, K/BK), output revisited over (c, k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onehot_wide_kernel(codes_ref, w_ref, out_ref, *, bk: int):
    c = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((c == 0) & (k == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]                        # (1, BN) int32, column c
    w = w_ref[...]                                # (1, BK, F)
    bn = codes.shape[1]
    local = codes.reshape(bn, 1) - k * bk
    col = jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1)
    onehot = (local == col).astype(w.dtype)
    out_ref[...] += jnp.dot(onehot, w[0],
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def onehot_wide_pallas(codes: jnp.ndarray, w: jnp.ndarray,
                       bn: int = 256, bk: int = 512,
                       interpret: bool = True) -> jnp.ndarray:
    """codes (C, N) int32; w (C, K, F) float -> out (N, F).

    Preconditions (ops.py): N % bn == 0, K % bk == 0.
    """
    c, n = codes.shape
    _, k_rows, f = w.shape
    grid = (n // bn, c, k_rows // bk)
    return pl.pallas_call(
        functools.partial(_onehot_wide_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, c, k: (c, i)),
            pl.BlockSpec((1, bk, f), lambda i, c, k: (c, k, 0)),
        ],
        out_specs=pl.BlockSpec((bn, f), lambda i, c, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), w.dtype),
        interpret=interpret,
    )(codes, w)
