"""Pure-jnp oracle for onehot_wide."""
import jax
import jax.numpy as jnp


def onehot_wide_ref(codes: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """codes (C, N), w (C, K, F) -> sum_c w[c, codes[c, n], :]  (N, F)."""
    gathered = jnp.take_along_axis(
        w, codes[:, :, None].astype(jnp.int32), axis=1)   # (C, N, F)
    return gathered.sum(axis=0)


def onehot_wide_materialized(codes: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """The traditional path: materialize one-hot then matmul (benchmarks)."""
    k = w.shape[1]
    oh = jax.nn.one_hot(codes, k, dtype=w.dtype)          # (C, N, K)
    return jnp.einsum("cnk,ckf->nf", oh, w)
