from repro.kernels.onehot_wide import ops, ref
from repro.kernels.onehot_wide.ops import onehot_wide

__all__ = ["ops", "ref", "onehot_wide"]
