from repro.kernels.bitunpack import ops, ref
from repro.kernels.bitunpack.ops import bitunpack, repack_for_device
from repro.kernels.bitunpack.kernel import tpu_width

__all__ = ["ops", "ref", "bitunpack", "repack_for_device", "tpu_width"]
