"""Pure-jnp oracles for bitunpack (general widths, incl. straddling fields)."""
import jax.numpy as jnp

from repro.columnar.bitpack import unpack_bits_jnp


def bitunpack_ref(words, bits: int, n: int):
    return unpack_bits_jnp(words, bits, n)


def bitunpack_divisor_ref(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Gather-free unpack for divisor widths (bits | 32) — the vector recipe
    the Pallas kernel uses, expressed in XLA. Fields never straddle words, so
    the unpack is a reshape + shift + mask with no cross-lane indexing.

    ``words`` (W,) uint32 packed at ``bits``; returns (n,) int32 codes.
    Over-provisioned ``words`` are sliced to the ``n`` codes requested.
    """
    if 32 % bits:
        raise ValueError(f"divisor unpack needs bits | 32, got {bits}")
    s = 32 // bits
    w = jnp.asarray(words, jnp.uint32)[: (n + s - 1) // s]
    shifts = jnp.arange(s, dtype=jnp.uint32) * jnp.uint32(bits)
    fields = w[:, None] >> shifts[None, :]          # (W, S) word-major
    if bits < 32:
        fields = fields & jnp.uint32((1 << bits) - 1)
    return fields.reshape(-1)[:n].astype(jnp.int32)
