"""Pure-jnp oracle for bitunpack (general widths, incl. straddling fields)."""
from repro.columnar.bitpack import unpack_bits_jnp


def bitunpack_ref(words, bits: int, n: int):
    return unpack_bits_jnp(words, bits, n)
