"""Bit-unpack Pallas kernel: packed uint32 words -> int32 codes (paper §5.1).

TPU adaptation of the DAX/SIMD packed scan (DESIGN.md §2): TPU vector units
have no cross-lane funnel shift, so gather-free unpacking requires the field
width to divide the 32-bit word. ops.py therefore rounds dictionary widths up
to the next divisor of 32 ({1,2,4,8,16,32}) for device shipping — trading a
bounded ≤2x packing loss (e.g. 6->8 bits) for a fully lane-parallel unpack:

    out.reshape(BW, S)[w, s] = (words[w] >> (s*b)) & mask,  S = 32/b

Each grid step unpacks one (1, BW) word tile into an (S, BW)-transposed code
tile, all in VREGs. Host storage (columnar/bitpack.py) keeps exact widths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DIVISOR_WIDTHS = (1, 2, 4, 8, 16, 32)


def tpu_width(bits: int) -> int:
    """Round a dictionary bit-width up to the next divisor of 32."""
    for w in DIVISOR_WIDTHS:
        if bits <= w:
            return w
    raise ValueError(f"bits {bits} > 32")


def _bitunpack_kernel(words_ref, out_ref, *, bits: int):
    words = words_ref[...]                       # (1, BW) uint32
    bw = words.shape[1]
    s = 32 // bits
    # (S, BW): subfield s of word w
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (s, bw), 0) * jnp.uint32(bits)
    fields = (words.astype(jnp.uint32) >> shifts)
    if bits < 32:
        fields = fields & jnp.uint32((1 << bits) - 1)
    # code order is word-major, subfield-minor -> transpose to (BW, S)
    out_ref[...] = fields.T.reshape(1, bw * s).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "bw", "interpret"))
def bitunpack_pallas(words: jnp.ndarray, bits: int, bw: int = 512,
                     interpret: bool = True) -> jnp.ndarray:
    """words (W,) uint32 packed at ``bits`` (must divide 32, W % bw == 0)
    -> (W * 32/bits,) int32 codes."""
    if 32 % bits:
        raise ValueError(f"device path needs bits | 32, got {bits} "
                         "(use tpu_width + ops.repack)")
    w = words.shape[0]
    s = 32 // bits
    grid = (w // bw,)
    return pl.pallas_call(
        functools.partial(_bitunpack_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bw), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, bw * s), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, w * s), jnp.int32),
        interpret=interpret,
    )(words.reshape(1, w)).reshape(w * s)
