"""Public wrapper: host-side repack to TPU-friendly width + device unpack."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.columnar.bitpack import pack_bits, packed_nbytes
from repro.kernels.bitunpack.kernel import bitunpack_pallas, tpu_width


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def repack_for_device(codes: np.ndarray, bits: int) -> tuple[np.ndarray, int]:
    """Host: pack codes at the TPU-aligned width. Returns (words, device_bits)."""
    db = tpu_width(bits)
    return pack_bits(np.asarray(codes), db), db


def bitunpack(words: jnp.ndarray, device_bits: int, n: int,
              bw: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Unpack ``n`` codes from device-width packed words.

    ``words`` may be over-provisioned (more words than ``n`` codes need —
    e.g. a whole-IMCU buffer queried for a prefix): the excess is sliced off
    before block padding.
    """
    s = 32 // device_bits
    w_needed = (n + s - 1) // s
    w_pad = _pad_to(max(w_needed, 1), bw)
    words = jnp.asarray(words, jnp.uint32)[:w_needed]
    words_p = jnp.pad(words, (0, w_pad - words.shape[0]))
    out = bitunpack_pallas(words_p, device_bits, bw=bw, interpret=interpret)
    return out[:n]


def device_overhead(bits: int, n: int) -> float:
    """Bytes-overhead factor of the TPU-aligned width vs exact packing."""
    return packed_nbytes(n, tpu_width(bits)) / packed_nbytes(n, bits)
