"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §2).

The paper's hot path is featurization data movement, not FLOPs, so every
kernel here is a bandwidth-shaped kernel around the dictionary:

- ``bitunpack``  — b-bit packed code words -> int32 codes (DAX-scan analogue)
- ``adv_gather`` — codes -> ADV feature rows, dictionary pinned in VMEM;
  includes the fused packed path (``adv_gather_packed``: unpack -> clamp ->
  multi-hot gather in one pass, int32 codes never materialized)
- ``onehot_wide``— fused one-hot(codes) @ W wide-layer (one-hot never
  materialized in HBM; MXU-shaped accumulation over categorical columns)
- ``hist``       — count-metadata build (per-block histograms, paper §6.2)

Each subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd public wrapper), ``ref.py`` (pure-jnp oracle). Tests sweep shapes x
dtypes against the oracle with ``interpret=True`` (this container is CPU).
"""
