"""End-to-end training driver.

Runs any --arch at any scale on the available devices: the full configs are
for the production mesh (use dryrun.py there); on this CPU container use
--preset smoke|small for real optimization steps over the columnar token
pipeline (dictionary-encoded, bit-packed storage — the paper's data path).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b \
      --preset small --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data import TokenStore, synthetic_corpus, token_batches
from repro.models import lm
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def preset_config(cfg, preset: str):
    if preset == "full":
        return cfg
    if preset == "smoke":
        return reduced(cfg)
    if preset == "small":          # ~15M params, trainable on 1 CPU core
        return dataclasses.replace(
            reduced(cfg), d_model=256, d_head=32, d_ff=512 if cfg.d_ff else 0,
            vocab=4099, vocab_pad_multiple=64)
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--preset", default="small",
                    choices=["smoke", "small", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--opt", default="adamw",
                    choices=["adamw", "adamw8", "adafactor"])
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = preset_config(get_config(args.arch), args.preset)
    print(f"arch={cfg.name} family={cfg.family} params~"
          f"{cfg.param_count()/1e6:.1f}M (preset={args.preset})")

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    print(f"materialized params: {lm.param_count(params)/1e6:.1f}M")

    corpus = synthetic_corpus(2_000_000, cfg.vocab, seed=args.seed)
    store = TokenStore(corpus, cfg.vocab)
    print(f"token store: {store.n} tokens, {store.bits}b codes, "
          f"{store.packed_nbytes/1e6:.1f}MB packed "
          f"vs {store.raw_nbytes/1e6:.1f}MB raw "
          f"({store.raw_nbytes/store.packed_nbytes:.1f}x), "
          f"unigram entropy {store.entropy_bits():.2f} bits "
          f"(from count metadata)")

    data = token_batches(store, cfg, batch=args.batch, seq=args.seq,
                         seed=args.seed)
    # MiniCPM gets its signature WSD schedule by default
    schedule = "wsd" if (args.arch == "minicpm-2b"
                         and args.schedule == "cosine") else args.schedule
    trainer = Trainer(
        cfg=cfg,
        opt=OptConfig(name=args.opt, lr=args.lr),
        train=TrainConfig(steps=args.steps, warmup=max(2, args.steps // 20),
                          schedule=schedule, log_every=max(1, args.steps // 20),
                          ckpt_every=max(10, args.steps // 4),
                          ckpt_dir=args.ckpt_dir),
    )
    t0 = time.time()
    params, history = trainer.fit(params, data)
    dt = time.time() - t0
    first, last = history[0], history[-1]
    toks = args.steps * args.batch * args.seq
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({toks/dt:.0f} tok/s)")
    print(f"loss: {first['loss']:.4f} -> {last['loss']:.4f}")
    print(json.dumps(history[-3:], indent=1))
    if trainer.fault_log.events:
        print("fault log:", trainer.fault_log.summary())
    assert last["loss"] < first["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
