"""Serving driver: batched requests against any --arch (reduced presets on
CPU; full configs are exercised via the dry-run).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.train import preset_config
from repro.models import lm
from repro.serve import ServeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--preset", default="small",
                    choices=["smoke", "small", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = preset_config(get_config(args.arch), args.preset)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, batch_size=args.requests,
                         max_len=args.prompt_len + args.max_new,
                         temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, args.prompt_len)
                    .astype(np.int32), max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    done = engine.run_batch(reqs)
    dt = time.time() - t0
    stats = engine.throughput_stats(done, dt)
    print(f"arch={cfg.name} ({lm.param_count(params)/1e6:.1f}M params)")
    print(f"served {stats['requests']} requests, "
          f"{stats['new_tokens']} new tokens in {dt:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt[:8]={r.prompt[:8].tolist()} "
              f"-> out[:8]={r.out_tokens[:8]}")


if __name__ == "__main__":
    main()
