"""Production mesh construction (DESIGN.md §4).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is pure
    data parallelism over the slower inter-pod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_probe_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (8, 4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
