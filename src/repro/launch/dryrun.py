import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
extract memory / FLOP / byte / collective statistics for the roofline
(EXPERIMENTS.md §Dry-run, §Roofline).

The two lines above MUST precede any jax import (device count locks on first
init). Per cell:

1. FULL-depth compile on the target mesh — proves the sharding config is
   coherent (no mismatch, no unsupported collective), yields
   memory_analysis() (fits/doesn't) and the collective schedule.
2. Unrolled depth-1 and depth-2 compiles (single-pod only) — XLA's
   HloCostAnalysis counts while-loop bodies ONCE, so per-layer-group cost is
   recovered exactly by differencing two unrolled shallow modules and
   extrapolating: total(L) = outside + L·per_group. Collective bytes are
   parsed from the partitioned HLO the same way.

Results go to results/dryrun/<arch>__<shape>__<mesh>[__<variant>].json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, ARCH_IDS, SHAPES, input_specs, applicable
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.blocks import block_pattern
from repro.models.config import ModelConfig
from repro.train.optimizer import OptConfig, init_opt_state, apply_updates
from repro.train.trainer import _opt_pspecs

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective result bytes by kind (static count — while-loop
    bodies counted once; dryrun extrapolates via depth differencing)."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def pick_optimizer(cfg: ModelConfig) -> OptConfig:
    """adamw8 for the MoE giants (fits 16GB/chip), adamw elsewhere."""
    if cfg.param_count() > 5e10:
        return OptConfig(name="adamw8")
    return OptConfig(name="adamw")


def build_cell(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (fn, example_args, in_shardings, out_shardings, donate)."""
    shape = SHAPES[shape_name]
    batch = input_specs(cfg, shape)
    b_specs = shd.to_shardings(mesh, shd.batch_pspecs(cfg, batch, mesh))
    p_shape = lm.param_specs(cfg)
    p_specs = shd.to_shardings(mesh, shd.param_pspecs(cfg, p_shape, mesh))

    if shape.kind == "train":
        opt = pick_optimizer(cfg)
        opt_shape = jax.eval_shape(lambda: init_opt_state(opt, p_shape))
        o_specs = shd.to_shardings(mesh, _opt_pspecs(cfg, opt_shape, mesh))

        accum = max(1, cfg.grad_accum)

        def train_step(params, opt_state, batch, step):
            if accum > 1:
                # microbatch gradient accumulation: scan over A splits of the
                # global batch; activation liveness shrinks by A (identical
                # math up to CE renormalization across splits)
                def micro(carry, mb):
                    g_acc, loss_acc = carry
                    (loss, metrics), grads = jax.value_and_grad(
                        lambda p: lm.train_loss(cfg, p, mb),
                        has_aux=True)(params)
                    g_acc = jax.tree.map(jnp.add, g_acc, grads)
                    return (g_acc, loss_acc + loss), None
                mbs = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum,
                                        *x.shape[1:]), batch)
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, p.dtype), params)
                (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = loss / accum
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: lm.train_loss(cfg, p, batch),
                    has_aux=True)(params)
            params, opt_state = apply_updates(opt, grads, opt_state, params,
                                              3e-4)
            return params, opt_state, loss

        args = (p_shape, opt_shape, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (p_specs, o_specs, b_specs, None)
        out_sh = (p_specs, o_specs, None)
        return train_step, args, in_sh, out_sh, (0, 1)

    # serving state (prefill & decode) — int8 dictionary-quantized KV cache
    # is the production serving default (paper §5 applied to the cache; the
    # bf16 variant exists for §Perf before/after). pure_dp is a TRAINING
    # topology (ZeRO-3 weight gathers would dominate decode latency).
    if cfg.kv_cache_dtype == "bfloat16":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if cfg.pure_dp and shape.kind == "decode":
        # ZeRO-3 weight gathers would dominate per-token decode latency;
        # prefill is throughput-shaped and keeps the DP topology
        cfg = dataclasses.replace(cfg, pure_dp=False)
    shape_b = shape.global_batch
    max_len = shape.seq_len if shape.kind == "prefill" else shape.seq_len
    enc_len = shape.seq_len if cfg.family == "audio" else 0
    state_shape = jax.eval_shape(
        lambda: lm.init_serve_state(cfg, shape_b, max_len=max_len,
                                    enc_len=enc_len))
    s_specs = shd.to_shardings(mesh, shd.state_pspecs(cfg, state_shape, mesh))

    if shape.kind == "prefill":
        def prefill_step(params, state, batch):
            logits, new_state = lm.prefill(cfg, params, state, batch)
            # return only last-token logits (serving returns sampled token)
            return logits[:, -1], new_state
        args = (p_shape, state_shape, batch)
        return prefill_step, args, (p_specs, s_specs, b_specs), \
            (None, s_specs), (1,)

    def serve_step(params, state, batch):
        # decode against a full cache: state enters at pos = seq_len - 1
        state = dict(state, pos=jnp.asarray(shape.seq_len - 1, jnp.int32))
        logits, new_state = lm.decode_step(cfg, params, state,
                                           batch["tokens"])
        return logits[:, -1], new_state
    args = (p_shape, state_shape, batch)
    return serve_step, args, (p_specs, s_specs, b_specs), \
        (None, s_specs), (1,)


def compile_cell(cfg: ModelConfig, shape_name: str, mesh,
                 seq_parallel: bool = True):
    from repro.distributed.context import activation_mesh
    fn, args, in_sh, out_sh, donate = build_cell(cfg, shape_name, mesh)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    t0 = time.perf_counter()
    with activation_mesh(mesh if seq_parallel else None):
        lowered = jfn.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return lowered, compiled, {"lower_s": t1 - t0, "compile_s": t2 - t1}


def cell_stats(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    try:
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": int(ma.argument_size_in_bytes),
               "output_bytes": int(ma.output_size_in_bytes),
               "temp_bytes": int(ma.temp_size_in_bytes),
               "alias_bytes": int(ma.alias_size_in_bytes),
               "code_bytes": int(ma.generated_code_size_in_bytes)}
        mem["peak_bytes"] = (mem["argument_bytes"] + mem["output_bytes"] +
                             mem["temp_bytes"] - mem["alias_bytes"])
    except Exception as e:                       # pragma: no cover
        mem = {"error": str(e)}
    colls = parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "memory": mem, "collectives": colls}


def _with_depth(cfg: ModelConfig, groups: int, unroll: bool) -> ModelConfig:
    pat_len = len(block_pattern(cfg))
    enc = min(cfg.enc_layers, groups) if cfg.enc_layers else 0
    # grad_accum=1 in probes: the microbatch loop is a while loop whose body
    # HloCostAnalysis counts once; totals are accum-invariant anyway.
    return dataclasses.replace(cfg, n_layers=groups * pat_len,
                               enc_layers=enc, scan_unroll=unroll,
                               grad_accum=1)


def extrapolated_costs(cfg: ModelConfig, shape_name: str, mesh,
                       seq_parallel: bool = True) -> dict:
    """Per-layer-exact totals via unrolled depth-1/depth-2 differencing."""
    from repro.models.blocks import n_groups as ngroups
    g_full = ngroups(cfg)
    out = {}
    stats = {}
    for g in (1, 2):
        c1 = _with_depth(cfg, g, unroll=True)
        _, compiled, _ = compile_cell(c1, shape_name, mesh,
                                      seq_parallel=seq_parallel)
        stats[g] = cell_stats(compiled)
    for key in ("flops", "bytes_accessed"):
        per_group = stats[2][key] - stats[1][key]
        outside = stats[1][key] - per_group
        out[key] = outside + per_group * g_full
        out[key + "_per_group"] = per_group
        out[key + "_outside"] = outside
    # collectives: extrapolate totals and per-kind
    kinds = set(stats[1]["collectives"]["bytes"]) | \
        set(stats[2]["collectives"]["bytes"])
    coll = {}
    for k in kinds:
        b1 = stats[1]["collectives"]["bytes"].get(k, 0)
        b2 = stats[2]["collectives"]["bytes"].get(k, 0)
        per_group = max(b2 - b1, 0)
        coll[k] = max((b1 - per_group) + per_group * g_full, 0)
    out["collective_bytes"] = coll
    out["collective_total_bytes"] = float(sum(coll.values()))
    # enc-dec: encoder depth also scaled 1->2; fold into same linear model
    out["note"] = ("enc+dec depths differenced together"
                   if cfg.enc_layers else "")
    return out


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> dict:
    t_c = flops / (n_chips * PEAK_FLOPS)
    t_m = hbm_bytes / (n_chips * HBM_BW)
    t_n = coll_bytes / ICI_BW       # per-device bytes already
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms


# ---------------------------------------------------------------------------
# variants (perf-iteration knobs; 'baseline' is the production default)
# ---------------------------------------------------------------------------
def _v_naive(cfg):
    return cfg


def _v_remat_dots(cfg):
    return dataclasses.replace(cfg, remat="dots")


def _v_no_remat(cfg):
    return dataclasses.replace(cfg, remat="none")


def _v_accum2(cfg):
    return dataclasses.replace(cfg, grad_accum=2)


def _v_accum4(cfg):
    return dataclasses.replace(cfg, grad_accum=4)


def _v_fsdp(cfg):
    return dataclasses.replace(cfg, force_fsdp=True)


def _v_dp(cfg):
    return dataclasses.replace(cfg, pure_dp=True)


def _v_dp_dots(cfg):
    return dataclasses.replace(cfg, pure_dp=True, remat="dots")


def _v_accum4_dots(cfg):
    return dataclasses.replace(cfg, grad_accum=4, remat="dots")


def _v_accum8(cfg):
    return dataclasses.replace(cfg, grad_accum=8)


def _v_fsdp_accum2(cfg):
    return dataclasses.replace(cfg, force_fsdp=True, grad_accum=2)


def _v_cap10(cfg):
    return dataclasses.replace(cfg, capacity_factor=1.0)


def _v_kv_bf16(cfg):
    # sentinel dtype: skips build_cell's default bf16->int8 upgrade but is
    # treated as bf16 by init_serve_state (anything != 'int8' is bf16)
    return dataclasses.replace(cfg, kv_cache_dtype="bf16_forced")


VARIANTS = {
    # name: (seq_parallel, cfg_transform)
    "baseline": (True, None),           # production default: Megatron-SP
    "naive": (False, None),             # paper-faithful first cut: plain TP
    "remat_dots": (True, _v_remat_dots),
    "no_remat": (True, _v_no_remat),
    "accum2": (True, _v_accum2),
    "accum4": (True, _v_accum4),
    "kv_bf16": (True, _v_kv_bf16),
    "dp": (True, _v_dp),
    "fsdp": (True, _v_fsdp),
    "dp_dots": (True, _v_dp_dots),
    "accum4_dots": (True, _v_accum4_dots),
    "accum8": (True, _v_accum8),
    "fsdp_accum2": (True, _v_fsdp_accum2),
    "cap10": (True, _v_cap10),
}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             variant: str = "baseline", cfg_override=None) -> dict:
    cfg = cfg_override or get_config(arch)
    seq_parallel, cfg_fn = VARIANTS.get(variant, (True, None))
    if cfg_fn is not None:
        cfg = cfg_fn(cfg)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "variant": variant}
    if not ok:
        result["status"] = reason
        return result
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.perf_counter()
    with jax.default_device(jax.devices("cpu")[0]):
        lowered, compiled, times = compile_cell(cfg, shape_name, mesh,
                                                seq_parallel=seq_parallel)
        stats = cell_stats(compiled)
        result.update(status="ok", n_chips=n_chips, times=times,
                      raw=stats)
        if mesh_kind == "single":
            extra = extrapolated_costs(cfg, shape_name, mesh,
                                       seq_parallel=seq_parallel)
            result["extrapolated"] = extra
            # HLO 'bytes accessed' per-device? cost_analysis reports whole-
            # module bytes on the partitioned module -> per-device values.
            flops_dev = extra["flops"]
            bytes_dev = extra["bytes_accessed"]
            coll_dev = extra["collective_total_bytes"]
            result["roofline"] = roofline_terms(flops_dev, bytes_dev,
                                                coll_dev, 1)
            # model flops (6·N·D for train = fwd+bwd, 2·N·D inference)
            tokens = shape.global_batch * (shape.seq_len
                                           if shape.kind != "decode" else 1)
            mult = 3 if shape.kind == "train" else 1
            result["model_flops"] = 2.0 * cfg.active_param_count() * \
                tokens * mult
    result["wall_s"] = time.perf_counter() - t0
    return result


def save_result(res: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{res['arch']}__{res['shape']}__{res['mesh']}__{res['variant']}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(res, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                name = f"{arch}__{shape}__{mesh_kind}__{args.variant}"
                path = os.path.join(args.out, name + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {name}")
                    continue
                print(f"[cell] {name} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mesh_kind, args.variant)
                except Exception as e:
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "variant": args.variant, "status": "error",
                           "error": f"{type(e).__name__}: {e}"}
                save_result(res, args.out)
                status = res.get("status")
                extra = ""
                if status == "ok" and "roofline" in res:
                    r = res["roofline"]
                    extra = (f" dom={r['dominant']} "
                             f"bound={r['bound_s']*1e3:.2f}ms")
                print(f"       -> {status}{extra} "
                      f"({res.get('wall_s', 0):.0f}s)", flush=True)


if __name__ == "__main__":
    main()
