"""Wide&Deep model + feature-spec + remaining query/dryrun-internals tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.widedeep import (WideDeepConfig, init_widedeep,
                                   forward_widedeep, loss_widedeep,
                                   make_widedeep_train_step)
from repro.core.feature_spec import spec, FeatureSet
from repro.launch import dryrun as dr


def _wd_setup(seed=0, use_kernel=False):
    rng = np.random.default_rng(seed)
    cfg = WideDeepConfig(wide_cards=(5, 3), deep_dim=4,
                         embed_cols=((5, 4),), hidden=(8,),
                         use_kernel=use_kernel)
    params = init_widedeep(cfg, jax.random.PRNGKey(seed))
    n = 64
    wide = jnp.asarray(np.stack([rng.integers(0, 5, n),
                                 rng.integers(0, 3, n)]), jnp.int32)
    deep = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
    emb = [jnp.asarray(rng.integers(0, 5, n), jnp.int32)]
    y = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    return cfg, params, wide, deep, emb, y


def test_widedeep_kernel_path_matches_ref():
    cfg, params, wide, deep, emb, y = _wd_setup()
    out_ref = forward_widedeep(cfg, params, wide, deep, emb)
    cfg_k, *_ = _wd_setup(use_kernel=True)
    out_k = forward_widedeep(cfg_k, params, wide, deep, emb)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_k),
                               rtol=1e-5, atol=1e-5)


def test_widedeep_trains():
    cfg, params, wide, deep, emb, _ = _wd_setup()
    # learnable labels: depend on wide code 0
    y = (np.asarray(wide[0]) % 2).astype(np.float32)
    step = make_widedeep_train_step(cfg, lr=0.5)
    for i in range(120):
        params, loss = step(params, wide, deep, jnp.asarray(y), emb)
    assert float(loss) < 0.2


# -- feature specs --------------------------------------------------------------
def test_feature_spec_hashable_and_named():
    s1 = spec("age", "bucketize", boundaries=(10.0, 20.0))
    s2 = spec("age", "bucketize", boundaries=(10.0, 20.0))
    assert s1 == s2 and hash(s1) == hash(s2)
    assert s1.adv_name == "age.bucketize"
    assert spec("age", "zscore", name="z").adv_name == "z"


def test_feature_set_builds_all_columns():
    from repro.columnar import Table
    rng = np.random.default_rng(0)
    t = Table.from_data({"a": rng.integers(0, 9, 100),
                         "b": rng.integers(0, 5, 100)})
    fs = FeatureSet().add("a", "zscore").add("b", "onehot")
    built = fs.build(t)
    assert set(built) == {"a", "b"}
    assert "a.zscore" in built["a"].advs


# -- dryrun internals ---------------------------------------------------------------
def test_parse_collectives():
    hlo = """
      %all-gather = f32[32,128]{0,1} all-gather(%copy), channel_id=1
      %ar.1 = bf16[64]{0} all-reduce(%x), replica_groups={}
      %rs = (f32[16,8]{1,0}, f32[16,8]{1,0}) reduce-scatter(%a, %b)
      %nothing = f32[4]{0} add(%p, %q)
      %a2a.5 = s8[1024]{0} all-to-all(%y)
      %cp = f32[2,2]{1,0} collective-permute-start(%z)
    """
    got = dr.parse_collectives(hlo)
    assert got["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "reduce-scatter": 1, "all-to-all": 1,
                             "collective-permute": 1}
    assert got["bytes"]["all-gather"] == 32 * 128 * 4
    assert got["bytes"]["all-reduce"] == 64 * 2
    assert got["bytes"]["reduce-scatter"] == 2 * 16 * 8 * 4
    assert got["bytes"]["all-to-all"] == 1024
    assert got["total_bytes"] == sum(got["bytes"].values())


def test_roofline_terms_dominance():
    t = dr.roofline_terms(flops=197e12, hbm_bytes=819e9 * 2,
                          coll_bytes=50e9 * 0.5, n_chips=1)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(0.5)
    assert t["dominant"] == "memory_s"


def test_variant_registry():
    assert "baseline" in dr.VARIANTS and "naive" in dr.VARIANTS
    cfg = dr.get_config("glm4-9b")
    sp, fn = dr.VARIANTS["remat_dots"]
    assert fn(cfg).remat == "dots"


def test_shape_applicability():
    from repro.configs import get_config, SHAPES, applicable
    ok, _ = applicable(get_config("xlstm-1.3b"), SHAPES["long_500k"])
    assert ok
    ok, why = applicable(get_config("qwen2-7b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        ok, _ = applicable(get_config("seamless-m4t-large-v2"), SHAPES[s])
        assert ok
