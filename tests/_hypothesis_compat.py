"""Deterministic stand-in for the parts of ``hypothesis`` this suite uses.

The container images the CI and offline devboxes run on have no network, so
``hypothesis`` may be absent. Instead of skipping 6 of 12 test modules, the
suite falls back to this shim (installed into ``sys.modules`` by
``tests/conftest.py``): ``@given`` draws ``max_examples`` pseudo-random
examples from the declared strategies with a seed derived from the test name,
so runs are reproducible and property tests still exercise a spread of inputs
— just without shrinking or the example database.

Only the strategies the suite uses are implemented: ``integers``, ``lists``,
``sampled_from``, ``booleans``, ``floats``.
"""
from __future__ import annotations

import types
import zlib

import numpy as np

__version__ = "0.0-compat"


class SearchStrategy:
    """A strategy is just a draw function: rng -> value."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, fn) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred, max_tries: int = 100) -> "SearchStrategy":
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_ignored) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def sampled_from(seq) -> SearchStrategy:
    pool = list(seq)
    return SearchStrategy(lambda rng: pool[int(rng.integers(0, len(pool)))])


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int | None = None) -> SearchStrategy:
    hi = (min_size + 10) if max_size is None else max_size

    def draw(rng):
        n = int(rng.integers(min_size, hi + 1))
        return [elements.draw(rng) for _ in range(n)]
    return SearchStrategy(draw)


def settings(max_examples: int | None = None, deadline=None, **_ignored):
    """Attach example-count settings; works above or below @given."""
    def deco(fn):
        fn._compat_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    def deco(fn):
        def runner():
            cfg = (getattr(runner, "_compat_settings", None)
                   or getattr(fn, "_compat_settings", None) or {})
            n = cfg.get("max_examples") or 20
            seed = zlib.crc32(f"{fn.__module__}.{fn.__name__}".encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                args = [s.draw(rng) for s in arg_strategies]
                kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: args={args!r} "
                        f"kwargs={kwargs!r}") from e
        runner.__name__ = fn.__name__
        runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.is_hypothesis_test = True
        return runner
    return deco


# expose a module-like ``strategies`` so both import styles work:
#   from hypothesis import strategies as st
#   import hypothesis.strategies as st
strategies = types.ModuleType("hypothesis.strategies")
strategies.SearchStrategy = SearchStrategy
strategies.integers = integers
strategies.booleans = booleans
strategies.floats = floats
strategies.sampled_from = sampled_from
strategies.lists = lists
