"""Suite-wide setup: CPU-only JAX by default, hypothesis fallback shim.

If the real ``hypothesis`` is installed it is used untouched; otherwise the
deterministic shim in ``tests/_hypothesis_compat.py`` is registered under the
``hypothesis`` module names so the 6 property-test modules still collect and
run in offline environments.
"""
import os
import pathlib
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis_compat

    sys.modules["hypothesis"] = _hypothesis_compat
    sys.modules["hypothesis.strategies"] = _hypothesis_compat.strategies
