"""Flash attention custom-VJP: forward AND gradients vs naive reference."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.flash import flash_attention


def _naive(qg, k, v, q_pos, kbias, window):
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    t = k.shape[1]
    k_pos = jnp.arange(t, dtype=jnp.float32)
    keep = q_pos[:, None] >= k_pos[None, :]
    w = jnp.where(window > 0, window, jnp.float32(1e18))
    keep &= (q_pos[:, None] - k_pos[None, :]) < w
    mask = jnp.where(keep, 0.0, -1e30) + kbias[None, :]
    probs = jax.nn.softmax(scores + mask, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out


def _setup(seed, b=2, s=32, t=32, kv=2, g=2, dh=8):
    rng = np.random.default_rng(seed)
    qg = jnp.asarray(rng.standard_normal((b, s, kv, g, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, kv, dh)), jnp.float32)
    q_pos = jnp.arange(s, dtype=jnp.float32) + (t - s)
    kbias = jnp.zeros((t,), jnp.float32)
    return qg, k, v, q_pos, kbias


@pytest.mark.parametrize("window", [0.0, 9.0])
@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_flash_forward_matches_naive(window, chunk):
    qg, k, v, q_pos, kbias = _setup(0)
    w = jnp.float32(window)
    got = flash_attention(qg, k, v, q_pos, kbias, w, chunk)
    want = _naive(qg, k, v, q_pos, kbias, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [0.0, 9.0])
def test_flash_gradients_match_naive(window):
    qg, k, v, q_pos, kbias = _setup(1)
    w = jnp.float32(window)

    def loss_flash(qg, k, v):
        out = flash_attention(qg, k, v, q_pos, kbias, w, 8)
        return jnp.sum(jnp.sin(out))

    def loss_naive(qg, k, v):
        out = _naive(qg, k, v, q_pos, kbias, w)
        return jnp.sum(jnp.sin(out))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(qg, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(qg, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


def test_flash_decode_kbias():
    """kbias masks invalid cache tail exactly like a shorter cache."""
    qg, k, v, _, _ = _setup(2, s=1, t=32)
    q_pos = jnp.asarray([10.0])
    kbias = jnp.where(jnp.arange(32) < 11, 0.0, -1e30).astype(jnp.float32)
    out = flash_attention(qg, k, v, q_pos, kbias, jnp.float32(0), 8)
    k2 = k.at[:, 11:].set(777.0)
    v2 = v.at[:, 11:].set(777.0)
    out2 = flash_attention(qg, k2, v2, q_pos, kbias, jnp.float32(0), 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-6)


def test_flash_grad_memory_no_full_matrix():
    """Residuals stay O(S): jaxpr of the VJP must not contain an (S,T)-sized
    f32 tensor stacked across chunks (the naive-scan failure mode)."""
    qg, k, v, q_pos, kbias = _setup(3, b=1, s=64, t=64, kv=1, g=1, dh=4)

    def loss(qg, k, v):
        return jnp.sum(flash_attention(qg, k, v, q_pos, kbias,
                                       jnp.float32(0), 16))
    jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(qg, k, v)
    # the largest residual tensor must be O(S*dh), not O(n_chunks*S*T)
    biggest = 0
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            if hasattr(var, "aval") and var.aval.shape:
                n = int(np.prod(var.aval.shape))
                biggest = max(biggest, n)
    assert biggest <= 64 * 64 * 4, biggest   # one chunk's work, not 4x stacked
