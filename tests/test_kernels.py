"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.columnar.bitpack import pack_bits
from repro.kernels.adv_gather import adv_gather
from repro.kernels.adv_gather.ref import adv_gather_ref
from repro.kernels.bitunpack import bitunpack, repack_for_device, tpu_width
from repro.kernels.bitunpack.ops import device_overhead
from repro.kernels.onehot_wide import onehot_wide
from repro.kernels.onehot_wide.ref import (onehot_wide_ref,
                                           onehot_wide_materialized)
from repro.kernels.hist import hist
from repro.kernels.hist.ref import hist_ref


# -- adv_gather ---------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 7, 256, 1000])
@pytest.mark.parametrize("k,f", [(4, 1), (50, 3), (513, 17), (2048, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adv_gather_sweep(n, k, f, dtype):
    rng = np.random.default_rng(n * 1000 + k + f)
    table = jnp.asarray(rng.standard_normal((k, f)), dtype=dtype)
    codes = jnp.asarray(rng.integers(0, k, size=n), jnp.int32)
    got = adv_gather(table, codes)
    want = adv_gather_ref(codes, table)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-6)


def test_adv_gather_2d_codes_and_large_k_fallback():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((1 << 17, 4)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 1 << 17, size=(8, 16)), jnp.int32)
    got = adv_gather(table, codes)     # falls back to XLA gather path
    assert got.shape == (8, 16, 4)
    want = adv_gather_ref(codes.reshape(-1), table).reshape(8, 16, 4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(0, 2**31), st.integers(1, 300), st.integers(2, 700))
@settings(max_examples=15, deadline=None)
def test_adv_gather_property(seed, n, k):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((k, 5)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, k, size=n), jnp.int32)
    np.testing.assert_allclose(np.asarray(adv_gather(table, codes)),
                               np.asarray(adv_gather_ref(codes, table)),
                               rtol=1e-6)


# -- bitunpack -------------------------------------------------------------------
@pytest.mark.parametrize("bits", [1, 2, 4, 8, 16, 32])
@pytest.mark.parametrize("n", [1, 31, 512, 4097])
def test_bitunpack_sweep(bits, n):
    rng = np.random.default_rng(bits * 100 + n)
    hi = min(1 << bits, 1 << 31)
    codes = rng.integers(0, hi, size=n)
    words = pack_bits(codes, bits)
    out = bitunpack(jnp.asarray(words), bits, n)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_bitunpack_overprovisioned_words():
    """Regression: a words buffer LONGER than n codes need (e.g. a whole
    IMCU queried for a prefix) used to crash jnp.pad with a negative pad
    width; the excess must be sliced off before block padding."""
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 256, size=4096)
    words = pack_bits(codes, 8)                    # 1024 words
    n = 100                                        # needs only 25 words
    out = bitunpack(jnp.asarray(words), 8, n)
    np.testing.assert_array_equal(np.asarray(out), codes[:n])
    # boundary case: buffer exactly one block over the padded width
    out = bitunpack(jnp.asarray(np.concatenate([words,
                                                np.zeros(512, np.uint32)])),
                    8, 4096)
    np.testing.assert_array_equal(np.asarray(out), codes)


@pytest.mark.parametrize("bits,expected", [(1, 1), (3, 4), (6, 8), (9, 16),
                                           (17, 32), (32, 32)])
def test_tpu_width(bits, expected):
    assert tpu_width(bits) == expected


def test_repack_for_device_roundtrip():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 50, size=1000)     # states: 6 bits -> 8 on device
    words, db = repack_for_device(codes, 6)
    assert db == 8
    out = bitunpack(jnp.asarray(words), db, 1000)
    np.testing.assert_array_equal(np.asarray(out), codes)
    assert device_overhead(6, 1000) < 1.5      # bounded loss vs exact packing


@given(st.integers(0, 2**31), st.sampled_from([1, 2, 4, 8, 16]),
       st.integers(1, 2000))
@settings(max_examples=20, deadline=None)
def test_bitunpack_property(seed, bits, n):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=n)
    words = pack_bits(codes, bits)
    np.testing.assert_array_equal(
        np.asarray(bitunpack(jnp.asarray(words), bits, n)), codes)


# -- onehot_wide -------------------------------------------------------------------
@pytest.mark.parametrize("c,n,k,f", [(1, 16, 4, 8), (3, 100, 50, 16),
                                     (2, 256, 600, 128), (5, 33, 7, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_onehot_wide_sweep(c, n, k, f, dtype):
    rng = np.random.default_rng(c * n + k)
    w = jnp.asarray(rng.standard_normal((c, k, f)), dtype=dtype)
    codes = jnp.asarray(rng.integers(0, k, size=(c, n)), jnp.int32)
    got = np.asarray(onehot_wide(codes, w), np.float32)
    want = np.asarray(onehot_wide_ref(codes, w), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_onehot_wide_equals_materialized():
    """The fusion invariant: fused == one-hot @ W materialized."""
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((3, 20, 6)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 20, size=(3, 40)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(onehot_wide(codes, w)),
        np.asarray(onehot_wide_materialized(codes, w)), rtol=1e-5, atol=1e-5)


# -- hist ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,k", [(1, 2), (100, 7), (4096, 512), (10000, 1000)])
def test_hist_sweep(n, k):
    rng = np.random.default_rng(n + k)
    codes = jnp.asarray(rng.integers(0, k, size=n), jnp.int32)
    np.testing.assert_array_equal(np.asarray(hist(codes, k)),
                                  np.asarray(hist_ref(codes, k)))


@given(st.integers(0, 2**31), st.integers(1, 500), st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_hist_property_total(seed, n, k):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, k, size=n), jnp.int32)
    counts = np.asarray(hist(codes, k))
    assert counts.sum() == n                       # conservation of rows
    np.testing.assert_array_equal(counts, np.asarray(hist_ref(codes, k)))


# -- cross-kernel: the paper's full device featurization path -------------------------
def test_packed_codes_to_features_end_to_end():
    """bitunpack -> adv_gather == featurize-from-raw (the ADV fast path)."""
    rng = np.random.default_rng(3)
    k = 50
    n = 777
    codes = rng.integers(0, k, size=n)
    table = rng.standard_normal((k, 9)).astype(np.float32)
    words, db = repack_for_device(codes, 6)
    dev_codes = bitunpack(jnp.asarray(words), db, n)
    feats = adv_gather(jnp.asarray(table), dev_codes)
    np.testing.assert_allclose(np.asarray(feats), table[codes], rtol=1e-6)
