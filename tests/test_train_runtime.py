"""Tests: optimizers, schedules, checkpointing, fault tolerance, trainer."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.train.optimizer import (OptConfig, init_opt_state, apply_updates,
                                   quantize_blockwise, dequantize_blockwise,
                                   clip_by_global_norm, global_norm)
from repro.train.schedule import warmup_cosine, wsd
from repro.train import checkpoint as ck
from repro.train.fault import StragglerDetector, plan_elastic_mesh
from repro.distributed.compression import (quantize, dequantize,
                                           compress_decompress,
                                           compression_ratio)


def _toy_params(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((16,)), jnp.float32)}


def _quadratic_grads(params, target):
    return jax.grad(lambda p: sum(jnp.sum((x - t) ** 2) for x, t in
                                  zip(jax.tree_util.tree_leaves(p),
                                      jax.tree_util.tree_leaves(target))))(
        params)


@pytest.mark.parametrize("name", ["adamw", "adamw8", "adafactor"])
def test_optimizer_descends(name):
    params = _toy_params()
    target = jax.tree.map(jnp.zeros_like, params)
    opt = OptConfig(name=name, lr=0.05, weight_decay=0.0)
    state = init_opt_state(opt, params)
    loss0 = float(sum(jnp.sum(x ** 2)
                      for x in jax.tree_util.tree_leaves(params)))
    for _ in range(60):
        grads = _quadratic_grads(params, target)
        params, state = apply_updates(opt, grads, state, params, 0.05)
    loss1 = float(sum(jnp.sum(x ** 2)
                      for x in jax.tree_util.tree_leaves(params)))
    assert loss1 < 0.2 * loss0, (name, loss0, loss1)


def test_adamw8_tracks_adamw():
    """Quantized states follow full-precision trajectory closely."""
    p1 = _toy_params(1)
    p2 = jax.tree.map(lambda x: x, p1)
    target = jax.tree.map(jnp.zeros_like, p1)
    o1, o2 = OptConfig("adamw", weight_decay=0), OptConfig("adamw8",
                                                           weight_decay=0)
    s1, s2 = init_opt_state(o1, p1), init_opt_state(o2, p2)
    for _ in range(20):
        g1 = _quadratic_grads(p1, target)
        g2 = _quadratic_grads(p2, target)
        p1, s1 = apply_updates(o1, g1, s1, p1, 0.01)
        p2, s2 = apply_updates(o2, g2, s2, p2, 0.01)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.15, atol=0.10)
    # and the trajectories reach comparable loss
    l1 = sum(float(jnp.sum(x ** 2)) for x in jax.tree_util.tree_leaves(p1))
    l2 = sum(float(jnp.sum(x ** 2)) for x in jax.tree_util.tree_leaves(p2))
    assert abs(l1 - l2) / max(l1, 1e-9) < 0.15


@given(st.integers(0, 10_000), st.integers(64, 600))
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_error_bound(seed, rows):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, 512)) * 10, jnp.float32)
    d = quantize_blockwise(x)
    if not isinstance(d, dict):          # below QUANT_MIN_SIZE stays f32
        np.testing.assert_array_equal(np.asarray(d), np.asarray(x))
        return
    y = dequantize_blockwise(d)
    # error bounded by half a code step per row
    err = np.abs(np.asarray(x - y))
    bound = np.asarray(d["scale"])[:, None] * 0.5 * (1 + 1e-4) + 1e-6
    assert (err <= bound).all()
    # code tensor keeps the param shape (sharding-preserving invariant)
    assert d["q"].shape == x.shape


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# -- schedules ---------------------------------------------------------------
def test_wsd_shape():
    lr = [float(wsd(s, peak_lr=1.0, warmup=10, total=100, decay_frac=0.2))
          for s in range(100)]
    assert lr[0] == 0.0
    assert lr[9] == pytest.approx(0.9)
    assert lr[40] == pytest.approx(1.0)          # stable phase
    assert lr[79] == pytest.approx(1.0)
    assert lr[99] < 0.05                          # decayed
    d = np.diff(lr[80:])
    assert (d <= 1e-6).all()                      # monotone decay


def test_cosine_shape():
    lr = [float(warmup_cosine(s, peak_lr=1.0, warmup=10, total=100))
          for s in range(100)]
    assert lr[9] == pytest.approx(0.9)
    assert max(lr) <= 1.0 + 1e-6
    assert lr[-1] < 0.2


# -- checkpoint -----------------------------------------------------------------
def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"params": _toy_params(), "step": jnp.asarray(7)}
    ck.save(str(tmp_path), 10, tree, extra={"note": "x"})
    ck.save(str(tmp_path), 20, tree)
    assert ck.latest_steps(str(tmp_path)) == [10, 20]
    step, restored, extra = ck.restore_latest(str(tmp_path), tree)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_checkpoint_async_and_gc(tmp_path):
    saver = ck.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = _toy_params()
    for step in (1, 2, 3, 4):
        saver.save_async(step, tree)
    saver.wait()
    assert ck.latest_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_structure_mismatch(tmp_path):
    ck.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError, match="mismatch"):
        ck.restore(str(tmp_path), 1, {"b": jnp.zeros(3)})


# -- fault tolerance ---------------------------------------------------------------
def test_straggler_detector_flags_outliers():
    det = StragglerDetector(warmup=3)
    flagged = [det.observe(i, 1.0 + 0.01 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert det.observe(20, 5.0) is True
    assert det.straggler_fraction > 0
    # EWMA not polluted by the outlier
    assert det.mean < 1.1


def test_plan_elastic_mesh():
    p = plan_elastic_mesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    p = plan_elastic_mesh(240, model_parallel=16)   # lost a host of 16
    assert p.shape == (15, 16) and p.n_devices == 240
    p = plan_elastic_mesh(512, model_parallel=16, multi_pod=True)
    assert p.shape == (2, 16, 16)
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, model_parallel=16)


# -- gradient compression -----------------------------------------------------------
@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_compression_roundtrip_property(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(1000) * rng.uniform(0.1, 10),
                    jnp.float32)
    y = compress_decompress(x)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(x - y))) <= scale * 0.51 + 1e-6


def test_compression_ratio():
    tree = {"w": jnp.zeros((1024, 1024))}
    r = compression_ratio(tree)
    assert 3.5 < r < 4.01
