"""Per-architecture smoke tests on REDUCED configs (CPU, 1 device).

For every assigned arch: one forward + one train (loss+grad) step asserting
output shapes and finiteness, and a prefill/decode teacher-forcing
equivalence check (the serve path must reproduce the training forward).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced, ARCH_IDS
from repro.models import lm
from repro.models.blocks import block_pattern

S = 8          # smoke sequence length
B = 2


def _batch(cfg, rng, s=S, b=B):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.frontend_dim)),
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch))
            params = lm.init_params(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    logits, (aux, z), _ = lm.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())
    # padded vocab rows masked out
    if cfg.padded_vocab > cfg.vocab:
        assert float(logits[..., cfg.vocab:].max()) < -1e29


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch, arch_state):
    cfg, params = arch_state(arch)
    rng = np.random.default_rng(2)
    batch = _batch(cfg, rng)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.train_loss(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # embedding (the learned ADV) must receive gradient
    assert float(jnp.abs(grads["embed"]).max()) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, arch_state):
    """Teacher forcing: prefill(t0..t6) + decode(t7) == forward(t0..t7)[-1]."""
    cfg, params = arch_state(arch)
    rng = np.random.default_rng(3)
    batch = _batch(cfg, rng)
    full_logits, _, _ = lm.forward(cfg, params, batch)

    state = lm.init_serve_state(cfg, B, max_len=S,
                                enc_len=S if cfg.family == "audio" else 0)
    pre_batch = {k: (v[:, :S - 1] if k in ("tokens",) else v)
                 for k, v in batch.items()}
    pre_logits, state = lm.prefill(cfg, params, state, pre_batch)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(full_logits[:, :S - 1]),
                               rtol=2e-3, atol=2e-3)
    step_logits, state = lm.decode_step(cfg, params, state,
                                        batch["tokens"][:, S - 1:S])
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
    assert int(state["pos"]) == S


@pytest.mark.parametrize("arch", ["glm4-9b", "xlstm-1.3b", "hymba-1.5b"])
def test_multi_step_decode(arch, arch_state):
    cfg, params = arch_state(arch)
    rng = np.random.default_rng(4)
    state = lm.init_serve_state(cfg, B, max_len=S)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    for i in range(4):
        logits, state = lm.decode_step(cfg, params, state, tok)
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())
        tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)


def test_param_counts_full_configs():
    """Full configs hit the published parameter scale (±20%)."""
    expect = {"glm4-9b": 9.4e9, "qwen2-7b": 7.6e9, "minicpm-2b": 2.7e9,
              "starcoder2-15b": 15e9, "xlstm-1.3b": 1.55e9,
              "hymba-1.5b": 1.5e9, "llava-next-mistral-7b": 7.2e9}
    for arch, n in expect.items():
        cfg = get_config(arch)
        got = cfg.param_count()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)
    # MoE: total vs active split
    l4 = get_config("llama4-maverick-400b-a17b")
    assert 3.2e11 < l4.param_count() < 4.8e11
    assert 1.2e10 < l4.active_param_count() < 2.2e10
    ms = get_config("moonshot-v1-16b-a3b")
    assert ms.active_param_count() < 0.25 * ms.param_count()
