"""Multi-device tests (8 fake CPU devices via subprocess — the main pytest
process must keep seeing 1 device for the smoke tests).

Covers: pjit'd train step on a (4,2) data×model mesh with real loss descent,
sharding-spec consistency, pipeline parallelism vs sequential reference,
compressed cross-pod psum with error feedback, and elastic re-mesh restore.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 540) -> str:
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {os.path.join(REPO, 'src')!r})
    """)
    r = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_pjit_train_step_descends_on_mesh():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, reduced
        from repro.models import lm
        from repro.distributed import sharding as shd
        from repro.distributed.context import activation_mesh
        from repro.train.optimizer import OptConfig
        from repro.train.trainer import make_train_step, TrainConfig

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = dataclasses.replace(reduced(get_config("qwen2-7b")),
                                  d_model=64, vocab=256,
                                  vocab_pad_multiple=64)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        p_specs = shd.param_pspecs(cfg, params, mesh)
        p_shard = shd.to_shardings(mesh, p_specs)
        params = jax.device_put(params, p_shard)

        step_fn, _ = make_train_step(cfg, OptConfig(lr=5e-2), TrainConfig(
            steps=60, warmup=2, donate=False), mesh=mesh)
        from repro.train.optimizer import init_opt_state
        from repro.data import TokenStore, synthetic_corpus, token_batches
        opt_state = init_opt_state(OptConfig(lr=5e-2), params)
        store = TokenStore(synthetic_corpus(100_000, cfg.vocab), cfg.vocab)
        data = token_batches(store, cfg, batch=8, seq=16)
        losses = []
        with activation_mesh(mesh):
            for i in range(50):
                params, opt_state, m = step_fn(params, opt_state, next(data),
                                               jnp.asarray(i))
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses
        # params stayed sharded
        leaf = params["blocks"][0]["mlp"]["wu"]
        assert not leaf.sharding.is_fully_replicated
        print("DESCENT", losses[0], "->", losses[-1])
    """)
    assert "DESCENT" in out


def test_param_specs_divisible_everywhere():
    """Every spec'd axis must divide the dim for all 10 archs on the
    production mesh (the invariant behind 'compiles on 16x16')."""
    out = run_sub("""
        import jax
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config, ARCH_IDS
        from repro.distributed import sharding as shd
        from repro.models import lm

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sizes = dict(mesh.shape)
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            tree = lm.param_specs(cfg)
            specs = shd.param_pspecs(cfg, tree, mesh)
            flat_specs = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            flat_shapes = jax.tree_util.tree_leaves(tree)
            assert len(flat_specs) == len(flat_shapes)
            for spec, leaf in zip(flat_specs, flat_shapes):
                for i, entry in enumerate(spec):
                    axes = entry if isinstance(entry, tuple) else \
                        (entry,) if entry else ()
                    n = int(np.prod([sizes[a] for a in axes])) if axes else 1
                    assert leaf.shape[i] % n == 0, (arch, spec, leaf.shape)
        print("DIVISIBLE-OK")
    """, devices=8)
    assert "DIVISIBLE-OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline_par import (pipelined_forward,
                                                    bubble_fraction)
        mesh = jax.make_mesh((4,), ("stage",))
        rng = np.random.default_rng(0)
        S, M, MB, D = 4, 6, 8, 16
        w = jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32)

        def stage_fn(p, x):
            return jnp.tanh(x @ p)

        x = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)
        piped = pipelined_forward(stage_fn, mesh)
        got = piped(w, x)
        want = x
        for i in range(S):
            want = jnp.tanh(want @ w[i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
        print("PIPELINE-OK")
    """, devices=4)
    assert "PIPELINE-OK" in out


def test_compressed_psum_error_feedback():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import (psum_compressed,
                                                   compression_ratio)
        from repro.distributed.compat import shard_map
        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        g_all = jnp.asarray(rng.standard_normal((4, 64, 32)), jnp.float32)

        def worker(g_local, err):
            red, new_err = psum_compressed({"w": g_local[0]}, "pod",
                                           {"w": err[0]})
            return red["w"], new_err["w"][None]

        sharded = shard_map(worker, mesh=mesh,
                            in_specs=(P("pod"), P("pod")),
                            out_specs=(P(), P("pod")))
        err = jnp.zeros((4, 64, 32), jnp.float32)
        exact = np.asarray(g_all.sum(0))
        red, err = sharded(g_all, err)
        got = np.asarray(red)
        rel = np.abs(got - exact).max() / np.abs(exact).max()
        assert rel < 0.05, rel
        # error feedback: residuals are nonzero and bounded by a code step
        assert float(jnp.abs(err).max()) > 0
        assert compression_ratio({"w": g_all}) > 3.5
        print("COMPRESS-OK", rel)
    """, devices=4)
    assert "COMPRESS-OK" in out


def test_elastic_remesh_checkpoint_restore():
    """Save on a (4,2) mesh, restore onto (2,2) with 4 'surviving' devices."""
    out = run_sub("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.train import checkpoint as ck
        from repro.train.fault import plan_elastic_mesh

        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        tree = {"w": jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(mesh1, P("data", "model")))}
        d = tempfile.mkdtemp()
        ck.save(d, 5, tree)

        plan = plan_elastic_mesh(4, model_parallel=2)
        assert plan.shape == (2, 2)
        mesh2 = jax.make_mesh(plan.shape, plan.axes,
                              devices=np.array(jax.devices()[:4]))
        sh2 = {"w": NamedSharding(mesh2, P("data", "model"))}
        step, restored, _ = ck.restore_latest(d, tree, shardings=sh2)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64).reshape(8, 8))
        assert restored["w"].sharding.mesh.shape == {"data": 2, "model": 2}
        print("ELASTIC-OK")
    """, devices=8)
    assert "ELASTIC-OK" in out
