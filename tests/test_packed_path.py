"""Packed-code fast path: fused unpack+gather, device-resident word serving.

The invariant under test everywhere: ``packed=True`` output is BIT-exact
(assert_array_equal, not allclose) against the int32 take+concat reference —
the packed path changes the representation that moves, never the math.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.columnar import Table
from repro.columnar.bitpack import pack_bits, packed_gather, packed_nbytes
from repro.core import FeatureSet, FeaturePipeline, FeaturePlan, FeatureExecutor
from repro.kernels.adv_gather import (adv_gather_packed,
                                      adv_gather_packed_split,
                                      adv_gather_packed_rows,
                                      adv_gather_packed_rows_split,
                                      autotune_packed, packed_kernel_fits,
                                      fuse_tables)
from repro.kernels.adv_gather.ref import (adv_gather_multi_ref,
                                          adv_gather_packed_ref,
                                          adv_gather_packed_rows_ref)
from repro.kernels.bitunpack.kernel import tpu_width
from repro.serve import FeatureService

# satellite requirement: every storage width class, incl. non-divisors
# (3 -> 4, 6 -> 8, 12 -> 16) that force a device-width repack
BITS_SWEEP = (1, 2, 3, 4, 6, 8, 12, 16)


def _column_data(rng, bits, n):
    """Integer column whose dictionary needs exactly ``bits`` bits."""
    # minimal cardinality with bits_needed(k) == bits; n must be >= k
    k = 2 if bits == 1 else (1 << (bits - 1)) + 1
    base = np.arange(k)
    return np.concatenate([base, rng.integers(0, k, n - k)])


def _packed_vs_int32(table, fs, use_kernel):
    plan_i = FeaturePlan(table, fs)
    plan_p = FeaturePlan(table, fs, packed=True)
    ex_i = FeatureExecutor(plan_i)
    ex_p = FeatureExecutor(plan_p, use_kernel=use_kernel)
    return plan_i, plan_p, ex_i, ex_p


# -- kernel parity -----------------------------------------------------------------
@pytest.mark.parametrize("bits_set,n", [
    ((1, 3), 64), ((2, 6, 8), 300), ((12,), 257), ((4, 16), 40),
])
def test_packed_kernel_matches_multi_ref(bits_set, n):
    rng = np.random.default_rng(sum(bits_set) + n)
    cards = [1 << b for b in bits_set]
    dbs = [tpu_width(b) for b in bits_set]
    dims = [int(rng.integers(1, 9)) for _ in cards]
    tables = [rng.standard_normal((k, f)).astype(np.float32)
              for k, f in zip(cards, dims)]
    codes = [rng.integers(0, k, n).astype(np.int32) for k in cards]
    windows = [jnp.asarray(pack_bits(c, db)) for c, db in zip(codes, dbs)]
    fused = fuse_tables(tables)
    got = np.asarray(adv_gather_packed(
        windows, dbs, fused.table, fused.row_offsets, fused.card_limits,
        n, fused.out_dim))
    want = np.asarray(adv_gather_multi_ref(
        jnp.asarray(np.stack(codes)), [jnp.asarray(t) for t in tables]))
    np.testing.assert_array_equal(got, want)       # one-hot matmul is exact
    # split fallback and pure-jnp oracle agree too
    jt = [jnp.asarray(t) for t in tables]
    np.testing.assert_array_equal(
        np.asarray(adv_gather_packed_split(windows, dbs, jt, n)), want)
    np.testing.assert_array_equal(
        np.asarray(adv_gather_packed_ref(windows, dbs, jt, n)), want)


def test_packed_kernel_overprovisioned_windows():
    """Whole-stream windows (more words than the batch needs) are sliced,
    mirroring the bitunpack over-provisioning fix."""
    rng = np.random.default_rng(0)
    table = rng.standard_normal((256, 3)).astype(np.float32)
    codes = rng.integers(0, 256, 1000).astype(np.int32)
    words = jnp.asarray(pack_bits(codes, 8))       # covers all 1000 rows
    fused = fuse_tables([table])
    got = np.asarray(adv_gather_packed(
        [words], [8], fused.table, fused.row_offsets, fused.card_limits,
        64, fused.out_dim))
    np.testing.assert_array_equal(got, table[codes[:64]])


def test_packed_vmem_guard_and_autotune():
    assert packed_kernel_fits((100, 50), (4, 4))
    assert not packed_kernel_fits((1 << 17,), (4,))          # K guard
    assert not packed_kernel_fits((1 << 15, 1 << 15), (64, 64))  # ~16MB guard
    rng = np.random.default_rng(1)
    tables = [rng.standard_normal((64, 2)).astype(np.float32)]
    codes = rng.integers(0, 64, 128).astype(np.int32)
    windows = [jnp.asarray(pack_bits(codes, 8))]
    fused = fuse_tables(tables)
    bn, bk, bw = autotune_packed(windows, (8,), fused, 128, repeats=1)
    assert bn % 32 == 0 and fused.table.shape[0] % bk == 0
    # cached: second call returns the same winner without re-sweeping
    assert autotune_packed(windows, (8,), fused, 128) == (bn, bk, bw)


# -- random-row indexed gather (indices in, features out) ---------------------------
def _rows_fixture(rng, bits_set, n):
    """Full resident streams + fused tables + reference codes for bits_set."""
    cards = [2 if b == 1 else (1 << (b - 1)) + 1 for b in bits_set]
    dbs = [tpu_width(b) for b in bits_set]
    dims = [int(rng.integers(1, 9)) for _ in cards]
    tables = [rng.standard_normal((k, f)).astype(np.float32)
              for k, f in zip(cards, dims)]
    codes = [rng.integers(0, k, n).astype(np.int32) for k in cards]
    streams = [jnp.asarray(pack_bits(c, db)) for c, db in zip(codes, dbs)]
    offs, off = [], 0
    for s in streams:
        offs.append(off)
        off += int(s.shape[0])
    flat = jnp.concatenate(streams)
    return cards, dbs, tables, codes, streams, tuple(offs), flat


def _straddling_rows(rng, dbs, n, m=120):
    """Arbitrary rows biased to sit on BOTH sides of every column's word
    boundary (row % (32/db) in {s-1, 0, 1}), plus uniform filler."""
    picks = []
    for db in dbs:
        s = 32 // db
        base = np.arange(s, n - s, max(n // 8, s))
        picks += [base // s * s - 1, base // s * s, base // s * s + 1]
    rows = np.concatenate(picks + [rng.integers(0, n, m)])
    return np.clip(rows, 0, n - 1)


@pytest.mark.parametrize("bits_set,n", [
    ((1, 3), 96), ((2, 6, 8), 300), ((12,), 257), ((4, 16), 64),
    (BITS_SWEEP, 200),
])
def test_packed_rows_kernel_matches_reference(bits_set, n):
    """Fused random-row kernel == take reference, bit-exact, for arbitrary
    rows including ones straddling every tpu_width word boundary."""
    rng = np.random.default_rng(sum(bits_set) + n)
    cards, dbs, tables, codes, streams, offs, flat = \
        _rows_fixture(rng, bits_set, n)
    fused = fuse_tables(tables)
    rows = _straddling_rows(rng, dbs, n)
    want = np.concatenate([t[np.clip(c[rows], 0, len(t) - 1)]
                           for t, c in zip(tables, codes)], axis=1)
    got = np.asarray(adv_gather_packed_rows(
        flat, offs, dbs, fused.table, fused.row_offsets, fused.card_limits,
        jnp.asarray(rows), fused.out_dim))
    np.testing.assert_array_equal(got, want)       # one-hot matmul is exact
    # split fallback (index-only transfer preserved) and pure-jnp oracle
    jt = [jnp.asarray(t) for t in tables]
    np.testing.assert_array_equal(
        np.asarray(adv_gather_packed_rows_split(flat, offs, dbs, jt,
                                                jnp.asarray(rows))), want)
    np.testing.assert_array_equal(
        np.asarray(adv_gather_packed_rows_ref(streams, dbs, jt,
                                              jnp.asarray(rows))), want)


@pytest.mark.parametrize("n0,appended", [
    (203, 5),      # mid-word tail append, stays inside the pad32 capacity
    (224, 10),     # n0 IS the pad32 boundary: append must GROW the resident
                   # stream, else indices past it clip into the next column
])
def test_packed_rows_after_refresh_appends(n0, appended):
    """The indexed gather serves rows appended by FeaturePlan.refresh —
    mid-word tail appends AND appends that cross the executor's word-stream
    capacity — bit-exact vs the int32 layout."""
    rng = np.random.default_rng(21)
    t = Table.from_data({"a": rng.integers(0, 100, n0),
                         "b": rng.integers(0, 9, n0)})
    fs = FeatureSet().add("a", "zscore").add("b", "onehot")
    plan_i = FeaturePlan(t, fs)
    plan_p = FeaturePlan(t, fs, packed=True)
    ex_i = FeatureExecutor(plan_i)
    ex_p = FeatureExecutor(plan_p)
    np.asarray(ex_p.batch(np.arange(64)))          # compile + put pre-refresh
    new = {"a": t["a"].dictionary.add_rows(rng.integers(0, 100, appended)),
           "b": t["b"].dictionary.add_rows(rng.integers(0, 9, appended))}
    plan_p.refresh(new)
    plan_i.refresh(new)
    rows = np.array([0, 31, 32, 33, n0 - 2, n0 - 1, n0,
                     n0 + appended - 1])
    np.testing.assert_array_equal(np.asarray(ex_p.batch(rows)),
                                  np.asarray(ex_i.batch(rows)))


def test_packed_batch_keeps_int32_error_contract():
    """Empty and out-of-range batches behave like the int32 path: empty ->
    (0, F), OOB -> IndexError (never a silent clipped gather)."""
    rng = np.random.default_rng(24)
    t = Table.from_data({"a": rng.integers(0, 100, 224)})
    fs = FeatureSet().add("a", "zscore")
    ex_p = FeatureExecutor(FeaturePlan(t, fs, packed=True))
    ex_i = FeatureExecutor(FeaturePlan(t, fs))
    empty = np.array([], dtype=np.int64)
    assert np.asarray(ex_p.batch(empty)).shape == \
        np.asarray(ex_i.batch(empty)).shape
    for bad in ([500], [-1]):
        with pytest.raises(IndexError):
            ex_p.batch(np.array(bad))


def test_packed_rows_autotune_sweeps_rows_kernel():
    """autotune=True on the rows path sweeps the rows kernel itself and
    still serves bit-exact."""
    rng = np.random.default_rng(23)
    t = Table.from_data({"a": rng.integers(0, 100, 512)})
    fs = FeatureSet().add("a", "zscore")
    plan_p = FeaturePlan(t, fs, packed=True)
    ex_p = FeatureExecutor(plan_p, use_kernel=True, autotune=True)
    ex_i = FeatureExecutor(FeaturePlan(t, fs))
    rows = rng.integers(0, 512, 96)
    np.testing.assert_array_equal(np.asarray(ex_p.batch(rows)),
                                  np.asarray(ex_i.batch(rows)))
    assert 96 in ex_p._rows_blocks_cache           # swept once per shape
    bn, bk = ex_p._rows_blocks_cache[96]
    assert bn % 32 == 0 and plan_p.fused_tables().table.shape[0] % bk == 0


def test_packed_service_serves_rows_past_initial_capacity():
    """Service-level regression: a request for rows appended after compile
    (past the word stream's original pad32 capacity) is served bit-exact,
    not silently clipped into another column's words."""
    rng = np.random.default_rng(22)
    t = Table.from_data({"a": rng.integers(0, 100, 224),
                         "b": rng.integers(0, 9, 224)})
    fs = FeatureSet().add("a", "zscore").add("b", "onehot")
    pipe = FeaturePipeline(t, fs)
    plan_p = FeaturePlan(t, fs, packed=True)
    svc = FeatureService(plan_p, buckets=(64,))
    svc.result(svc.submit(np.arange(64)))          # puts words at cap 224
    new = {"a": t["a"].dictionary.add_rows(rng.integers(0, 100, 10)),
           "b": t["b"].dictionary.add_rows(rng.integers(0, 9, 10))}
    plan_p.refresh(new)
    pipe.plan.refresh(new)
    rows = np.arange(220, 234)                     # spans the old capacity
    np.testing.assert_array_equal(svc.result(svc.submit(rows)),
                                  np.asarray(pipe.batch(rows)))
    svc.shutdown()


# -- executor bit-exactness across the bits sweep ------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_executor_bit_exact_across_bits(use_kernel):
    rng = np.random.default_rng(7)
    n = 33024                  # bits=16 needs cardinality 2**15 + 1 <= n
    data = {f"c{b}": _column_data(rng, b, n) for b in BITS_SWEEP}
    table = Table.from_data(data)
    fs = FeatureSet()
    for b in BITS_SWEEP:
        fs = fs.add(f"c{b}", "zscore").add(f"c{b}", "minmax")
    plan_i, plan_p, ex_i, ex_p = _packed_vs_int32(table, fs, use_kernel)
    assert [tpu_width(b) for b in BITS_SWEEP] == plan_p.device_bits
    for start, m in ((0, 128), (512, 128), (96, 100)):
        idx = np.arange(start, start + m)
        np.testing.assert_array_equal(np.asarray(ex_p.batch_range(start, m)),
                                      np.asarray(ex_i.batch(idx)))
    # arbitrary rows fall back to the host word-gather, still bit-exact
    ridx = rng.integers(0, n, 333)
    np.testing.assert_array_equal(np.asarray(ex_p.batch(ridx)),
                                  np.asarray(ex_i.batch(ridx)))
    # coalesced multi-range launch == per-range launches
    multi = np.asarray(ex_p._multi_range_future([0, 224, 512], 128))
    for k, st in enumerate((0, 224, 512)):
        np.testing.assert_array_equal(multi[k],
                                      np.asarray(ex_i.batch(
                                          np.arange(st, st + 128))))


@given(st.integers(0, 2**31), st.sampled_from(BITS_SWEEP),
       st.integers(33, 500))
@settings(max_examples=10, deadline=None)
def test_packed_executor_property(seed, bits, n):
    rng = np.random.default_rng(seed)
    k = 2 if bits == 1 else (1 << (bits - 1)) + 1
    table = Table.from_data({"c": _column_data(rng, bits, max(n, k))})
    fs = FeatureSet().add("c", "zscore")
    plan_i, plan_p, ex_i, ex_p = _packed_vs_int32(table, fs, False)
    m = int(rng.integers(1, table.n_rows))
    np.testing.assert_array_equal(
        np.asarray(ex_p.batch_range(0, m)),
        np.asarray(ex_i.batch(np.arange(m))))


def test_packed_batches_iterator_block_shuffled():
    rng = np.random.default_rng(3)
    table = Table.from_data({"a": rng.integers(0, 50, 512)})
    fs = FeatureSet().add("a", "zscore")
    plan_i, plan_p, ex_i, ex_p = _packed_vs_int32(table, fs, False)
    got = list(ex_p.batches(128, seed=5, epochs=2))
    assert len(got) == 8
    starts = sorted(int(idx[0]) for idx, _ in got[:4])
    assert starts == [0, 128, 256, 384]            # one epoch covers all
    for idx, feats in got:
        np.testing.assert_array_equal(np.asarray(feats),
                                      np.asarray(ex_i.batch(idx)))
    with pytest.raises(ValueError):
        next(ex_p.batches(100))                    # not word-aligned


# -- refresh across a tpu_width boundary ---------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_refresh_across_width_boundary(use_kernel):
    """K=4 (2 bits, db=2) grows to K=5 (3 bits, db=4): the word stream must
    repack in place and stay bit-exact vs the int32 layout, including the
    appended rows and already-compiled batch shapes."""
    rng = np.random.default_rng(4)
    n = 400
    vals = np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)]
    ages = rng.integers(18, 80, n)
    t = Table.from_data({"state": vals, "age": ages})
    fs = FeatureSet().add("state", "onehot").add("age", "zscore")
    plan_i = FeaturePlan(t, fs)
    plan_p = FeaturePlan(t, fs, packed=True)
    ex_i = FeatureExecutor(plan_i)
    ex_p = FeatureExecutor(plan_p, use_kernel=use_kernel)
    np.asarray(ex_p.batch_range(0, 128))           # compile pre-refresh
    assert plan_p.device_bits == [2, 8]
    new = {"state": t["state"].dictionary.add_rows(
               np.array(["TX", "CA", "TX"])),      # K 4 -> 5: bits 2 -> 3
           "age": t["age"].dictionary.add_rows(np.array([150, 25, 33]))}
    assert plan_p.refresh(new) == 2
    assert plan_i.refresh(new) == 2                # separate augmented dicts
    assert plan_p.device_bits == [4, 8]            # crossed db 2 -> 4
    assert plan_p.stats["words_repacked"] == 1
    assert plan_p.n_rows == plan_i.n_rows == n + 3
    idx = np.arange(n - 32, n + 3)                 # spans old rows + appended
    np.testing.assert_array_equal(np.asarray(ex_p.batch(idx)),
                                  np.asarray(ex_i.batch(idx)))
    # compiled range shape serves the repacked stream (db is a static arg,
    # so the width change retraces; values must be the new tables')
    np.testing.assert_array_equal(
        np.asarray(ex_p.batch_range(n - n % 32, 32 + (n + 3) % 32)[:3 + n % 32]),
        np.asarray(ex_i.batch(np.arange(n - n % 32, n + 3))))


def test_packed_refresh_tail_word_append():
    """Appends that land mid-word rewrite exactly one tail word."""
    rng = np.random.default_rng(5)
    t = Table.from_data({"a": rng.integers(0, 100, 203)})  # db=8, 203 % 4 = 3
    fs = FeatureSet().add("a", "minmax")
    plan_p = FeaturePlan(t, fs, packed=True)
    for step in range(3):
        codes = t["a"].dictionary.add_rows(rng.integers(0, 100, 5))
        plan_p.refresh({"a": codes})
        np.testing.assert_array_equal(
            plan_p.host_codes(np.arange(plan_p.n_rows - 5,
                                        plan_p.n_rows))[0], codes)
    assert plan_p.n_rows == 218


# -- service over a packed plan -----------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_packed_service_matches_pipeline(use_kernel):
    rng = np.random.default_rng(6)
    n = 2048
    t = Table.from_data({
        "age": rng.integers(18, 80, n),
        "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
        "income": rng.integers(20, 200, n) * 1000,
    })
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    pipe = FeaturePipeline(t, fs)
    svc = FeatureService(FeaturePlan(t, fs, packed=True),
                         use_kernel=use_kernel, buckets=(64, 256))
    reqs = [np.arange(0, 256),                     # aligned range chunk(s)
            np.arange(992, 1056),                  # aligned, mid-table
            rng.integers(0, n, 200),               # arbitrary rows: fallback
            np.arange(7, 40),                      # contiguous, unaligned
            np.arange(1984, 2048),                 # tail range
            np.arange(0, 520)]                     # multi-chunk, mixed tail
    tickets = [svc.submit(r) for r in reqs]
    for r, tk in zip(reqs, tickets):
        np.testing.assert_array_equal(svc.result(tk), np.asarray(pipe.batch(r)))
    assert svc.stats["packed_ranges"] >= 4
    assert svc.stats["bytes_h2d"] > 0              # fallbacks shipped codes


def test_packed_service_coalesces_launches():
    rng = np.random.default_rng(8)
    n = 4096
    t = Table.from_data({"a": rng.integers(0, 100, n)})
    fs = FeatureSet().add("a", "zscore")
    pipe = FeaturePipeline(t, fs)
    svc = FeatureService(FeaturePlan(t, fs, packed=True), buckets=(128,),
                         coalesce=4)
    # pause holds the pump so the whole burst queues before any launch —
    # the deterministic maximal-coalescing schedule
    svc.pause()
    starts = [0, 512, 1024, 2048, 3072, 256]
    tickets = [svc.submit(np.arange(s, s + 128)) for s in starts]
    svc.resume()
    out = svc.drain()
    assert set(out) == set(tickets)
    # 6 chunks in groups of <= 4 -> 2 launches
    assert svc.stats["launches"] == 2
    assert svc.stats["packed_ranges"] == 6
    for s, tk in zip(starts, tickets):
        np.testing.assert_array_equal(out[tk],
                                      np.asarray(pipe.batch(
                                          np.arange(s, s + 128))))


def test_packed_service_poll_flushes_partial_group():
    """A single queued range (partial coalescing group) must still complete
    through poll() alone — flushing is part of the pump, not result()."""
    import time
    rng = np.random.default_rng(9)
    t = Table.from_data({"a": rng.integers(0, 100, 512)})
    fs = FeatureSet().add("a", "zscore")
    svc = FeatureService(FeaturePlan(t, fs, packed=True), buckets=(64,))
    tk = svc.submit(np.arange(64, 128))
    deadline = time.perf_counter() + 30.0
    while not svc.poll(tk):
        assert time.perf_counter() < deadline
        time.sleep(0.001)
    pipe = FeaturePipeline(t, fs)
    np.testing.assert_array_equal(svc.result(tk),
                                  np.asarray(pipe.batch(np.arange(64, 128))))


def test_packed_sharding_supported_but_no_codes_matrix():
    """Packed plans shard per IMCU (word-stream slices) since the mesh PR;
    what they still never do is materialize the int32 code matrix, and a
    shard view refuses refresh (that belongs to the parent)."""
    rng = np.random.default_rng(10)
    t = Table.from_data({"a": rng.integers(0, 10, 256)}, imcu_rows=128)
    plan = FeaturePlan(t, FeatureSet().add("a", "zscore"), packed=True)
    shards = plan.imcu_shards()
    assert [s.n_rows for s in shards] == [128, 128]
    with pytest.raises(RuntimeError):
        plan.codes_matrix
    with pytest.raises(RuntimeError):
        shards[0].codes_matrix
    with pytest.raises(RuntimeError):
        shards[0].refresh()
    with FeatureService(plan, sharded=True, buckets=(64,)) as svc:
        assert svc.n_shards == 2
        rows = rng.integers(0, 256, 100)
        got = svc.result(svc.submit(rows))
        want = np.asarray(FeaturePipeline(t, FeatureSet().add("a", "zscore"))
                          .batch(rows))
        np.testing.assert_array_equal(got, want)


def test_packed_vmem_fallback_still_serves():
    """A plan past the VMEM budget keeps use_kernel off (split gathers) but
    the packed transfer/serving path still works."""
    rng = np.random.default_rng(12)
    t = Table.from_data({"zip": rng.integers(0, 1 << 17, 4096)})
    # ~4000 distinct codes x ~4000 one-hot dims: ΣKxΣF blows the ~16MB budget
    fs = FeatureSet().add("zip", "onehot", max_cardinality=4096)
    plan = FeaturePlan(t, fs, packed=True)
    ex = FeatureExecutor(plan, use_kernel=True)
    assert not ex.kernel_active
    ex_i = FeatureExecutor(FeaturePlan(t, fs))
    np.testing.assert_array_equal(np.asarray(ex.batch_range(0, 256)),
                                  np.asarray(ex_i.batch(np.arange(256))))


# -- data movement accounting --------------------------------------------------------
def test_packed_bytes_moved_table2_mixed_cardinality():
    """Paper Table 2 mixed-cardinality workload: the packed layout ships
    >= 4x fewer host->device bytes than the int32 code matrix."""
    rng = np.random.default_rng(13)
    n = 4096
    t = Table.from_data({
        "binary_gender": rng.integers(0, 2, n),          # 1 bit  -> db 1
        "season": rng.integers(0, 4, n),                 # 2 bits -> db 2
        "months": rng.integers(0, 12, n),                # 4 bits -> db 4
        "us_states": rng.integers(0, 50, n),             # 6 bits -> db 8
        "countries": rng.integers(0, 195, n),            # 8 bits -> db 8
    })
    fs = FeatureSet()
    for c in t.names:
        fs = fs.add(c, "zscore")
    plan_i = FeaturePlan(t, fs)
    plan_p = FeaturePlan(t, fs, packed=True)
    b = 1024
    assert plan_i.bytes_moved_adv(b) == 4 * b * 5
    assert plan_p.bytes_moved_adv(b) == sum(
        packed_nbytes(b, db) for db in (1, 2, 4, 8, 8))
    ratio = plan_i.bytes_moved_adv(b) / plan_p.bytes_moved_adv(b)
    assert ratio >= 4.0
    # resident duplication shrinks by the same factor
    assert plan_i.bytes_resident_codes() / plan_p.bytes_resident_codes() >= 4


def test_packed_gather_host_util():
    rng = np.random.default_rng(14)
    for db in (1, 2, 4, 8, 16, 32):
        codes = rng.integers(0, min(1 << db, 1 << 31), 500)
        words = pack_bits(codes, db)
        rows = rng.integers(0, 500, 99)
        np.testing.assert_array_equal(packed_gather(words, db, rows),
                                      codes[rows])
    with pytest.raises(ValueError):
        packed_gather(np.zeros(4, np.uint32), 6, np.array([0]))
