"""Random-row packed serving + the background pump (PR 3 tentpole).

Covers the acceptance surface: arbitrary-row requests served bit-exact by
the unified coalescer with index-only host->device traffic, a pump that
drains with ZERO caller-driven dispatch (poll/result never launch), thread
safety under concurrent submit/poll/result, and orderly shutdown/drain.
"""
import threading
import time

import numpy as np
import pytest

from repro.columnar import Table
from repro.core import FeatureSet, FeaturePipeline, FeaturePlan
from repro.serve import FeatureService


def _table(n=2048, seed=0, cols=3):
    rng = np.random.default_rng(seed)
    data = {"age": rng.integers(18, 80, n),
            "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
            "income": rng.integers(20, 200, n) * 1000}
    return Table.from_data({k: data[k] for k in list(data)[:cols]})


def _features(cols=3):
    fs = (FeatureSet().add("age", "zscore")
          .add("age", "bucketize", boundaries=(30.0, 50.0, 65.0)))
    if cols >= 2:
        fs = fs.add("state", "onehot")
    if cols >= 3:
        fs = fs.add("income", "minmax")
    return fs


@pytest.mark.parametrize("use_kernel", [False, True])
def test_random_requests_bit_exact(use_kernel):
    """Uniform arbitrary-row requests (mixed sizes) through the coalescer
    == the direct pipeline, bit-exact."""
    t = _table()
    pipe = FeaturePipeline(t, _features())
    rng = np.random.default_rng(1)
    with FeatureService(FeaturePlan(t, _features(), packed=True),
                        use_kernel=use_kernel, buckets=(64, 256)) as svc:
        reqs = [rng.integers(0, 2048, sz)
                for sz in (1, 17, 64, 200, 256, 700)]
        tickets = [svc.submit(r) for r in reqs]
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(svc.result(tk),
                                          np.asarray(pipe.batch(r)))


def test_random_requests_ship_index_only_bytes():
    """bytes_h2d on packed plans counts 4B x padded rows of INDICES per
    launch — independent of how many columns the plan serves."""
    observed = {}
    for cols in (1, 3):
        t = _table(cols=cols)
        svc = FeatureService(FeaturePlan(t, _features(cols), packed=True),
                             buckets=(128,), coalesce=4)
        rng = np.random.default_rng(2)
        svc.pause()                       # deterministic grouping
        for _ in range(8):
            svc.submit(rng.integers(0, 2048, 100))
        svc.resume()
        svc.drain()
        assert svc.stats["launches"] == 2          # 8 chunks / coalesce 4
        # every launch ships exactly one padded (coalesce, bucket) index
        # matrix: 4B * 4 * 128 each, no code bytes at all
        assert svc.stats["bytes_h2d"] == 2 * 4 * 4 * 128
        observed[cols] = svc.stats["bytes_h2d"]
        svc.shutdown()
    assert observed[1] == observed[3]              # column-count independent


def test_pump_drains_without_caller_dispatch():
    """A submitted request completes with NO poll/result/drain call at all
    — the background pump is the only dispatcher (ROADMAP open item)."""
    t = _table(n=512)
    pipe = FeaturePipeline(t, _features())
    svc = FeatureService(FeaturePlan(t, _features(), packed=True),
                         buckets=(64,))
    tk = svc.submit(np.arange(7, 64))              # unaligned, mid-word
    deadline = time.perf_counter() + 30.0
    while svc.stats["completed"] < 1:              # stats read, no API call
        assert time.perf_counter() < deadline, "pump never retired"
        time.sleep(0.001)
    assert svc.poll(tk)                            # already done: no work
    np.testing.assert_array_equal(svc.result(tk),
                                  np.asarray(pipe.batch(np.arange(7, 64))))
    svc.shutdown()


def test_poll_and_result_never_launch():
    """While the pump is paused, poll never makes progress happen — proof
    that result retrieval carries no dispatch path of its own."""
    t = _table(n=512)
    svc = FeatureService(FeaturePlan(t, _features(), packed=True),
                         buckets=(64,))
    svc.pause()
    tk = svc.submit(np.arange(64))
    for _ in range(20):
        assert svc.poll(tk) is False               # no caller-driven launch
        time.sleep(0.001)
    assert svc.stats["launches"] == 0
    svc.resume()
    assert svc.result(tk).shape[0] == 64
    svc.shutdown()


@pytest.mark.parametrize("packed", [False, True])
def test_concurrent_submit_poll_result_threads(packed):
    """Many client threads submit/poll/result against one service; every
    thread must see its own bit-exact results."""
    t = _table()
    pipe = FeaturePipeline(t, _features())
    svc = FeatureService(FeaturePlan(t, _features(), packed=packed),
                         buckets=(64, 256))
    errors = []

    def client(seed):
        try:
            rng = np.random.default_rng(seed)
            for _ in range(8):
                rows = rng.integers(0, 2048, int(rng.integers(1, 300)))
                tk = svc.submit(rows)
                if seed % 2:                       # half poll, half block
                    while not svc.poll(tk):
                        time.sleep(0.0005)
                got = svc.result(tk)
                want = np.asarray(pipe.batch(rows))
                if packed:
                    np.testing.assert_array_equal(got, want)
                else:
                    np.testing.assert_allclose(got, want, atol=1e-6)
        except Exception as e:                     # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors, errors
    svc.shutdown()


def test_drain_does_not_steal_claimed_results():
    """A ticket another thread is blocked on in result() must not be swept
    away by a concurrent drain() — the waiter owns it."""
    t = _table()
    pipe = FeaturePipeline(t, _features())
    svc = FeatureService(FeaturePlan(t, _features(), packed=True),
                         buckets=(64,))
    rng = np.random.default_rng(7)
    rows = rng.integers(0, 2048, 64 * 12)          # multi-chunk: stays
    tk = svc.submit(rows)                          # pending long enough for
    got, errors = {}, []                           # the waiter to claim it

    def waiter():
        try:
            got["res"] = svc.result(tk)
        except Exception as e:                     # pragma: no cover
            errors.append(e)

    th = threading.Thread(target=waiter)
    th.start()
    deadline = time.perf_counter() + 30.0
    while tk not in svc._claimed and "res" not in got:   # waiter is in
        assert time.perf_counter() < deadline            # result() now
        time.sleep(0.0005)
    drained = svc.drain()                          # concurrent with waiter
    th.join()
    assert not errors, errors
    assert tk not in drained                       # not stolen
    np.testing.assert_array_equal(got["res"], np.asarray(pipe.batch(rows)))
    svc.shutdown()


def test_paused_result_and_drain_raise_instead_of_hanging():
    """Blocking on work the paused pump will never launch must raise, not
    deadlock — pause() is for burst batching, not a silent off switch."""
    t = _table(n=512)
    svc = FeatureService(FeaturePlan(t, _features(), packed=True),
                         buckets=(64,))
    svc.pause()
    tk = svc.submit(np.arange(64))
    with pytest.raises(RuntimeError, match="paused"):
        svc.result(tk)
    with pytest.raises(RuntimeError, match="pause"):
        svc.drain()
    svc.resume()                                   # still fully usable
    assert svc.result(tk).shape[0] == 64
    svc.shutdown()


def test_shutdown_drains_and_rejects_new_work():
    t = _table(n=512)
    pipe = FeaturePipeline(t, _features())
    svc = FeatureService(FeaturePlan(t, _features(), packed=True),
                         buckets=(64,))
    rng = np.random.default_rng(3)
    reqs = [rng.integers(0, 512, 64) for _ in range(6)]
    tickets = [svc.submit(r) for r in reqs]
    svc.shutdown()                                 # orderly drain + join
    assert not svc._pump.is_alive()
    for r, tk in zip(reqs, tickets):               # results survive shutdown
        np.testing.assert_array_equal(svc.result(tk),
                                      np.asarray(pipe.batch(r)))
    with pytest.raises(RuntimeError):
        svc.submit(np.arange(4))
    svc.shutdown()                                 # idempotent


def test_shutdown_discard_forgets_queued_tickets():
    t = _table(n=512)
    svc = FeatureService(FeaturePlan(t, _features(), packed=True),
                         buckets=(64,))
    svc.pause()                                    # hold the queue
    tk = svc.submit(np.arange(64))
    svc.shutdown(drain=False)
    with pytest.raises(KeyError):                  # dropped, not pending
        svc.poll(tk)
    assert not svc._pump.is_alive()


def test_service_context_manager_and_drain():
    t = _table(n=512)
    pipe = FeaturePipeline(t, _features())
    rng = np.random.default_rng(4)
    with FeatureService(FeaturePlan(t, _features(), packed=True),
                        buckets=(64,)) as svc:
        reqs = [rng.integers(0, 512, 40) for _ in range(5)]
        tickets = [svc.submit(r) for r in reqs]
        out = svc.drain()
        assert set(out) == set(tickets)
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(out[tk], np.asarray(pipe.batch(r)))
    assert not svc._pump.is_alive()                # __exit__ joined the pump
