"""End-to-end Trainer integration: loss descent, checkpoint/restart
(fault-tolerance contract), straggler accounting, WSD scheduling."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data import TokenStore, synthetic_corpus, token_batches
from repro.models import lm
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainConfig
from repro.train import checkpoint as ck


def _setup(arch="qwen2-7b", vocab=512):
    cfg = dataclasses.replace(reduced(get_config(arch)), vocab=vocab,
                              vocab_pad_multiple=64)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    store = TokenStore(synthetic_corpus(60_000, cfg.vocab), cfg.vocab)
    return cfg, params, store


def test_trainer_descends_and_checkpoints(tmp_path):
    cfg, params, store = _setup()
    trainer = Trainer(
        cfg=cfg, opt=OptConfig(lr=3e-2),
        train=TrainConfig(steps=24, warmup=2, log_every=4, ckpt_every=8,
                          ckpt_dir=str(tmp_path), donate=False))
    data = token_batches(store, cfg, batch=8, seq=16)
    params, history = trainer.fit(params, data)
    assert history[-1]["loss"] < history[0]["loss"] - 0.4
    # checkpoints landed, latest == final step
    assert ck.latest_steps(str(tmp_path))[-1] == 24


def test_trainer_resume_after_interrupt(tmp_path):
    """Phase 1 runs 16/32 steps; phase 2 resumes from the checkpoint and the
    restart is recorded in the fault log — the node-failure recovery path."""
    cfg, params, store = _setup()
    opt = OptConfig(lr=1e-2)

    t1 = Trainer(cfg=cfg, opt=opt,
                 train=TrainConfig(steps=16, warmup=2, log_every=4,
                                   ckpt_every=8, ckpt_dir=str(tmp_path),
                                   donate=False))
    data = token_batches(store, cfg, batch=8, seq=16)
    _, hist1 = t1.fit(params, data)
    assert ck.latest_steps(str(tmp_path))[-1] == 16

    # 'crash' + new process: fresh params, resume pulls step-16 state
    fresh = lm.init_params(cfg, jax.random.PRNGKey(99))
    t2 = Trainer(cfg=cfg, opt=opt,
                 train=TrainConfig(steps=32, warmup=2, log_every=4,
                                   ckpt_every=8, ckpt_dir=str(tmp_path),
                                   donate=False))
    # restart-safe data: same seed, loader replays exact batches per step
    data2 = token_batches(store, cfg, batch=8, seq=16, start_step=16)
    _, hist2 = t2.fit(fresh, data2)
    assert t2.fault_log.summary().get("restart") == 1
    # resumed run continues from trained state, not from scratch
    assert hist2[0]["loss"] < hist1[0]["loss"]
    assert hist2[0]["step"] == 16


def test_trainer_wsd_schedule_applied():
    cfg, params, store = _setup()
    trainer = Trainer(cfg=cfg, opt=OptConfig(lr=1e-2),
                      train=TrainConfig(steps=10, warmup=2, schedule="wsd",
                                        log_every=1, ckpt_every=0,
                                        donate=False))
    data = token_batches(store, cfg, batch=4, seq=16)
    _, history = trainer.fit(params, data)
    lrs = [h["lr"] for h in history]
    assert lrs[0] == 0.0                       # warmup start
    assert abs(lrs[5] - 1e-2) < 1e-9           # stable phase at peak
    assert lrs[-1] < 1e-2                      # decay tail


def test_trainer_adamw8_path():
    """Quantized-state optimizer trains through the full Trainer loop."""
    cfg, params, store = _setup()
    trainer = Trainer(cfg=cfg, opt=OptConfig(name="adamw8", lr=3e-2),
                      train=TrainConfig(steps=16, warmup=2, log_every=4,
                                        ckpt_every=0, donate=False))
    data = token_batches(store, cfg, batch=8, seq=16)
    _, history = trainer.fit(params, data)
    assert history[-1]["loss"] < history[0]["loss"] - 0.3
