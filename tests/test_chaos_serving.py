"""Chaos suite: launch-level fault isolation, failover, deadlines.

The invariant under test: a FeatureService under injected launch faults
either completes every ticket BIT-exact vs the fault-free reference (when
a healthy replica exists to fail over to) or resolves exactly the faulted
tickets to typed ServeErrors while everything else keeps serving — the
service itself never dies from a launch-path exception. Faults are
injected by :class:`repro.serve.faults.FaultInjector` ON the pump's
launch path, so they exercise the same recovery machinery a real device
error would.

Deterministic by construction: scripted rules fire on exact launch
sequences (no timing races), and breaker thresholds are raised wherever a
test's fault script must fully play out. The randomized sweep reads
``CHAOS_SWEEP_SEEDS`` (nightly sets it high; default keeps tier-1 quick).
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.columnar import Table
from repro.core import (FeatureSet, FeaturePlan, FeatureExecutor)
from repro.serve import (DeadlineExceeded, FaultInjector, FaultPolicy,
                         FeatureService, InjectedFault, ServeError)
from repro.serve.faults import StreamBreaker


def _mixed_table(n=3000, imcu_rows=700, seed=0):
    rng = np.random.default_rng(seed)
    t = Table.from_data({
        "age": rng.integers(18, 80, n),
        "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
        "income": rng.integers(20, 200, n) * 1000,
    }, imcu_rows=imcu_rows)
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    return t, fs


def _reference(t, fs, requests):
    """Fault-free ground truth: the unsharded int32 executor."""
    ex = FeatureExecutor(FeaturePlan(t, fs))
    return [np.asarray(ex.batch(r)) for r in requests]


# -- faults.py unit behavior ---------------------------------------------------------
def test_injector_rules_are_deterministic():
    inj = (FaultInjector()
           .fail_launches(2, shard=1)
           .delay_launches(0.0, 1, shard=0, after=1)
           .fail_launches(1, shard=0, stream=2, every=2))
    # shard-1 rule: exactly the next two shard-1 launches fail, then heal
    with pytest.raises(InjectedFault):
        inj.before_launch(1, 0)
    with pytest.raises(InjectedFault):
        inj.before_launch(1, 0)
    inj.before_launch(1, 0)                        # healed
    # shard-0 delay skips `after` matches, then fires once
    inj.before_launch(0, 0)
    inj.before_launch(0, 0)
    assert inj.delays_injected == 1
    # every=2 on (0, stream=2): first match skipped, second fires
    inj.before_launch(0, 2)
    with pytest.raises(InjectedFault):
        inj.before_launch(0, 2)
    assert inj.faults_injected == 3
    assert inj.launches_seen == 7


def test_injector_random_mode_seeded():
    a = FaultInjector(seed=7).random_faults(p_fail=0.5, max_events=10)
    b = FaultInjector(seed=7).random_faults(p_fail=0.5, max_events=10)
    pat_a = []
    for _ in range(40):
        try:
            a.before_launch(0, 0)
            pat_a.append(0)
        except InjectedFault:
            pat_a.append(1)
    pat_b = []
    for _ in range(40):
        try:
            b.before_launch(0, 0)
            pat_b.append(0)
        except InjectedFault:
            pat_b.append(1)
    assert pat_a == pat_b and sum(pat_a) == 10     # capped by max_events


def test_policy_backoff_and_breaker():
    p = FaultPolicy(backoff_s=0.01, backoff_cap_s=0.04)
    assert p.backoff_for(1) == 0.01
    assert p.backoff_for(2) == 0.02
    assert p.backoff_for(5) == 0.04                # capped
    with pytest.raises(ValueError):
        FaultPolicy(max_retries=-1)
    b = StreamBreaker()
    assert not b.strike(3, 1.0, now=0.0)
    assert not b.strike(3, 1.0, now=0.0)
    assert b.strike(3, 1.0, now=0.0)               # trips on the 3rd
    assert b.opened == 1
    assert b.is_open(3, now=0.5)
    assert not b.is_open(3, now=1.5)               # cooldown over: half-open
    assert not b.strike(3, 1.0, now=2.0)           # probe failed: re-open...
    assert b.is_open(3, now=2.5)                   # ...without re-counting
    b.reset()
    assert not b.is_open(3, now=2.5) and b.fails == 0


# -- acceptance: failover keeps availability at 1.0 ----------------------------------
def test_chaos_failover_bit_exact_availability_one():
    """>= 20 injected launch faults + 2 straggler episodes on a shard with
    2 replicas: every ticket completes bit-exact vs the fault-free
    reference, availability 1.0, failovers observed."""
    t, fs = _mixed_table()
    rng = np.random.default_rng(41)
    requests = [rng.integers(0, 700, rng.integers(8, 64))
                for _ in range(40)]                # all rows in shard 0
    requests += [np.arange(700 * s, 700 * s + 48) for s in (1, 2, 3)]
    want = _reference(t, fs, requests)
    inj = (FaultInjector()
           .fail_launches(12, shard=0, stream=0)
           .fail_launches(8, shard=0, stream=1)
           .delay_launches(0.12, 1, shard=0, stream=2, after=6)
           .delay_launches(0.12, 1, shard=1))
    # breaker effectively disabled so both fail rules play out in full and
    # the test stays deterministic whatever the launch interleaving
    pol = FaultPolicy(max_retries=3, backoff_s=0.001, breaker_fails=100,
                      straggler_min_s=0.05, straggler_warmup=3)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        svc.add_replica(0)
        svc.add_replica(0)
        tickets = [svc.submit(r) for r in requests]
        got = [svc.result(tk, timeout=60) for tk in tickets]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    st = svc.throughput_stats(1.0)
    assert inj.faults_injected >= 20
    assert inj.delays_injected == 2
    assert st["completed"] == st["requests"] == len(requests)
    assert st["availability"] == 1.0
    assert st["failed_tickets"] == 0
    assert st["failovers"] > 0
    assert st["retries"] >= 20


def test_chaos_no_replicas_isolates_faulted_shard():
    """Without replicas, a persistently failing shard takes down ONLY its
    own tickets — each resolves to a typed ServeError — while every other
    shard's tickets complete bit-exact, and the service accepts (and
    serves) new submits after the fault heals."""
    t, fs = _mixed_table()
    reqs_ok = [np.arange(700 * s + 8, 700 * s + 40) for s in (0, 1, 3)]
    reqs_bad = [np.arange(1400 + 16 * i, 1400 + 16 * i + 16)
                for i in range(5)]                 # shard 2 rows
    want_ok = _reference(t, fs, reqs_ok)
    # enough scripted faults that every shard-2 launch fails through all
    # retries: 5 tickets x (1 + 2 retries) = 15
    inj = FaultInjector().fail_launches(15, shard=2)
    pol = FaultPolicy(max_retries=2, backoff_s=0.001, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        tickets_ok = [svc.submit(r) for r in reqs_ok]
        tickets_bad = [svc.submit(r) for r in reqs_bad]
        for g, w in zip((svc.result(tk, timeout=60)
                         for tk in tickets_ok), want_ok):
            np.testing.assert_array_equal(g, w)
        for tk in tickets_bad:
            assert svc.poll(tk)                     # resolved, not hung
            with pytest.raises(ServeError) as ei:
                svc.result(tk, timeout=60)
            assert ei.value.shard == 2
            assert ei.value.attempts == 3           # 1 + max_retries
            assert isinstance(ei.value.__cause__, InjectedFault)
        st = dict(svc.stats)
        assert st["failed_tickets"] == len(reqs_bad)
        # the rules are exhausted (healed): the shard serves again
        again = np.arange(1400, 1464)
        np.testing.assert_array_equal(
            svc.result(svc.submit(again), timeout=60),
            _reference(t, fs, [again])[0])


def test_chaos_collect_mixes_results_and_errors():
    t, fs = _mixed_table()
    inj = FaultInjector().fail_launches(3, shard=1)
    pol = FaultPolicy(max_retries=2, backoff_s=0.001, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        t_ok = svc.submit(np.arange(0, 32))
        t_bad = svc.submit(np.arange(700, 732))
        out = svc.collect(timeout=60)
    assert isinstance(out[t_ok], np.ndarray)
    assert isinstance(out[t_bad], ServeError)
    np.testing.assert_array_equal(out[t_ok],
                                  _reference(t, fs, [np.arange(0, 32)])[0])


# -- breaker / monitor integration ---------------------------------------------------
def test_breaker_opens_and_monitor_rereplicates():
    """Consecutive failures open the primary's breaker (shard turns
    unhealthy); rebalance() grows a failover replica on a healthy device;
    retries drain through it and the breaker probe eventually closes."""
    t, fs = _mixed_table()
    inj = FaultInjector().fail_launches(3, shard=0, stream=0)
    pol = FaultPolicy(max_retries=5, backoff_s=0.001, breaker_fails=3,
                      breaker_cooldown_s=30.0)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol, max_replicas=2) as svc:
        tk = svc.submit(np.arange(0, 32))
        deadline = time.perf_counter() + 30
        while not svc.unhealthy and time.perf_counter() < deadline:
            time.sleep(0.005)
        assert svc.unhealthy == [0]
        assert svc.stats["unhealthy_shards"] == 1
        acts = svc.rebalance()
        assert [s for s, _ in acts["failover_replicated"]] == [0]
        assert svc.replicas[0] == 1
        # the failover replica serves the stuck ticket bit-exact
        np.testing.assert_array_equal(
            svc.result(tk, timeout=60),
            _reference(t, fs, [np.arange(0, 32)])[0])
        assert svc.stats["failovers"] > 0
        # a second rebalance does NOT stack FAILOVER replicas (one healthy
        # copy already covers the shard) and never sheds the existing one
        # (policy 2 may still replicate shard 0 for plain load — all the
        # traffic is on it)
        acts2 = svc.rebalance()
        assert acts2["failover_replicated"] == []
        assert acts2["dropped"] == []
        assert svc.replicas[0] >= 1


def test_breaker_probe_recovers_stream():
    """After the cooldown the opened stream is half-open: the next launch
    probes it, a success closes the breaker (shard healthy again)."""
    t, fs = _mixed_table()
    inj = FaultInjector().fail_launches(2, shard=0, stream=0)
    pol = FaultPolicy(max_retries=5, backoff_s=0.001, breaker_fails=2,
                      breaker_cooldown_s=0.05)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        svc.result(svc.submit(np.arange(0, 32)), timeout=60)
        assert svc.stats["unhealthy_shards"] == 1
        time.sleep(0.06)                           # ride out the cooldown
        svc.result(svc.submit(np.arange(0, 32)), timeout=60)  # the probe
        assert svc.unhealthy == []


# -- deadlines & timeouts ------------------------------------------------------------
def test_deadline_expires_queued_ticket():
    t, fs = _mixed_table()
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1) as svc:
        with pytest.raises(ValueError):
            svc.submit(np.arange(8), deadline_ms=0)
        svc.pause()                                # hold the queue
        tk = svc.submit(np.arange(0, 32), deadline_ms=20)
        time.sleep(0.05)                           # let it expire queued
        svc.resume()
        with pytest.raises(DeadlineExceeded) as ei:
            svc.result(tk, timeout=60)
        assert isinstance(ei.value, TimeoutError)  # generic catch works
        assert ei.value.ticket == tk
        assert svc.stats["timeouts"] == 1
        assert svc.stats["failed_tickets"] == 1
        # the expired ticket is gone from the ledger, service healthy
        svc.result(svc.submit(np.arange(0, 32), deadline_ms=60_000),
                   timeout=60)
        assert svc.stats["completed"] == 1


def test_result_and_drain_timeout_on_stuck_ticket():
    """A straggling launch makes result(timeout=) and drain(timeout=)
    raise builtin TimeoutError promptly — and the ticket still completes
    afterwards (a wait timeout never cancels work)."""
    t, fs = _mixed_table()
    inj = FaultInjector().delay_launches(0.6, 1, shard=0)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj) as svc:
        tk = svc.submit(np.arange(0, 32))
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError):
            svc.result(tk, timeout=0.05)
        with pytest.raises(TimeoutError):
            svc.drain(timeout=0.05)
        assert time.perf_counter() - t0 < 0.5      # both bailed early
        np.testing.assert_array_equal(
            svc.result(tk, timeout=60),
            _reference(t, fs, [np.arange(0, 32)])[0])


# -- defensive paths: dead pump surfaced everywhere ----------------------------------
def _dying_service(monkeypatch):
    t, fs = _mixed_table(n=1400)
    svc = FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                         buckets=(64,), coalesce=1)
    boom = RuntimeError("pump infrastructure fault")

    def die():
        raise boom
    monkeypatch.setattr(svc, "_pick_action", die)
    return svc, boom


def test_pump_death_surfaces_from_every_entry_point(monkeypatch):
    """A pump-infrastructure error is terminal BY DESIGN — and every
    public entry point must report it promptly with the original error
    chained, rather than hanging or pretending to serve."""
    svc, boom = _dying_service(monkeypatch)
    with svc._lock:
        svc._work.notify_all()                     # wake into the fault
    svc._pump.join(timeout=10)
    assert not svc._pump.is_alive()
    for call in (lambda: svc.poll(0),
                 lambda: svc.submit(np.arange(8)),
                 lambda: svc.result(0),
                 svc.drain,
                 svc.collect,
                 svc.pause,
                 svc.resume,
                 lambda: svc.add_replica(0),
                 svc.rebalance):
        with pytest.raises(RuntimeError) as ei:
            call()
        assert ei.value.__cause__ is boom


def test_pump_death_unblocks_concurrent_waiters(monkeypatch):
    """_notify_everyone + _fail_admin: threads parked in result(), drain()
    and _run_admin() (all three condition classes) all wake with the
    chained error when the pump dies mid-wait."""
    t, fs = _mixed_table(n=1400)
    # the injected delay stalls the pump INSIDE its first launch, giving
    # all three waiter classes time to park before the pump's next tick
    inj = FaultInjector().delay_launches(0.5, 1)
    svc = FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                         buckets=(64,), coalesce=1, faults=inj)
    errs: dict[str, BaseException] = {}

    def waiter(name, fn):
        try:
            fn()
        except BaseException as e:
            errs[name] = e
    boom = RuntimeError("pump infrastructure fault")

    def die():
        raise boom
    tk = svc.submit(np.arange(8, 16))              # pump enters the delay
    threads = [threading.Thread(target=waiter, args=("result",
                                lambda: svc.result(tk))),
               threading.Thread(target=waiter, args=("drain", svc.drain)),
               threading.Thread(target=waiter, args=("admin",
                                lambda: svc.add_replica(0)))]
    for th in threads:
        th.start()
    time.sleep(0.1)                                # let them all park
    # the pump's next tick top runs _drain_admin — and dies there, with
    # the admin request still queued (_fail_admin must unblock it)
    monkeypatch.setattr(svc, "_drain_admin", die)
    for th in threads:
        th.join(timeout=20)
    assert not any(th.is_alive() for th in threads)
    assert set(errs) == {"result", "drain", "admin"}
    for e in errs.values():
        assert e.__cause__ is boom or e is boom


# -- device-loss recovery ------------------------------------------------------------
def test_device_loss_serves_via_host_gather():
    """Killing EVERY serving device must not lose a single ticket: each
    shard's streams get evicted as their device's DeviceDown arrives, and
    with no survivor to rebuild on the pump serves the orphaned shards
    from the host packed words — bit-exact, availability 1.0. (Tier-1's
    single-device run reaches this with one kill; the 4-device CI lane
    walks the evict -> rebuild -> re-evict chain until the pool is gone.)"""
    import jax
    t, fs = _mixed_table()
    rng = np.random.default_rng(17)
    requests = [rng.integers(0, 3000, rng.integers(8, 64))
                for _ in range(12)]
    requests += [np.arange(700 * s, 700 * s + 48) for s in range(4)]
    want = _reference(t, fs, requests)
    inj = FaultInjector()
    # retries cover the worst chain: a group re-placed onto another dead
    # device once per pool member before its shard goes host-served
    pol = FaultPolicy(max_retries=8, backoff_s=0.001, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        svc.result(svc.submit(np.arange(0, 32)), timeout=60)   # warm
        for d in jax.devices():
            inj.kill_device(d)
        tickets = [svc.submit(r) for r in requests]
        got = [svc.result(tk, timeout=120) for tk in tickets]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        st = svc.throughput_stats(1.0)
        assert st["availability"] == 1.0
        assert st["failed_tickets"] == 0
        assert st["devices_lost"] >= 1
        assert st["host_gathers"] > 0
        # evicted streams surrendered their breaker entries: the table
        # only holds tokens of streams still in the shard set
        live = {ex.stream_token
                for s in range(svc.n_shards)
                for ex in svc._sharded_ex.stream_executors(s)}
        assert set(svc._breakers) <= live


def test_device_loss_rebuilds_shard_on_survivor():
    """With a healthy device left in the pool, a dead device's shards are
    REBUILT there from the host packed words (version-keyed re-put): the
    miss window is host-served, the rebuild lands automatically (pump
    policy, no admin call), and post-recovery serving is bit-exact on
    device again."""
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (CI forces a 4-device host "
                    "platform)")
    t, fs = _mixed_table()
    inj = FaultInjector()
    pol = FaultPolicy(max_retries=8, backoff_s=0.001, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        svc.result(svc.submit(np.arange(0, 32)), timeout=60)   # warm
        dead = svc._sharded_ex.devices[0]
        inj.kill_device(dead)
        rows = np.arange(8, 56)
        np.testing.assert_array_equal(
            svc.result(svc.submit(rows), timeout=60),
            _reference(t, fs, [rows])[0])
        deadline = time.perf_counter() + 30
        while svc.stats["recoveries"] == 0 and \
                time.perf_counter() < deadline:
            time.sleep(0.005)
        st = dict(svc.stats)
        assert st["devices_lost"] == 1
        assert st["recoveries"] >= 1
        assert svc._sharded_ex.devices[0] is not dead
        launches0 = st["launches"]
        again = np.arange(64, 128)
        np.testing.assert_array_equal(
            svc.result(svc.submit(again), timeout=60),
            _reference(t, fs, [again])[0])
        assert svc.stats["launches"] > launches0   # device path is back
        assert svc.throughput_stats(1.0)["availability"] == 1.0


# -- supervised pump restart ---------------------------------------------------------
def test_pump_restart_survives_infrastructure_crash(monkeypatch):
    """ONE pump-infrastructure exception no longer poisons the service:
    the supervisor restarts the pump with the ledger intact, queued and
    re-enqueued work completes bit-exact, and only the restart budget
    separates this from the terminal path the _dying_service tests pin."""
    t, fs = _mixed_table()
    rng = np.random.default_rng(23)
    requests = [rng.integers(0, 3000, rng.integers(8, 64))
                for _ in range(10)]
    want = _reference(t, fs, requests)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1) as svc:
        svc.result(svc.submit(np.arange(0, 32)), timeout=60)   # warm
        orig = svc._pick_action
        state = {"fired": False}

        def crash_once():
            if not state["fired"]:
                state["fired"] = True
                raise RuntimeError("injected pump-infrastructure crash")
            return orig()
        monkeypatch.setattr(svc, "_pick_action", crash_once)
        tickets = [svc.submit(r) for r in requests]
        got = [svc.result(tk, timeout=60) for tk in tickets]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        assert svc.stats["pump_restarts"] == 1
        assert svc.stats["failed_tickets"] == 0
        # and the restarted pump is a full citizen: drain/collect work
        svc.drain(timeout=60)


def test_pump_restart_reenqueues_partially_retired_flight(monkeypatch):
    """A crash INSIDE _retire (after the flight left the launch queue)
    must not strand its chunks: the retire journal re-enqueues exactly
    the unretired remainder, the relaunch retires it, and every ticket
    resolves bit-exact — the restart is invisible to clients."""
    t, fs = _mixed_table()
    rng = np.random.default_rng(29)
    requests = [rng.integers(0, 3000, rng.integers(8, 64))
                for _ in range(8)]
    want = _reference(t, fs, requests)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1) as svc:
        svc.result(svc.submit(np.arange(0, 32)), timeout=60)   # warm
        orig = svc._retire
        state = {"fired": False}

        def crash_once(arr, parts):
            if not state["fired"]:
                state["fired"] = True
                raise RuntimeError("injected crash mid-retire")
            return orig(arr, parts)
        monkeypatch.setattr(svc, "_retire", crash_once)
        tickets = [svc.submit(r) for r in requests]
        got = [svc.result(tk, timeout=60) for tk in tickets]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        assert svc.stats["pump_restarts"] == 1
        assert svc.stats["failed_tickets"] == 0


# -- speculative hedged launches -----------------------------------------------------
def test_hedged_launch_beats_stalled_primary():
    """A launch whose retire wait crosses the hedge cutoff gets a
    duplicate on the shard's other healthy stream; the duplicate retires
    FIRST (the primary is stalled), resolves the tickets bit-exact, and
    the straggler's eventual buffer is discarded without double-counting.
    Latency: the ticket completes in ~the hedge cutoff, far under the
    stall."""
    t, fs = _mixed_table()
    inj = FaultInjector()
    pol = FaultPolicy(hedge=True, hedge_min_s=0.02, hedge_factor=2.0,
                      straggler_min_s=10.0, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        svc.add_replica(0)
        rows = np.arange(0, 64)
        for _ in range(10):                        # warm EWMA past warmup
            svc.result(svc.submit(rows), timeout=60)
        completed0 = svc.stats["completed"]
        inj.stall_launches(0.6, 1, shard=0)        # next primary launch
        t0 = time.perf_counter()
        out = svc.result(svc.submit(rows), timeout=60)
        dt = time.perf_counter() - t0
        np.testing.assert_array_equal(out, _reference(t, fs, [rows])[0])
        st = dict(svc.stats)
        assert st["hedges"] >= 1
        assert st["hedge_wins"] >= 1
        assert dt < 0.5                            # did not ride the stall
        assert st["completed"] == completed0 + 1   # no double-count
        assert st["failed_tickets"] == 0


def test_no_hedge_policy_rides_out_the_stall():
    """hedge=False is the control: the same stall is simply waited out
    (that contrast is what the hedged serving benchmark measures)."""
    t, fs = _mixed_table()
    inj = FaultInjector()
    pol = FaultPolicy(hedge=False, straggler_min_s=10.0, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        svc.add_replica(0)
        rows = np.arange(0, 64)
        for _ in range(10):
            svc.result(svc.submit(rows), timeout=60)
        inj.stall_launches(0.3, 1, shard=0)
        t0 = time.perf_counter()
        out = svc.result(svc.submit(rows), timeout=60)
        dt = time.perf_counter() - t0
        np.testing.assert_array_equal(out, _reference(t, fs, [rows])[0])
        assert dt >= 0.28                          # rode the stall
        assert svc.stats["hedges"] == 0


# -- refresh() racing stream loss ----------------------------------------------------
def test_replica_lost_between_refresh_and_reput_resyncs_lazily():
    """A stream that fails BETWEEN plan.refresh() and its version-keyed
    re-put must not serve stale words: the failed launch fails over to a
    stream that re-puts first (bit-exact vs the refreshed reference), and
    once the faulted stream heals, its own next launch performs the lazy
    re-sync — also bit-exact."""
    t, fs = _mixed_table(n=1400, imcu_rows=700)
    plan_p = FeaturePlan(t, fs, packed=True)
    plan_i = FeaturePlan(t, fs)                    # refreshed ground truth
    ref_ex = FeatureExecutor(plan_i)
    pol = FaultPolicy(max_retries=4, backoff_s=0.001, breaker_fails=100)
    inj = FaultInjector()
    with FeatureService(plan_p, sharded=True, buckets=(64,), coalesce=1,
                        faults=inj, fault_policy=pol) as svc:
        svc.add_replica(0)
        rows = np.arange(8, 56)
        for _ in range(4):                         # both streams resident
            svc.result(svc.submit(rows), timeout=60)
        new = {"age": t["age"].dictionary.add_rows(np.array([150])),
               "state": t["state"].dictionary.add_rows(np.array(["TX"])),
               "income": t["income"].dictionary.add_rows(
                   np.array([1_234_000]))}
        plan_p.refresh(new)
        plan_i.refresh(new)
        # the next shard-0 launch dies before it can re-put its words
        inj.fail_launches(1, shard=0)
        want = np.asarray(ref_ex.batch(rows))
        np.testing.assert_array_equal(
            svc.result(svc.submit(rows), timeout=60), want)
        assert svc.stats["failovers"] > 0
        # the healed stream's own next launches lazily re-sync: serve
        # enough that round-robin touches BOTH streams post-refresh
        for _ in range(4):
            np.testing.assert_array_equal(
                svc.result(svc.submit(rows), timeout=60), want)
        assert svc.stats["failed_tickets"] == 0


# -- breaker hygiene (regression: table leak + gauge) --------------------------------
def test_drop_replica_discards_breaker_entry():
    """_breakers is keyed by stream token and cleaned on drop: dropping a
    replica removes exactly its entry (the old id()-keyed table leaked
    one entry per dropped stream and could alias a recycled id onto a
    NEW stream's state)."""
    t, fs = _mixed_table(n=1400, imcu_rows=700)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1) as svc:
        svc.add_replica(0)
        dropped_tok = svc._sharded_ex.replicas[0][-1].stream_token
        for _ in range(4):                         # traffic on both streams
            svc.result(svc.submit(np.arange(0, 32)), timeout=60)
        assert dropped_tok in svc._breakers
        svc.drop_replica(0)
        assert dropped_tok not in svc._breakers
        live = {ex.stream_token
                for s in range(svc.n_shards)
                for ex in svc._sharded_ex.stream_executors(s)}
        assert set(svc._breakers) <= live
        # and the drop never underflows the unhealthy gauge
        assert svc.stats["unhealthy_shards"] == 0


def test_unhealthy_shards_is_a_gauge():
    """unhealthy_shards DECREMENTS when the probe closes a breaker — it
    reports streams unhealthy NOW, not trips ever."""
    t, fs = _mixed_table()
    inj = FaultInjector().fail_launches(2, shard=0, stream=0)
    pol = FaultPolicy(max_retries=5, backoff_s=0.001, breaker_fails=2,
                      breaker_cooldown_s=0.05)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), coalesce=1, faults=inj,
                        fault_policy=pol) as svc:
        svc.result(svc.submit(np.arange(0, 32)), timeout=60)
        assert svc.stats["unhealthy_shards"] == 1  # open: gauge holds
        time.sleep(0.06)                           # cooldown -> half-open
        svc.result(svc.submit(np.arange(0, 32)), timeout=60)  # probe
        assert svc.stats["unhealthy_shards"] == 0  # closed: gauge returns
        b = svc._breakers[svc._sharded_ex.executors[0].stream_token]
        assert b.opened == 1 and b.fails == 0


# -- seeded randomized sweep (nightly sets CHAOS_SWEEP_SEEDS high) -------------------
@pytest.mark.parametrize("seed",
                         range(int(os.environ.get("CHAOS_SWEEP_SEEDS", 2))))
def test_chaos_random_sweep_with_replicas_never_loses_a_ticket(seed):
    """Random faults + delays (seeded) against a fully replicated shard
    set: with a healthy stream always available and retries > expected
    consecutive faults, EVERY ticket must complete bit-exact."""
    t, fs = _mixed_table(n=2100, imcu_rows=700, seed=seed)
    rng = np.random.default_rng(100 + seed)
    requests = [rng.integers(0, 2100, rng.integers(4, 80))
                for _ in range(30)]
    want = _reference(t, fs, requests)
    inj = FaultInjector(seed=seed).random_faults(p_fail=0.25, p_delay=0.05,
                                                 delay_s=0.01)
    pol = FaultPolicy(max_retries=6, backoff_s=0.001, breaker_fails=4,
                      breaker_cooldown_s=0.02)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64, 256), faults=inj,
                        fault_policy=pol) as svc:
        for s in range(svc.n_shards):
            svc.add_replica(s)
        tickets = [svc.submit(r) for r in requests]
        got = [svc.result(tk, timeout=120) for tk in tickets]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    st = svc.throughput_stats(1.0)
    assert st["availability"] == 1.0
    assert inj.faults_injected > 0


@pytest.mark.parametrize("seed",
                         range(int(os.environ.get("CHAOS_SWEEP_SEEDS", 2))))
def test_chaos_sweep_device_loss_mid_traffic(seed):
    """Random faults PLUS a device killed mid-run: the first wave serves
    normally, then a device (seed-chosen) dies and the second wave rides
    eviction + rebuild-or-host-gather. No ticket is ever lost and every
    result stays bit-exact — the device-loss acceptance bar under the
    same randomized schedule the nightly lane widens."""
    import jax
    t, fs = _mixed_table(n=2100, imcu_rows=700, seed=seed)
    rng = np.random.default_rng(300 + seed)
    wave1 = [rng.integers(0, 2100, rng.integers(4, 80)) for _ in range(10)]
    wave2 = [rng.integers(0, 2100, rng.integers(4, 80)) for _ in range(15)]
    want1 = _reference(t, fs, wave1)
    want2 = _reference(t, fs, wave2)
    inj = FaultInjector(seed=seed).random_faults(p_fail=0.1, p_delay=0.05,
                                                 delay_s=0.01)
    pol = FaultPolicy(max_retries=8, backoff_s=0.001, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64, 256), faults=inj,
                        fault_policy=pol) as svc:
        for g, w in zip((svc.result(svc.submit(r), timeout=120)
                         for r in wave1), want1):
            np.testing.assert_array_equal(g, w)
        # kill a device that actually HOLDS a shard (on a wide mesh
        # some devices are empty and their loss is unobservable)
        devs = svc._sharded_ex.devices
        inj.kill_device(devs[seed % len(devs)])
        tickets = [svc.submit(r) for r in wave2]
        got = [svc.result(tk, timeout=120) for tk in tickets]
    for g, w in zip(got, want2):
        np.testing.assert_array_equal(g, w)
    st = svc.throughput_stats(1.0)
    assert st["availability"] == 1.0
    assert st["failed_tickets"] == 0
    assert st["devices_lost"] >= 1


@pytest.mark.parametrize("seed",
                         range(int(os.environ.get("CHAOS_SWEEP_SEEDS", 2))))
def test_chaos_tier_transitions_with_device_loss(seed):
    """Tier transitions racing faults AND a device kill: shards are demoted
    down the ladder (host-warm, RLE-cold) mid-traffic, a seed-chosen device
    dies, and promotions are requested while launches still carry injected
    faults. Demoted shards must keep host-serving through the loss (they
    skip rebuild entirely), a promotion whose home device died rebuilds on
    a survivor (or stays warm when none exists — a 1-device process), and
    every ticket lands bit-exact with availability 1.0."""
    import jax
    t, fs = _mixed_table(n=2100, imcu_rows=700, seed=seed)
    rng = np.random.default_rng(700 + seed)
    wave1 = [rng.integers(0, 2100, rng.integers(4, 80)) for _ in range(8)]
    wave2 = [rng.integers(0, 2100, rng.integers(4, 80)) for _ in range(15)]
    want1 = _reference(t, fs, wave1)
    want2 = _reference(t, fs, wave2)
    inj = FaultInjector(seed=seed).random_faults(p_fail=0.1, p_delay=0.05,
                                                 delay_s=0.01)
    pol = FaultPolicy(max_retries=8, backoff_s=0.001, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64, 256), faults=inj,
                        fault_policy=pol) as svc:
        for g, w in zip((svc.result(svc.submit(r), timeout=120)
                         for r in wave1), want1):
            np.testing.assert_array_equal(g, w)
        svc.demote(0, "cold")                   # closed shard: runs only
        svc.demote(1, "warm")
        assert svc.tiers[:2] == ["cold", "warm"]
        # kill a device that actually HOLDS a shard (on a wide mesh
        # some devices are empty and their loss is unobservable)
        devs = svc._sharded_ex.devices
        inj.kill_device(devs[seed % len(devs)])
        tickets = [svc.submit(r) for r in wave2]
        # promotions race the faulted/killed traffic on the pump
        svc.promote(1)
        svc.promote(0)
        got = [svc.result(tk, timeout=120) for tk in tickets]
        for g, w in zip(got, want2):
            np.testing.assert_array_equal(g, w)
        # post-loss steady state: every tier still serves bit-exact
        again = rng.integers(0, 2100, 200)
        np.testing.assert_array_equal(
            svc.result(svc.submit(again), timeout=120),
            _reference(t, fs, [again])[0])
        assert (svc.stats["tier_hot"] + svc.stats["tier_warm"]
                + svc.stats["tier_cold"]) == svc.n_shards
    st = svc.throughput_stats(1.0)
    assert st["availability"] == 1.0
    assert st["failed_tickets"] == 0
    assert st["devices_lost"] >= 1
    assert svc.stats["demotions"] >= 2
