"""Tiered residency: HBM-hot / host-warm / RLE-cold shard ladder.

Layers under test, bottom-up:

- ``DeviceBudget`` ledger semantics (``distributed/sharding.py``);
- ``_PackedShardPlan`` cold round-trips (``demote_cold``/``rehydrate``) and
  ``FeatureExecutor`` residency accounting (``commit=False``, ``evict_words``);
- ``ShardedFeatureExecutor(hbm_budget_bytes=...)`` budget-gated commits;
- ``FeatureService`` tier transitions: warm shards host-serve bit-exact
  while the monitor promotes hot traffic and demotes idle residents, the
  device byte budget is never exceeded, and explicit ``demote``/``promote``
  admin ops interleave safely with serving.

The invariant everywhere mirrors the sharded-serving suite: tiering changes
WHERE bytes live, never the math — every ticket is bit-exact against the
unsharded reference. A seeded sweep is keyed by ``TIER_SWEEP_SEEDS``
(nightly sets it high; the default keeps tier-1 quick).
"""
import os

import numpy as np
import pytest

from repro.columnar import Table
from repro.core import (FeatureSet, FeaturePipeline, FeaturePlan,
                        FeatureExecutor, ShardedFeatureExecutor)
from repro.distributed.sharding import DeviceBudget
from repro.serve import FeatureService

N_SEEDS = int(os.environ.get("TIER_SWEEP_SEEDS", "2"))


def _mixed_table(n=3000, imcu_rows=700, seed=0):
    rng = np.random.default_rng(seed)
    t = Table.from_data({
        "age": rng.integers(18, 80, n),
        "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
        "income": rng.integers(20, 200, n) * 1000,
    }, imcu_rows=imcu_rows)
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    return t, fs


# -- DeviceBudget ledger -------------------------------------------------------------
def test_device_budget_semantics():
    b = DeviceBudget(100)
    assert b.fits(1, 100) and not b.fits(1, 101)
    b.charge(1, 60)
    b.charge(2, 40)
    assert b.bytes(1) == 60 and b.bytes(2) == 40 and b.bytes(3) == 0
    assert b.headroom(1) == 40
    assert b.fits(1, 40) and not b.fits(1, 41)
    b.release(1, 20)
    assert b.bytes(1) == 40
    with pytest.raises(ValueError):
        b.release(1, 41)                        # underflow is a bug
    b.charge(2, 70)                             # charge may overshoot...
    assert b.over_budget() == {2: 10}           # ...but the ledger says so
    # budget=None disables enforcement entirely
    free = DeviceBudget(None)
    free.charge(1, 1 << 40)
    assert free.fits(1, 1 << 40) and free.headroom(1) is None
    assert free.over_budget() == {}


# -- shard-plan cold tier ------------------------------------------------------------
def test_shard_plan_cold_roundtrip():
    t, fs = _mixed_table()
    plan = FeaturePlan(t, fs, packed=True)
    shards = plan.imcu_shards()
    sp = shards[1]
    ref = sp.host_codes(np.arange(sp.n_rows))
    assert not sp.is_cold and sp.rle_bytes() == 0
    held = sp.demote_cold()
    assert sp.is_cold and held == sp.rle_bytes() > 0
    assert sp.demote_cold() == held             # idempotent
    # host reads stay bit-exact straight from the runs
    np.testing.assert_array_equal(sp.host_codes(np.arange(sp.n_rows)), ref)
    rng = np.random.default_rng(3)
    rows = rng.integers(0, sp.n_rows, 200)
    np.testing.assert_array_equal(sp.host_codes(rows), ref[:, rows])
    # _shard_words repacks on demand, so a device commit works while cold
    words = sp._shard_words(0)
    assert words.dtype == np.uint32
    sp.rehydrate()
    assert not sp.is_cold and sp.rle_bytes() == 0
    assert sp.stats["rehydrated"] >= 1
    np.testing.assert_array_equal(sp.host_codes(np.arange(sp.n_rows)), ref)
    # the open tail refuses cold: appends would stale the runs
    with pytest.raises(ValueError):
        shards[-1].demote_cold()


def test_executor_residency_accounting():
    t, fs = _mixed_table(n=1400, imcu_rows=1400)
    plan = FeaturePlan(t, fs, packed=True)
    ref = plan.host_features(np.arange(64))
    ex = FeatureExecutor(plan, commit=False)
    assert ex.resident_bytes() == 0
    need = ex.stream_nbytes()
    assert need > 0
    ex.ensure_range_capacity(plan.n_rows)
    np.testing.assert_array_equal(np.asarray(ex.batch(np.arange(64))), ref)
    assert ex.resident_bytes() == ex.stream_nbytes() > 0
    freed = ex.evict_words()
    assert freed > 0 and ex.resident_bytes() == 0
    assert ex.stream_nbytes() == need           # projection survives eviction
    # next launch re-puts through the version-keyed sync, bit-exact
    np.testing.assert_array_equal(np.asarray(ex.batch(np.arange(64))), ref)
    assert ex.resident_bytes() > 0


def test_sharded_executor_budget_gates_commits():
    t, fs = _mixed_table()
    plan = FeaturePlan(t, fs, packed=True)
    full = ShardedFeatureExecutor(FeaturePlan(t, fs, packed=True))
    per_shard = [e.stream_nbytes() for e in full.executors]
    # budget below the first shard's stream: nothing commits anywhere
    sx = ShardedFeatureExecutor(plan, hbm_budget_bytes=1)
    assert all(e.resident_bytes() == 0 for e in sx.executors)
    assert sx.device_bytes() == {} or \
        all(v == 0 for v in sx.device_bytes().values())
    # budget for exactly one shard per device: earlier shards win, and the
    # live device bytes never exceed the budget
    budget = max(per_shard)
    sx2 = ShardedFeatureExecutor(FeaturePlan(t, fs, packed=True),
                                 hbm_budget_bytes=budget)
    assert any(e.resident_bytes() > 0 for e in sx2.executors)
    assert all(v <= budget for v in sx2.device_bytes().values())
    ledger = sx2.budget_ledger()
    assert ledger.over_budget() == {}
    # no budget -> everything resident (the pre-tiering behaviour)
    assert all(e.resident_bytes() > 0 for e in full.executors)


# -- FeatureService tier transitions -------------------------------------------------
def _budget_one_stream(t, fs):
    """Byte budget that fits the largest single shard stream exactly."""
    sx = ShardedFeatureExecutor(FeaturePlan(t, fs, packed=True))
    return max(e.stream_nbytes() for e in sx.executors)


def test_service_all_warm_serves_bitexact():
    """budget=1: nothing fits on device, every shard host-serves — misses
    count, availability stays 1.0, outputs are bit-exact."""
    t, fs = _mixed_table()
    pipe = FeaturePipeline(t, fs)
    rng = np.random.default_rng(11)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        hbm_budget_bytes=1, buckets=(64,),
                        max_replicas=0) as svc:
        assert all(tr != "hot" for tr in svc.tiers)
        reqs = [rng.integers(0, 3000, 128) for _ in range(12)]
        tickets = [svc.submit(r) for r in reqs]
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(svc.result(tk),
                                          np.asarray(pipe.batch(r)))
        st = svc.stats
        assert st["host_gathers"] > 0 and st["tier_misses"] > 0
        assert st["promotions"] == 0            # nothing can ever fit
        assert all(v == 0 for v in svc.device_bytes().values())
        assert (st["tier_hot"] + st["tier_warm"] + st["tier_cold"]
                == svc.n_shards)


def test_monitor_promotes_hot_and_demotes_idle():
    """One-stream budget + skewed traffic at a warm shard: the monitor
    promotes it (displacing colder residents when its device is full), an
    idle warm shard ages to cold, the budget holds at every observation
    point, and every ticket is bit-exact. Shards are demoted explicitly up
    front so the scenario is identical at any process device count (on a
    wide mesh every shard fits its own device and starts hot)."""
    t, fs = _mixed_table()
    pipe = FeaturePipeline(t, fs)
    budget = _budget_one_stream(t, fs)
    rng = np.random.default_rng(12)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        hbm_budget_bytes=budget + 1, buckets=(64,),
                        rebalance_every=4, cold_after=2,
                        max_replicas=0) as svc:
        tail = svc.n_shards - 1
        svc.demote(tail, "warm")                 # the shard we will hammer
        svc.demote(1, "warm")                    # idle: should age to cold
        base_demotions = svc.stats["demotions"]
        # hammer the (now warm) tail shard
        tail_lo = 700 * (svc.n_shards - 1)
        reqs = [np.sort(rng.integers(tail_lo, 3000, 64)) for _ in range(40)]
        tickets, outs = [], {}
        for i, r in enumerate(reqs):
            tickets.append(svc.submit(r))
            if i % 8 == 7:
                outs.update(svc.drain())
                assert all(v <= budget + 1
                           for v in svc.device_bytes().values())
        outs.update(svc.drain())
        for r, tk in zip(reqs, tickets):
            np.testing.assert_array_equal(outs[tk], np.asarray(pipe.batch(r)))
        st = svc.stats
        assert st["promotions"] >= 1, f"tiers={svc.tiers} stats={st}"
        # the idle warm shard aged to cold under the monitor
        assert st["demotions"] > base_demotions, \
            f"tiers={svc.tiers} stats={st}"
        assert svc.tiers[1] == "cold", f"tiers={svc.tiers} stats={st}"
        assert svc.tiers[tail] == "hot"
        assert all(v <= budget + 1 for v in svc.device_bytes().values())
        assert (st["tier_hot"] + st["tier_warm"] + st["tier_cold"]
                == svc.n_shards)
        assert st["tier_hot"] == sum(1 for x in svc.tiers if x == "hot")


def test_explicit_demote_promote_roundtrip():
    t, fs = _mixed_table()
    pipe = FeaturePipeline(t, fs)
    rng = np.random.default_rng(13)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        buckets=(64,), max_replicas=0) as svc:
        assert svc.tiers == ["hot"] * svc.n_shards   # no budget: all hot
        rows = np.arange(700, 764)                   # shard 1 only
        base = np.asarray(pipe.batch(rows))
        freed = svc.demote(1, "warm")
        assert freed > 0 and svc.tiers[1] == "warm"
        np.testing.assert_array_equal(svc.result(svc.submit(rows)), base)
        # warm -> cold drops the host packed copy too
        svc.demote(1, "cold")
        assert svc.tiers[1] == "cold"
        np.testing.assert_array_equal(svc.result(svc.submit(rows)), base)
        assert svc.promote(1) and svc.tiers[1] == "hot"
        assert svc.stats["rehydrations"] >= 1
        np.testing.assert_array_equal(svc.result(svc.submit(rows)), base)
        assert svc.promote(1)                        # idempotent
        assert svc.stats["demotions"] == 2
        with pytest.raises(ValueError):
            svc.demote(svc.n_shards - 1, "cold")     # open tail stays warm+
        with pytest.raises(ValueError):
            svc.demote(0, "lukewarm")
        # scattered traffic over all tiers stays bit-exact
        r = rng.integers(0, 3000, 300)
        np.testing.assert_array_equal(svc.result(svc.submit(r)),
                                      np.asarray(pipe.batch(r)))


def test_demoted_shard_serves_through_refresh():
    """Appends land in the open tail while other shards sit warm/cold; the
    demoted shards keep serving the enlarged table bit-exact."""
    t, fs = _mixed_table(n=2000, imcu_rows=800)
    pipe = FeaturePipeline(t, fs)
    plan_p = FeaturePlan(t, fs, packed=True)
    with FeatureService(plan_p, sharded=True, buckets=(64,),
                        max_replicas=0) as svc:
        svc.demote(0, "cold")
        svc.demote(1, "warm")
        assert svc.tiers[0] == "cold" and svc.tiers[1] == "warm"
        new = {"age": t["age"].dictionary.add_rows(np.array([150, 151])),
               "state": t["state"].dictionary.add_rows(
                   np.array(["CA", "OR"])),
               "income": t["income"].dictionary.add_rows(
                   np.array([40000, 60000]))}
        plan_p.refresh(new)
        pipe.plan.refresh(new)
        mixed = np.array([0, 799, 800, 1999, 2000, 2001])
        np.testing.assert_array_equal(svc.result(svc.submit(mixed)),
                                      np.asarray(pipe.batch(mixed)))
        # the monitor may already have promoted the loaded shards back
        # (self-healing under no budget); promote() is idempotent either way
        assert svc.promote(0)
        np.testing.assert_array_equal(svc.result(svc.submit(mixed)),
                                      np.asarray(pipe.batch(mixed)))


def test_tiered_stats_validation():
    t, fs = _mixed_table(n=1400, imcu_rows=700)
    with pytest.raises(ValueError):
        FeatureService(FeaturePlan(t, fs, packed=True),
                       hbm_budget_bytes=1 << 20)     # needs sharded+packed
    with pytest.raises(ValueError):
        FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                       hbm_budget_bytes=1 << 20, cold_after=0)
    with pytest.raises(ValueError):
        FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                       host_gather_workers=0)


# -- seeded chaos sweep (nightly sets TIER_SWEEP_SEEDS high) -------------------------
@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_tier_chaos_sweep(seed):
    """Randomized promote/demote admin ops interleaved with skewed serving:
    no ticket is ever dropped, every result is bit-exact, the budget holds,
    and the tier gauges stay consistent."""
    rng = np.random.default_rng(100 + seed)
    t, fs = _mixed_table(seed=seed)
    pipe = FeaturePipeline(t, fs)
    budget = _budget_one_stream(t, fs)
    with FeatureService(FeaturePlan(t, fs, packed=True), sharded=True,
                        hbm_budget_bytes=budget + 1, buckets=(64,),
                        rebalance_every=3, cold_after=2,
                        max_replicas=0) as svc:
        closed = [s for s in range(svc.n_shards) if s != svc.n_shards - 1]
        pending: list[tuple[np.ndarray, int]] = []
        for op in range(30):
            r = np.sort(rng.integers(0, 3000, int(rng.integers(16, 128))))
            pending.append((r, svc.submit(r)))
            k = rng.integers(0, 5)
            if k == 0:
                svc.demote(int(rng.choice(closed)),
                           "cold" if rng.integers(0, 2) else "warm")
            elif k == 1:
                svc.promote(int(rng.integers(0, svc.n_shards)))
            if op % 10 == 9:
                out = svc.drain()
                assert {tk for _, tk in pending} <= set(out)
                for r, tk in pending:
                    np.testing.assert_array_equal(out[tk],
                                                  np.asarray(pipe.batch(r)))
                pending.clear()
                assert all(v <= budget + 1
                           for v in svc.device_bytes().values())
        st = svc.stats
        assert (st["tier_hot"] + st["tier_warm"] + st["tier_cold"]
                == svc.n_shards)
        assert st["failed_tickets"] == 0
