"""Unit/property tests for model primitives: attention, GLA core, MoE."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.models.attention import attention
from repro.models.gla import chunked_gla, gla_ref, gla_step
from repro.models.moe import moe_ff, route, capacity
from repro.models.layers import apply_rope, rms_norm


# -- attention -------------------------------------------------------------------
def _qkv(rng, b, s, h, kv, dh, t=None):
    t = t or s
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, kv, dh)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 1)])
def test_blockwise_attention_matches_direct(h, kv):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 2, 64, h, kv, 16)
    direct = attention(q, k, v, q_offset=0, kv_chunk=64)       # direct path
    blocked = attention(q, k, v, q_offset=0, kv_chunk=16)      # 4 chunks
    np.testing.assert_allclose(np.asarray(direct), np.asarray(blocked),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_sliding_window_matches_direct():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 1, 64, 4, 2, 8)
    direct = attention(q, k, v, q_offset=0, window=7, kv_chunk=64)
    blocked = attention(q, k, v, q_offset=0, window=7, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(blocked),
                               rtol=2e-5, atol=2e-5)


def test_attention_causality():
    """Changing future keys must not change past outputs."""
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 1, 32, 4, 4, 8)
    out1 = attention(q, k, v, q_offset=0)
    k2 = k.at[:, 20:].set(rng.standard_normal((1, 12, 4, 8)))
    v2 = v.at[:, 20:].set(rng.standard_normal((1, 12, 4, 8)))
    out2 = attention(q, k2, v2, q_offset=0)
    np.testing.assert_allclose(np.asarray(out1[:, :20]),
                               np.asarray(out2[:, :20]), rtol=1e-5, atol=1e-5)


def test_attention_kv_len_mask():
    """Decode: entries beyond kv_len are invisible."""
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 2, 1, 4, 2, 8, t=32)
    out1 = attention(q, k, v, q_offset=10, kv_len=11)
    k2 = k.at[:, 11:].set(999.0)
    v2 = v.at[:, 11:].set(999.0)
    out2 = attention(q, k2, v2, q_offset=10, kv_len=11)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# -- GLA core ----------------------------------------------------------------------
@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (48, 48), (33, 3)])
def test_chunked_gla_matches_sequential(s, chunk):
    rng = np.random.default_rng(s)
    b, h, dk, dv = 2, 3, 8, 5
    q = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((b, s, h, dv)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.standard_normal((b, s, h))) * 0.2)
    out_c, st_c = chunked_gla(q, k, v, log_a, chunk=chunk)
    out_r, st_r = gla_ref(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10_000), st.integers(1, 6))
@settings(max_examples=15, deadline=None)
def test_gla_step_composition_property(seed, steps):
    """N single steps == one chunked pass over N tokens."""
    rng = np.random.default_rng(seed)
    b, h, dk, dv = 1, 2, 4, 3
    s = steps * 2
    q = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((b, s, h, dv)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.standard_normal((b, s, h))) * 0.3)
    out_c, st_c = chunked_gla(q, k, v, log_a, chunk=s)
    state = jnp.zeros((b, h, dk, dv), jnp.float32)
    for t in range(s):
        state, o = gla_step(state, q[:, t], k[:, t], v[:, t], log_a[:, t])
        np.testing.assert_allclose(np.asarray(o), np.asarray(out_c[:, t]),
                                   rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st_c),
                               rtol=2e-4, atol=2e-4)


def test_gla_decay_zero_is_cumulative_sum():
    """a=1 (log_a=0): state is a plain sum of k vᵀ — sanity anchor."""
    rng = np.random.default_rng(0)
    b, s, h, dk, dv = 1, 8, 1, 3, 2
    q = jnp.asarray(np.eye(3)[None, [0] * s, None, :], jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dv)), jnp.float32)
    log_a = jnp.zeros((b, s, h))
    out, st = chunked_gla(q, k, v, log_a, chunk=4)
    want = np.einsum("bshk,bshv->bhkv", np.asarray(k), np.asarray(v))
    np.testing.assert_allclose(np.asarray(st), want, rtol=1e-5, atol=1e-5)


# -- MoE ---------------------------------------------------------------------------
def test_route_respects_capacity_and_gates():
    rng = np.random.default_rng(0)
    g, s, e, k = 2, 16, 4, 2
    cap = capacity(s, k, e, 1.0)
    logits = jnp.asarray(rng.standard_normal((g, s, e)), jnp.float32)
    dispatch, combine, aux, z = route(logits, k, e, cap)
    # each (expert, slot) holds at most one token
    per_slot = np.asarray(dispatch).sum(axis=1)           # (G,E,C)
    assert per_slot.max() <= 1.0 + 1e-6
    # each token occupies at most k slots
    per_tok = np.asarray(dispatch).sum(axis=(2, 3))
    assert per_tok.max() <= k + 1e-6
    # combine weights per token sum to <= 1 (=1 when nothing dropped)
    w = np.asarray(combine).sum(axis=(2, 3))
    assert w.max() <= 1.0 + 1e-5
    assert float(aux) > 0 and float(z) >= 0


def test_moe_ff_no_drop_equals_dense_mixture():
    """With huge capacity, MoE out == gate-weighted sum of expert MLPs."""
    rng = np.random.default_rng(1)
    g, s, d, f, e, k = 1, 6, 8, 16, 4, 2
    x = jnp.asarray(rng.standard_normal((g, s, d)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((d, e)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((e, d, f)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((e, d, f)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((e, f, d)) * 0.1, jnp.float32)
    out, aux, z = moe_ff(x, router, wg, wu, wd, top_k=k, cap_factor=8.0)

    probs = jax.nn.softmax(x @ router, axis=-1)
    gv, idx = jax.lax.top_k(probs, k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = np.zeros((g, s, d), np.float32)
    for gi in range(g):
        for si in range(s):
            for kk in range(k):
                eid = int(idx[gi, si, kk])
                h = jax.nn.silu(x[gi, si] @ wg[eid]) * (x[gi, si] @ wu[eid])
                want[gi, si] += float(gv[gi, si, kk]) * np.asarray(h @ wd[eid])
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """cap_factor -> tiny: overflowing tokens produce zero output, not junk."""
    rng = np.random.default_rng(2)
    g, s, d, f, e = 1, 16, 4, 8, 2
    x = jnp.asarray(rng.standard_normal((g, s, d)), jnp.float32)
    router = jnp.zeros((d, e), jnp.float32)  # all tokens tie -> same expert order
    wg = jnp.ones((e, d, f), jnp.float32) * 0.1
    wu = jnp.ones((e, d, f), jnp.float32) * 0.1
    wd = jnp.ones((e, f, d), jnp.float32) * 0.1
    out, _, _ = moe_ff(x, router, wg, wu, wd, top_k=1, cap_factor=0.25)
    norms = np.linalg.norm(np.asarray(out)[0], axis=-1)
    assert (norms[-4:] == 0).all()        # late tokens dropped
    assert (norms[:2] > 0).all()          # early tokens kept


# -- layers ---------------------------------------------------------------------------
def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 6, 2, 8)), jnp.float32)
    pos = jnp.arange(6)[None]
    y = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 8)), jnp.float32)
    def dot(m, n):
        qm = apply_rope(q, jnp.array([[m]]), 1e4)
        kn = apply_rope(k, jnp.array([[n]]), 1e4)
        return float(jnp.sum(qm * kn))
    assert abs(dot(3, 1) - dot(7, 5)) < 1e-4


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16)) * 10,
                    jnp.float32)
    y = rms_norm(x, jnp.ones(16))
    rms = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
