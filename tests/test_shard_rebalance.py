"""Randomized interleaving harness: adaptive shard management is bit-exact.

The adaptive shard manager mutates the serving layout while traffic is in
flight — streaming appends (``FeaturePlan.refresh``), tail re-shard at
aligned AND unaligned cuts, replica add/drop with read fan-out, and tier
transitions (demote to host-warm / RLE-cold, promote back). Every test
here drives seeded random interleavings of those mutations with
aligned-range and arbitrary-row serving and asserts BIT-exactness
(``assert_array_equal``) against the unsharded int32 host reference: a
layout mutation may move where a launch runs and which stream slice it
reads, never the math.

Sweep depth is environment-scaled: CI runs the smoke subset
(``REBALANCE_SWEEP_SEEDS`` unset -> 2 seeds per mode); a deep local sweep
is ``REBALANCE_SWEEP_SEEDS=10 pytest tests/test_shard_rebalance.py``.
"""
import os

import numpy as np
import pytest

from repro.columnar import Table
from repro.core import (FeatureSet, FeaturePlan, FeatureExecutor,
                        ShardedFeatureExecutor)
from repro.serve import FeatureService

BITS_SWEEP = (1, 2, 3, 4, 6, 8, 12, 16)
N_SEEDS = int(os.environ.get("REBALANCE_SWEEP_SEEDS", "2"))


def _column_data(rng, bits, n):
    """Integer column whose dictionary needs exactly ``bits`` bits."""
    k = 2 if bits == 1 else (1 << (bits - 1)) + 1
    base = np.arange(k)
    return np.concatenate([base, rng.integers(0, k, n - k)])


def _bits_table(rng, n=33024, imcu_rows=8256):
    """Bits 1-16 sweep table: every storage width class, 4 IMCU shards."""
    data = {f"c{b}": _column_data(rng, b, n) for b in BITS_SWEEP}
    table = Table.from_data(data, imcu_rows=imcu_rows)
    fs = FeatureSet()
    for b in BITS_SWEEP:
        fs = fs.add(f"c{b}", "zscore")
    return table, fs


def _mixed_table(rng, n=3000, imcu_rows=700):
    """Unaligned-seam table: 700 % 32 != 0, so shard starts sit mid-word."""
    table = Table.from_data({
        "age": rng.integers(18, 80, n),
        "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
        "income": rng.integers(20, 200, n) * 1000,
    }, imcu_rows=imcu_rows)
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    return table, fs


def _append(rng, table, plan_p, plan_i, columns, grow=False):
    """One streaming insert: add rows to every column's dictionary and
    refresh BOTH plans (packed adaptive + int32 reference) identically.
    ``grow=True`` injects novel values so dictionaries widen — small
    columns cross tpu_width boundaries and force stream repacks."""
    m = int(rng.integers(1, 160))
    new = {}
    for c in columns:
        d = table[c].dictionary
        vals = d.values[rng.integers(0, d.cardinality, m)]
        if grow and np.issubdtype(d.values.dtype, np.integer):
            fresh = int(d.values.max()) + 1 + np.arange(rng.integers(1, 5))
            vals = np.concatenate([vals, fresh.astype(d.values.dtype)])
        new[c] = d.add_rows(vals)
    lens = {len(v) for v in new.values()}
    if len(lens) > 1:                       # equalize (string columns)
        m = min(lens)
        new = {c: v[:m] for c, v in new.items()}
    plan_p.refresh(new)
    plan_i.refresh(new)


def _pick_cut(rng, sx):
    """A split point inside the open tail: word-aligned half the time,
    deliberately UNALIGNED otherwise (the seam-repack path must stay
    bit-exact too)."""
    start, stop = sx.shards[-1].shard_bounds
    if stop - start < 64:
        return None
    cut = int(rng.integers(start + 1, stop))
    if rng.random() < 0.5:
        cut = max(start + 32, cut // 32 * 32)
    return cut


def _random_request(rng, n_rows, sx):
    """Aligned range / arbitrary rows / boundary-straddle biased rows."""
    kind = rng.integers(0, 3)
    if kind == 0:                                       # aligned range
        m = int(rng.integers(1, 8)) * 32
        start = int(rng.integers(0, max((n_rows - m) // 32, 1))) * 32
        return np.arange(start, min(start + m, n_rows))
    rows = rng.integers(0, n_rows, int(rng.integers(16, 400)))
    if kind == 2:                                       # straddle the bounds
        starts = sx.starts[1:]
        if starts.size:
            edges = np.concatenate([starts - 1, starts,
                                    np.minimum(starts + 1, n_rows - 1)])
            rows = np.concatenate([rows, np.clip(edges, 0, n_rows - 1)])
    return rows


def _run_interleaving(seed, table, fs, via_service, n_ops=16):
    """One seeded interleaving of mutations and serving over one table."""
    rng = np.random.default_rng(seed)
    plan_p = FeaturePlan(table, fs, packed=True)
    plan_i = FeaturePlan(table, fs)
    ex_i = FeatureExecutor(plan_i)
    columns = plan_p.columns
    svc = sx = None
    if via_service:
        svc = FeatureService(plan_p, sharded=True, buckets=(64, 256),
                             coalesce=4)
        sx = svc._sharded_ex
    else:
        sx = ShardedFeatureExecutor(plan_p)
    pending = []                        # (rows, ticket) awaiting verification

    def verify_pending():
        for rows, tk in pending:
            np.testing.assert_array_equal(svc.result(tk),
                                          np.asarray(ex_i.batch(rows)))
        pending.clear()

    def serve_check():
        rows = _random_request(rng, plan_p.n_rows, sx)
        if via_service:
            pending.append((rows, svc.submit(rows)))
            if len(pending) > 4 or rng.random() < 0.4:
                verify_pending()
        else:
            np.testing.assert_array_equal(np.asarray(sx.batch(rows)),
                                          np.asarray(ex_i.batch(rows)))

    def mutate(kind):
        if kind == "split":
            cut = _pick_cut(rng, sx)
            if cut is None:
                return
            svc.split_tail(cut) if via_service else sx.split_tail(cut)
        elif kind == "replica_add":
            s = int(rng.integers(0, sx.n_shards))
            svc.add_replica(s) if via_service else sx.add_replica(s)
        elif kind == "replica_drop":
            cands = [s for s in range(sx.n_shards) if sx.replicas[s]]
            if not cands:
                return
            s = int(rng.choice(cands))
            svc.drop_replica(s) if via_service else sx.drop_replica(s)
        elif kind == "demote":
            s = int(rng.integers(0, sx.n_shards))
            # the open tail refuses cold (appends would stale the runs)
            tier = ("cold" if rng.random() < 0.5
                    and not sx.shards[s]._last else "warm")
            if via_service:
                svc.demote(s, tier)
            else:
                # bare-executor ladder: evict the primary's device words
                # (replicas keep serving hot — reads fan out regardless)
                sx.executors[s].evict_words()
                if tier == "cold":
                    sx.shards[s].demote_cold()
        elif kind == "promote":
            s = int(rng.integers(0, sx.n_shards))
            if via_service:
                svc.promote(s)
            else:
                sx.shards[s].rehydrate()
                sx.executors[s].ensure_range_capacity(sx.shards[s].n_rows)

    try:
        for _ in range(n_ops):
            op = rng.choice(["serve", "serve", "serve", "append", "split",
                             "replica_add", "replica_drop",
                             "demote", "promote"])
            if op == "serve":
                serve_check()
            elif op == "append":
                # refresh is not atomic w.r.t. in-flight requests (the
                # documented drain-before-refresh contract): settle first
                if via_service:
                    verify_pending()
                _append(rng, table, plan_p, plan_i, columns,
                        grow=rng.random() < 0.4)
                serve_check()
            elif via_service and rng.random() < 0.5:
                # chaos variant: mutate WITH chunks queued behind pause —
                # the routing swap must re-route them, not drop or reorder
                svc.pause()
                for _ in range(int(rng.integers(1, 4))):
                    rows = _random_request(rng, plan_p.n_rows, sx)
                    pending.append((rows, svc.submit(rows)))
                mutate(op)
                svc.resume()
                verify_pending()
            else:
                mutate(op)
        # deterministic epilogue: unaligned split of the tail, appends
        # landing in the freshly split tail, then a full serving sweep
        if via_service:
            verify_pending()
        start, stop = sx.shards[-1].shard_bounds
        if stop - start >= 70:
            cut = start + 33                   # never word-aligned
            svc.split_tail(cut) if via_service else sx.split_tail(cut)
        _append(rng, table, plan_p, plan_i, columns, grow=True)
        n = plan_p.n_rows
        tail_start = int(sx.starts[-1])
        sweep = [np.arange(0, min(n, 256)),
                 np.arange(max(0, n // 2 // 32 * 32), min(n, n // 2 + 128)),
                 np.arange(tail_start, n),      # the freshly split tail
                 rng.integers(0, n, 500)]
        for rows in sweep:
            if rows.size == 0:
                continue
            if via_service:
                pending.append((rows, svc.submit(rows)))
            else:
                np.testing.assert_array_equal(np.asarray(sx.batch(rows)),
                                              np.asarray(ex_i.batch(rows)))
        if via_service:
            verify_pending()
        assert sx.n_shards >= len(table[columns[0]].imcu_bounds())
    finally:
        if svc is not None:
            svc.shutdown()


# -- the randomized sweeps -----------------------------------------------------------
@pytest.mark.parametrize("seed", range(N_SEEDS))
@pytest.mark.parametrize("via_service", [False, True],
                         ids=["executor", "service"])
def test_interleaved_rebalance_bits_sweep(seed, via_service):
    """Seeded random interleavings over every storage width class 1-16:
    appends, splits (aligned + unaligned cuts), replica flips, and both
    serving patterns stay bit-exact vs the unsharded host reference."""
    rng = np.random.default_rng(1000 + seed)
    table, fs = _bits_table(rng)
    _run_interleaving(seed, table, fs, via_service)


@pytest.mark.parametrize("seed", range(N_SEEDS + 1))
def test_interleaved_rebalance_unaligned_seams(seed):
    """Same harness over a table whose IMCU rows (700) are word-UNALIGNED:
    every shard start sits mid-word, so splits/replicas exercise the
    seam-repack slices throughout."""
    rng = np.random.default_rng(2000 + seed)
    table, fs = _mixed_table(rng)
    _run_interleaving(seed, table, fs, via_service=(seed % 2 == 0))


# -- deterministic split coverage ----------------------------------------------------
def test_split_unaligned_cut_and_append_into_fresh_tail():
    """An unaligned cut (mid-word on every column) closes the old tail and
    opens a seam-repacked new tail; appends land in the fresh tail and
    serve bit-exact, including rows straddling the new boundary."""
    rng = np.random.default_rng(5)
    table, fs = _mixed_table(rng, n=2048, imcu_rows=512)
    plan_p = FeaturePlan(table, fs, packed=True)
    plan_i = FeaturePlan(table, fs)
    sx = ShardedFeatureExecutor(plan_p)
    ex_i = FeatureExecutor(plan_i)
    all_rows = np.arange(0, 2048, 3)
    np.testing.assert_array_equal(np.asarray(sx.batch(all_rows)),
                                  np.asarray(ex_i.batch(all_rows)))
    cut = 1536 + 17                                # 17: unaligned everywhere
    new = sx.split_tail(cut)
    assert new == 4 and sx.starts[-1] == cut
    assert sx.shards[3].shard_bounds == (1536, cut)
    assert sx.shards[4].shard_bounds == (cut, 2048)
    _append(rng, table, plan_p, plan_i, plan_p.columns, grow=True)
    assert sx.shards[4].shard_bounds[1] == plan_p.n_rows  # open-ended tail
    rows = np.concatenate([np.arange(cut - 40, min(cut + 40, plan_p.n_rows)),
                           np.arange(2040, plan_p.n_rows),
                           rng.integers(0, plan_p.n_rows, 300)])
    np.testing.assert_array_equal(np.asarray(sx.batch(rows)),
                                  np.asarray(ex_i.batch(rows)))


def test_split_proactive_at_stop_then_append():
    """cut == n_rows opens an EMPTY tail shard (proactive split): appends
    land there and serve; the closed shard keeps its full row range."""
    rng = np.random.default_rng(6)
    table, fs = _mixed_table(rng, n=1024, imcu_rows=512)
    plan_p = FeaturePlan(table, fs, packed=True)
    plan_i = FeaturePlan(table, fs)
    sx = ShardedFeatureExecutor(plan_p)
    ex_i = FeatureExecutor(plan_i)
    new = sx.split_tail(1024)
    assert sx.shards[new].n_rows == 0
    _append(rng, table, plan_p, plan_i, plan_p.columns)
    assert sx.shards[new].n_rows == plan_p.n_rows - 1024 > 0
    rows = np.concatenate([np.arange(1000, plan_p.n_rows),
                           rng.integers(0, plan_p.n_rows, 200)])
    np.testing.assert_array_equal(np.asarray(sx.batch(rows)),
                                  np.asarray(ex_i.batch(rows)))


def test_split_validation_contract():
    rng = np.random.default_rng(7)
    table, fs = _mixed_table(rng, n=1400, imcu_rows=700)
    plan_p = FeaturePlan(table, fs, packed=True)
    sx = ShardedFeatureExecutor(plan_p)
    tail = sx.shards[-1]
    with pytest.raises(ValueError):                # cut before tail start
        sx.split_tail(64)
    with pytest.raises(ValueError):                # cut past the end
        sx.split_tail(1401)
    with pytest.raises(ValueError):                # interior shards are closed
        plan_p.split_tail_shard(sx.shards[0], 350)
    sx.split_tail(1024)
    with pytest.raises(ValueError):                # tail already closed
        tail.close_at(1100)
    with pytest.raises(RuntimeError):              # int32 plans don't split
        FeaturePlan(table, fs).split_tail_shard(tail, 1024)


# -- stats continuity across shard-set changes (regression) --------------------------
def test_stats_continuity_across_split_and_replica():
    """Rollup loses nothing and double-counts nothing when the shard set
    changes: per_shard entries keep their identity (index = shard), the
    new shard APPENDS, replica puts attribute to their shard's entry, and
    the plan totals always equal the pre-shard baseline plus the sum of
    per-shard deltas."""
    rng = np.random.default_rng(8)
    table, fs = _mixed_table(rng, n=2048, imcu_rows=1024)
    plan_p = FeaturePlan(table, fs, packed=True)
    base = plan_p.stats["words_put"]               # pre-shard baseline
    sx = ShardedFeatureExecutor(plan_p)
    ids0 = [id(s.stats) for s in sx.shards]

    def check_rollup():
        per = plan_p.stats["per_shard"]
        assert per == [s.stats for s in sx.shards]
        assert plan_p.stats["words_put"] == \
            base + sum(s["words_put"] for s in per)

    np.asarray(sx.batch(np.arange(0, 2048, 5)))    # both shards put once
    check_rollup()
    sx.add_replica(1)                              # replica put -> shard 1
    np.asarray(sx.batch(np.arange(1024, 2048)))
    np.asarray(sx.batch(np.arange(1024, 2048)))    # fan-out hits the replica
    check_rollup()
    assert plan_p.stats["per_shard"][1]["words_put"] >= 2  # primary+replica
    new = sx.split_tail(1536)
    # serve twice so BOTH of the closed shard's streams (primary + replica)
    # re-put their truncated slices before the puts snapshot below
    np.asarray(sx.batch(np.arange(1500, 2048)))
    np.asarray(sx.batch(np.arange(1500, 2048)))
    check_rollup()
    per = plan_p.stats["per_shard"]
    assert len(per) == 3 and new == 2
    assert [id(s.stats) for s in sx.shards[:2]] == ids0   # stable identity
    assert per[2]["words_put"] >= 1                # new tail attributed
    # appends attribute to the OPEN tail only (interior shards untouched)
    puts = [s["words_put"] for s in per]
    _append(rng, table, plan_p, FeaturePlan(table, fs), plan_p.columns)
    np.asarray(sx.batch(np.arange(0, plan_p.n_rows, 7)))
    per2 = [s["words_put"] for s in plan_p.stats["per_shard"]]
    assert per2[2] == puts[2] + 1 and per2[:2] == puts[:2]
    check_rollup()


# -- replica mechanics ---------------------------------------------------------------
def test_replica_resync_after_refresh():
    """A write (refresh) invalidates every copy of the touched shard: both
    the primary and the replica re-put their streams lazily and keep
    serving bit-exact — the versioned-sync write fan-in."""
    rng = np.random.default_rng(9)
    table, fs = _mixed_table(rng, n=2048, imcu_rows=512)
    plan_p = FeaturePlan(table, fs, packed=True)
    plan_i = FeaturePlan(table, fs)
    sx = ShardedFeatureExecutor(plan_p)
    ex_i = FeatureExecutor(plan_i)
    sx.add_replica(3)                              # the open tail shard
    tail_rows = np.arange(1536, 2048)
    for _ in range(2):                             # hit primary AND replica
        np.testing.assert_array_equal(np.asarray(sx.batch(tail_rows)),
                                      np.asarray(ex_i.batch(tail_rows)))
    puts0 = plan_p.stats["per_shard"][3]["words_put"]
    _append(rng, table, plan_p, plan_i, plan_p.columns, grow=True)
    rows = np.concatenate([tail_rows, np.arange(2048, plan_p.n_rows)])
    for _ in range(2):                             # both streams re-synced
        np.testing.assert_array_equal(np.asarray(sx.batch(rows)),
                                      np.asarray(ex_i.batch(rows)))
    assert plan_p.stats["per_shard"][3]["words_put"] >= puts0 + 2


def test_replica_device_placement_rule():
    """replica_device picks the least-loaded pool device, avoids devices
    already holding the same shard, and stays deterministic on ties."""
    from repro.distributed.sharding import replica_device
    a, b, c = object(), object(), object()
    pool = [a, b, c]
    assert replica_device(pool, {}) is a                       # tie -> first
    assert replica_device(pool, {id(a): 2, id(b): 1, id(c): 3}) is b
    assert replica_device(pool, {id(a): 1, id(b): 1},
                          exclude={id(c)}) is a
    # every device excluded (shard already everywhere): least-loaded wins
    assert replica_device(pool, {id(a): 2, id(b): 1, id(c): 3},
                          exclude={id(a), id(b), id(c)}) is b
    with pytest.raises(ValueError):
        replica_device([], {})


def test_place_fused_reuse_for_replicas():
    """place_fused is idempotent per device, and executors sharing a device
    (a replica landing beside another shard) share ONE placed table set."""
    import jax
    from repro.kernels.adv_gather import ops as adv_ops
    rng = np.random.default_rng(10)
    table, fs = _mixed_table(rng, n=1024, imcu_rows=512)
    plan_p = FeaturePlan(table, fs, packed=True)
    fused = plan_p.fused_tables()
    dev = jax.devices()[0]
    placed = adv_ops.place_fused(fused, dev)
    assert adv_ops.place_fused(placed, dev) is placed          # no re-copy
    sx = ShardedFeatureExecutor(plan_p)
    ex = sx.add_replica(0, device=sx.executors[0].device)
    assert ex._tcache is sx.executors[0]._tcache   # shared per-device cache
