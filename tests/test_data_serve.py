"""Data pipeline (columnar token store) + serving engine tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.data import TokenStore, synthetic_corpus, token_batches
from repro.models import lm
from repro.serve import ServeEngine, Request


# -- TokenStore -----------------------------------------------------------------
def test_tokenstore_roundtrip_and_compression():
    corpus = synthetic_corpus(50_000, vocab=4099, seed=0)
    store = TokenStore(corpus, vocab=4099)
    assert store.bits == 13
    np.testing.assert_array_equal(store.get_span(1000, 64), corpus[1000:1064])
    assert store.packed_nbytes < 0.45 * store.raw_nbytes
    # count metadata == true histogram
    np.testing.assert_array_equal(store.counts,
                                  np.bincount(corpus, minlength=4099))
    assert 0 < store.entropy_bits() < 13


@given(st.integers(0, 1000), st.integers(1, 200), st.integers(0, 400))
@settings(max_examples=25, deadline=None)
def test_tokenstore_span_property(seed, length, start):
    corpus = synthetic_corpus(1000, vocab=97, seed=seed)
    store = TokenStore(corpus, vocab=97)
    length = min(length, 1000 - start)
    np.testing.assert_array_equal(store.get_span(start, length),
                                  corpus[start:start + length])


def test_tokenstore_device_unpack_path():
    corpus = synthetic_corpus(10_000, vocab=50, seed=1)
    store = TokenStore(corpus, vocab=50, device_unpack=True)
    assert store.device_bits == 8          # 6 -> TPU-aligned 8
    np.testing.assert_array_equal(store.get_span(123, 77), corpus[123:200])


def test_loader_restart_determinism():
    """Resuming at step k replays batch k exactly (fault-tolerance)."""
    cfg = reduced(get_config("qwen2-7b"))
    store = TokenStore(synthetic_corpus(10_000, cfg.vocab), cfg.vocab)
    it1 = token_batches(store, cfg, batch=4, seq=16, seed=7)
    batches = [next(it1) for _ in range(5)]
    it2 = token_batches(store, cfg, batch=4, seq=16, seed=7, start_step=3)
    b3 = next(it2)
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(b3["tokens"]))


def test_loader_labels_are_shifted():
    cfg = reduced(get_config("qwen2-7b"))
    store = TokenStore(synthetic_corpus(10_000, cfg.vocab), cfg.vocab)
    b = next(token_batches(store, cfg, batch=2, seq=16))
    # labels[t] == tokens[t+1] (verify against the store)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_loader_vlm_audio_frontends():
    for arch in ("llava-next-mistral-7b", "seamless-m4t-large-v2"):
        cfg = reduced(get_config(arch))
        store = TokenStore(synthetic_corpus(10_000, cfg.vocab), cfg.vocab)
        b = next(token_batches(store, cfg, batch=2, seq=16))
        if cfg.family == "vlm":
            assert b["patch_embeds"].shape == (2, cfg.n_patches,
                                               cfg.frontend_dim)
            assert (np.asarray(b["labels"][:, :cfg.n_patches]) == -1).all()
        else:
            assert b["frames"].shape == (2, 16, cfg.frontend_dim)


# -- ServeEngine -----------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced(get_config("glm4-9b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_batched_requests(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, batch_size=4, max_len=24)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=6) for _ in range(4)]
    done = eng.run_batch(reqs)
    for r in done:
        assert len(r.out_tokens) == 6
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_engine_greedy_matches_forward(engine_setup):
    """Engine greedy decode == argmax over the training forward (teacher
    forcing on its own outputs)."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, batch_size=1, max_len=16)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    req = eng.run_batch([Request(prompt=prompt, max_new_tokens=4)])[0]
    # replay with full forwards
    seq = list(prompt)
    for i in range(4):
        logits, _, _ = lm.forward(cfg, params,
                                  {"tokens": jnp.asarray([seq])})
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
        assert nxt == req.out_tokens[i], (i, nxt, req.out_tokens)
        seq.append(nxt)


def test_engine_eos_stops_early(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, batch_size=1, max_len=32)
    prompt = np.arange(4, dtype=np.int32)
    # discover the first greedy token, then use it as eos
    r1 = eng.run_batch([Request(prompt=prompt, max_new_tokens=3)])[0]
    eos = r1.out_tokens[0]
    r2 = eng.run_batch([Request(prompt=prompt, max_new_tokens=8,
                                eos_id=eos)])[0]
    assert r2.out_tokens[0] == eos and len(r2.out_tokens) == 1


def test_engine_temperature_sampling(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, batch_size=2, max_len=16,
                      temperature=1.5, seed=3)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    r = eng.run_batch([Request(prompt=prompt.copy(), max_new_tokens=8),
                       Request(prompt=prompt.copy(), max_new_tokens=8)])
    # with hot sampling the two identical prompts should diverge
    assert r[0].out_tokens != r[1].out_tokens
