"""Unit + property tests for the columnar substrate (paper §5/§6.2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.columnar import (bits_needed, pack_bits, unpack_bits, rle_encode,
                            rle_decode, Dictionary, Column, Table)
from repro.columnar.bitpack import unpack_bits_jnp, packed_nbytes
from repro.columnar.rle import rle_decode_jnp
from repro.columnar import stats, query


# -- Table 2 of the paper, verbatim -------------------------------------------
@pytest.mark.parametrize("cardinality,bits", [
    (2, 1), (4, 2), (5, 3), (12, 4), (50, 6), (150, 8),
    (195, 8), (366, 9), (999, 10), (99_999, 17), (524_288, 19),
])
def test_bits_needed_paper_table2(cardinality, bits):
    # Paper reports fractional bits (log2); storage uses ceil(log2).
    assert bits_needed(cardinality) == bits


@given(st.lists(st.integers(0, 2**19 - 1), min_size=0, max_size=500),
       st.integers(19, 32))
@settings(max_examples=50, deadline=None)
def test_pack_roundtrip_property(codes, bits):
    codes = np.asarray(codes, dtype=np.int64)
    packed = pack_bits(codes, bits)
    out = unpack_bits(packed, bits, codes.size)
    np.testing.assert_array_equal(out, codes)


@given(st.integers(1, 31), st.integers(0, 1000), st.integers(0, 2**31))
@settings(max_examples=60, deadline=None)
def test_pack_roundtrip_any_width(bits, n, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=n)
    packed = pack_bits(codes, bits)
    np.testing.assert_array_equal(unpack_bits(packed, bits, n), codes)


def test_unpack_jnp_matches_numpy():
    rng = np.random.default_rng(0)
    for bits in (1, 3, 6, 7, 13, 19, 32):
        codes = rng.integers(0, min(1 << bits, 1 << 31), size=257)
        packed = pack_bits(codes, bits)
        out = np.asarray(unpack_bits_jnp(packed, bits, codes.size))
        np.testing.assert_array_equal(out, codes)


def test_packed_nbytes():
    assert packed_nbytes(512 * 1024, 6) == 4 * ((512 * 1024 * 6 + 31) // 32)


@given(st.lists(st.integers(0, 7), min_size=0, max_size=300))
@settings(max_examples=50, deadline=None)
def test_rle_roundtrip(codes):
    codes = np.asarray(codes, dtype=np.int32)
    vals, lens = rle_encode(codes)
    np.testing.assert_array_equal(rle_decode(vals, lens), codes)
    if codes.size:
        out = np.asarray(rle_decode_jnp(vals, lens, codes.size))
        np.testing.assert_array_equal(out, codes)


# -- Dictionary ---------------------------------------------------------------
def test_dictionary_counts_and_stats():
    data = np.array([5, 5, 2, 9, 5, 2], dtype=np.int64)
    d, codes = Dictionary.from_data(data)
    assert d.cardinality == 3
    assert d.n_rows == 6
    np.testing.assert_array_equal(d.decode(codes), data)
    assert d.sum() == data.sum()
    assert d.mean() == pytest.approx(data.mean())
    assert d.std() == pytest.approx(data.std())
    assert d.vmin == 2 and d.vmax == 9


def test_dictionary_load_order_codes():
    # Paper: encodings are internal and may not follow value order.
    d, codes = Dictionary.from_data(np.array(["b", "a", "c", "a"]))
    assert d.values.tolist() == ["b", "a", "c"]
    np.testing.assert_array_equal(codes, [0, 1, 2, 1])


def test_dictionary_insert_maintenance():
    d, codes = Dictionary.from_data(np.array([1, 2, 1]))
    new_codes = d.add_rows(np.array([3, 2]))
    assert d.cardinality == 3
    assert d.n_rows == 5
    np.testing.assert_array_equal(d.decode(new_codes), [3, 2])
    d.remove_rows(new_codes[:1])
    assert d.n_rows == 4


@given(st.lists(st.integers(-50, 50), min_size=1, max_size=400))
@settings(max_examples=50, deadline=None)
def test_count_stats_match_scan_property(data):
    data = np.asarray(data, dtype=np.int64)
    col = Column.from_data(data, imcu_rows=64)
    assert stats.sum_from_dictionary(col) == pytest.approx(stats.sum_scan(col))
    assert stats.mean_from_dictionary(col) == pytest.approx(stats.mean_scan(col))
    assert stats.std_from_dictionary(col) == pytest.approx(stats.std_scan(col))
    assert stats.minmax_from_dictionary(col) == stats.minmax_scan(col)


def test_histogram_is_dictionary():
    col = Column.from_data(np.array([3, 1, 3, 3, 2]))
    v_d, c_d = stats.histogram_from_dictionary(col)
    v_s, c_s = stats.histogram_scan(col)
    d_map = dict(zip(v_d.tolist(), c_d.tolist()))
    s_map = dict(zip(v_s.tolist(), c_s.tolist()))
    assert d_map == s_map


def test_quantile_edges_from_counts():
    data = np.concatenate([np.full(75, 1), np.full(25, 10)])
    d, _ = Dictionary.from_data(data)
    edges = d.quantile_edges(4)
    assert edges.tolist() == [1.0, 1.0, 1.0]


# -- Column / IMCU --------------------------------------------------------------
def test_column_roundtrip_multi_imcu():
    rng = np.random.default_rng(1)
    data = rng.integers(0, 50, size=1000)
    col = Column.from_data(data, imcu_rows=128)
    np.testing.assert_array_equal(col.decode(), data)
    assert len(col._imcus) == 8


def test_column_rle_on_sorted_data():
    data = np.repeat(np.arange(10), 200)
    col = Column.from_data(data, imcu_rows=512, use_rle=True)
    col_no = Column.from_data(data, imcu_rows=512, use_rle=False)
    assert col.packed_nbytes < col_no.packed_nbytes
    np.testing.assert_array_equal(col.decode(), data)


def test_compression_ratio_string_column():
    # 'state-like' strings compress heavily (paper §5.1).
    states = np.array(["California", "Connecticut", "Oregon", "Virginia"])
    data = states[np.random.default_rng(0).integers(0, 4, size=10_000)]
    col = Column.from_data(data, use_rle=False)
    assert col.dictionary.bits == 2
    assert col.compression_ratio > 10


# -- query ops ----------------------------------------------------------------
def test_filter_mask_via_dictionary():
    data = np.array([10, 20, 30, 20, 10, 40])
    col = Column.from_data(data)
    mask = query.filter_mask(col, lambda v: v >= 20)
    np.testing.assert_array_equal(mask, data >= 20)


def test_groupby_count_zero_scan():
    col = Column.from_data(np.array(["a", "b", "a", "a"]))
    vals, counts = query.groupby_count(col)
    assert dict(zip(vals.tolist(), counts.tolist())) == {"a": 3, "b": 1}


def test_groupby_agg_sum_mean():
    key = Column.from_data(np.array(["x", "y", "x", "y"]))
    val = Column.from_data(np.array([1, 2, 3, 4]))
    kv, s = query.groupby_agg(key, val, "sum")
    assert dict(zip(kv.tolist(), s.tolist())) == {"x": 4.0, "y": 6.0}
    _, m = query.groupby_agg(key, val, "mean")
    assert dict(zip(kv.tolist(), m.tolist())) == {"x": 2.0, "y": 3.0}


def test_join_codes_inner():
    left = Column.from_data(np.array(["a", "b", "c"]))
    right = Column.from_data(np.array(["b", "b", "a"]))
    li, ri = query.join_codes(left, right)
    pairs = {(int(l), int(r)) for l, r in zip(li, ri)}
    assert pairs == {(0, 2), (1, 0), (1, 1)}


def test_table_projection_and_sizes():
    t = Table.from_data({"a": np.arange(100) % 7, "b": np.arange(100) % 3})
    assert t.select(["a"]).names == ["a"]
    assert t.total_nbytes < t.raw_nbytes()
    assert t.n_rows == 100
