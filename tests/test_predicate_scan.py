"""Property suite for the predicate-scan kernel: bits 1-16, word-boundary
straddles, empty/full match sets, post-refresh appends, composed predicates —
every path (Pallas kernel, XLA split, executor wiring) bit-exact against the
numpy host oracle that unpacks the SAME packed word streams.

``PREDICATE_SCAN_SWEEP=full`` widens the bit-width sweep from the smoke
subset to all of 1..16 (the nightly lane); the per-PR default keeps the
boundary-interesting widths.
"""
import os

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.columnar.bitpack import pack_bits
from repro.columnar.column import Column
from repro.columnar.table import Table
from repro.columnar import query as Q
from repro.core import FeaturePlan, FeatureExecutor
from repro.core.feature_spec import FeatureSet
from repro.core.pipeline import _pad32
from repro.kernels.bitunpack.kernel import tpu_width
from repro.kernels.hist import masked_hist
from repro.kernels.hist.ref import masked_hist_ref
from repro.kernels.predicate_scan import (ScanTerm, predicate_scan,
                                          predicate_scan_split, compact_rows,
                                          masked_counts)
from repro.kernels.predicate_scan.ref import (predicate_scan_ref,
                                              compact_rows_ref,
                                              masked_counts_ref)

# smoke: the widths where packing geometry changes (1 code/bit edge, the
# divisor widths, and straddle-forcing odd widths that repack to them);
# PREDICATE_SCAN_SWEEP=full = the nightly full 1..16 sweep
if os.environ.get("PREDICATE_SCAN_SWEEP") == "full":
    BITS = list(range(1, 17))
else:
    BITS = [1, 2, 3, 5, 8, 11, 16]


def _stream(rng, bits_list, n):
    """Build a multi-column resident-style flat stream at _pad32 capacity.

    Returns (flat_words jnp, word_offs, dbs, per-col codes, per-col words).
    """
    dbs, offs, parts, codes_list, words_list = [], [], [], [], []
    off = 0
    for bits in bits_list:
        db = tpu_width(bits)
        k = 1 << bits
        codes = rng.integers(0, k, n).astype(np.int32)
        w = pack_bits(codes, db)
        need = _pad32(n) * db // 32
        w = np.pad(w, (0, need - w.shape[0]))
        dbs.append(db)
        offs.append(off)
        off += need
        parts.append(w)
        codes_list.append(codes)
        words_list.append(w)
    return (jnp.asarray(np.concatenate(parts)), tuple(offs), tuple(dbs),
            codes_list, words_list)


def _random_terms(rng, bits_list, n_terms):
    terms = []
    for _ in range(n_terms):
        c = int(rng.integers(0, len(bits_list)))
        k = 1 << bits_list[c]
        if rng.integers(0, 2):                      # range term
            lo = int(rng.integers(0, k))
            hi = int(rng.integers(lo, k))
            terms.append(ScanTerm(col=c, kind=0, lo=lo, hi=hi))
        else:                                       # IN-set LUT term
            m = int(rng.integers(1, min(k, 8) + 1))
            lut = np.zeros(k, np.int32)
            lut[rng.choice(k, size=m, replace=False)] = 1
            terms.append(ScanTerm(col=c, kind=1, lut=lut))
    return terms


@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from(BITS),
       n=st.integers(1, 700),
       n_terms=st.integers(1, 3),
       seed=st.integers(0, 2**31 - 1),
       combine=st.sampled_from(["and", "or"]))
def test_scan_matches_reference(bits, n, n_terms, seed, combine):
    """Split path and Pallas kernel agree bit-exactly with the host oracle
    across widths, row counts off every word boundary, and composed
    multi-column AND/OR predicates."""
    rng = np.random.default_rng(seed)
    bits_list = [bits, int(rng.integers(1, 17))]
    flat, offs, dbs, _, words = _stream(rng, bits_list, n)
    terms = _random_terms(rng, bits_list, n_terms)
    ref = predicate_scan_ref(words, dbs, terms, n, combine)
    split = np.asarray(predicate_scan_split(flat, offs, dbs, terms, n,
                                            combine))
    np.testing.assert_array_equal(split, ref)
    pal = np.asarray(predicate_scan(flat, offs, dbs, terms, n, combine,
                                    bn=128))
    np.testing.assert_array_equal(pal, ref)


@pytest.mark.parametrize("bits", BITS)
def test_word_boundary_straddles(bits):
    """Rows on either side of every word boundary evaluate correctly: a
    predicate selecting exactly the rows adjacent to word seams must come
    back as exactly those rows."""
    rng = np.random.default_rng(bits)
    db = tpu_width(bits)
    s = 32 // db
    n = 4 * s + 3                     # several words + a partial tail word
    k = 1 << bits
    flat, offs, dbs, codes_list, words = _stream(rng, [bits], n)
    codes = codes_list[0]
    # mark the straddle-adjacent rows (last of word w, first of word w+1)
    seam_rows = [r for w in range(1, (n + s - 1) // s)
                 for r in (w * s - 1, w * s) if r < n]
    target = codes[seam_rows[0]]
    terms = [ScanTerm(col=0, kind=0, lo=int(target), hi=int(target))]
    ref = predicate_scan_ref(words, dbs, terms, n)
    for got in (predicate_scan_split(flat, offs, dbs, terms, n),
                predicate_scan(flat, offs, dbs, terms, n, bn=32)):
        np.testing.assert_array_equal(np.asarray(got), ref)
    np.testing.assert_array_equal(ref, codes == target)


@pytest.mark.parametrize("bits", [1, 4, 7, 16])
def test_empty_and_full_match_sets(bits):
    rng = np.random.default_rng(100 + bits)
    n = 333
    k = 1 << bits
    flat, offs, dbs, _, _ = _stream(rng, [bits], n)
    empty = [ScanTerm(col=0, kind=0, lo=1, hi=0)]          # hi < lo
    full = [ScanTerm(col=0, kind=0, lo=0, hi=k - 1)]
    assert not np.asarray(
        predicate_scan_split(flat, offs, dbs, empty, n)).any()
    assert not np.asarray(predicate_scan(flat, offs, dbs, empty, n)).any()
    assert np.asarray(predicate_scan_split(flat, offs, dbs, full, n)).all()
    assert np.asarray(predicate_scan(flat, offs, dbs, full, n)).all()
    lut_none = [ScanTerm(col=0, kind=1, lut=np.zeros(k, np.int32))]
    lut_all = [ScanTerm(col=0, kind=1, lut=np.ones(k, np.int32))]
    assert not np.asarray(
        predicate_scan_split(flat, offs, dbs, lut_none, n)).any()
    assert np.asarray(predicate_scan(flat, offs, dbs, lut_all, n)).all()


def test_term_validation():
    rng = np.random.default_rng(0)
    flat, offs, dbs, _, _ = _stream(rng, [4], 64)
    with pytest.raises(ValueError):
        predicate_scan_split(flat, offs, dbs, [], 64)
    with pytest.raises(ValueError):
        predicate_scan_split(flat, offs, dbs,
                             [ScanTerm(col=3, kind=0, lo=0, hi=1)], 64)
    with pytest.raises(ValueError):
        predicate_scan_split(flat, offs, dbs,
                             [ScanTerm(col=0, kind=0, lo=0, hi=1)], 64,
                             combine="xor")
    with pytest.raises(ValueError):
        predicate_scan(flat, offs, dbs,
                       [ScanTerm(col=0, kind=0, lo=0, hi=1)], 64, bn=100)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 500), seed=st.integers(0, 2**31 - 1))
def test_compact_rows_matches_reference(n, seed):
    rng = np.random.default_rng(seed)
    mask = rng.integers(0, 2, n).astype(bool)
    ref = compact_rows_ref(mask)
    cap = _pad32(max(int(mask.sum()), 1))
    got = np.asarray(compact_rows(jnp.asarray(mask), cap))[:ref.shape[0]]
    np.testing.assert_array_equal(got, ref)
    # fill rows past the valid prefix are the fill value (gatherable)
    full = np.asarray(compact_rows(jnp.asarray(mask), cap, fill=7))
    assert (full[ref.shape[0]:] == 7).all()


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from(BITS), n=st.integers(1, 600),
       seed=st.integers(0, 2**31 - 1))
def test_masked_counts_matches_reference(bits, n, seed):
    rng = np.random.default_rng(seed)
    flat, offs, dbs, codes_list, _ = _stream(rng, [bits], n)
    codes = codes_list[0]
    k = 1 << bits
    mask = rng.integers(0, 2, n).astype(bool)
    ref = masked_counts_ref(codes, mask, k)
    for use_kernel in (False, True):
        got = np.asarray(masked_counts(flat, offs[0], dbs[0],
                                       jnp.asarray(mask), k, n,
                                       use_kernel=use_kernel))
        np.testing.assert_array_equal(got, ref)
    # the hist-package masked variant agrees with ITS oracle too
    mh = np.asarray(masked_hist(jnp.asarray(codes), jnp.asarray(mask), k))
    np.testing.assert_array_equal(
        mh, np.asarray(masked_hist_ref(jnp.asarray(codes),
                                       jnp.asarray(mask), k)))
    np.testing.assert_array_equal(mh, ref)


def _plan_fixture(rng, n, imcu_rows=500):
    age = rng.integers(18, 91, n)
    state = rng.integers(0, 51, n)
    device = rng.integers(0, 5, n)
    t = Table({"age": Column.from_data(age, "age", imcu_rows=imcu_rows),
               "state": Column.from_data(state, "state",
                                         imcu_rows=imcu_rows),
               "device": Column.from_data(device, "device",
                                          imcu_rows=imcu_rows)})
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("device", "onehot"))
    return t, fs, age, state, device


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), use_kernel=st.booleans())
def test_executor_mask_matches_host_reference(seed, use_kernel):
    """Executor-level scan (resident flat stream, compiled predicate)
    agrees with the host per-IMCU mask path on both scan backends."""
    rng = np.random.default_rng(seed)
    t, fs, age, state, _ = _plan_fixture(rng, int(rng.integers(100, 2000)))
    plan = FeaturePlan(t, fs, packed=True)
    ex = FeatureExecutor(plan, use_kernel=use_kernel)
    pick = rng.choice(51, size=3, replace=False).tolist()
    lo = int(rng.integers(18, 91))
    pred = Q.isin("state", pick) & Q.ge("age", lo)
    exp = Q.predicate_mask_host(t, pred)
    np.testing.assert_array_equal(np.asarray(ex.predicate_mask(pred)), exp)
    np.testing.assert_array_equal(ex.filtered_rows(pred),
                                  np.flatnonzero(exp))


def test_post_refresh_append_scan():
    """Streaming appends (FeaturePlan.refresh with new_codes) extend the
    resident streams; the scan sees the appended rows bit-exactly —
    including appends that land mid-word and grow a dictionary."""
    rng = np.random.default_rng(7)
    t, fs, age, state, device = _plan_fixture(rng, 777)   # off every width
    plan = FeaturePlan(t, fs, packed=True)
    ex = FeatureExecutor(plan)
    pred = Q.between("age", 30, 40) | Q.eq("device", 2)
    age_all, dev_all = age.copy(), device.copy()
    for step in range(3):
        extra = 50 + 13 * step                            # mid-word tails
        na = rng.integers(18, 91, extra)
        ns = rng.integers(0, 51, extra)
        nd = rng.integers(0, 5, extra)
        new_codes = {"age": t["age"].dictionary.add_rows(na),
                     "state": t["state"].dictionary.add_rows(ns),
                     "device": t["device"].dictionary.add_rows(nd)}
        plan.refresh(new_codes)
        age_all = np.concatenate([age_all, na])
        dev_all = np.concatenate([dev_all, nd])
        exp = ((age_all >= 30) & (age_all <= 40)) | (dev_all == 2)
        got = np.asarray(ex.predicate_mask(pred))
        assert got.shape[0] == age_all.shape[0]
        np.testing.assert_array_equal(got, exp)
        rows, feats = ex.batch_where(pred)
        np.testing.assert_array_equal(rows, np.flatnonzero(exp))
        np.testing.assert_array_equal(np.asarray(feats),
                                      np.asarray(ex.batch(rows)))
