"""Front door suite: request classes, admission control, priority pump
scheduling, and the serving-accounting bugfix regressions.

What the tentpole guarantees, stated as invariants:

- priority classes drain strictly by priority when aging is off, and a
  starved low-priority class JUMPS the queue once it has aged past the
  high-priority class (anti-starvation) — both observable from per-class
  latency extrema after a paused-stage / resume drain;
- admission is BOUNDED by construction: ``max_inflight + queue_depth``
  outstanding per class, then a typed :class:`Overloaded` carrying a
  retry-after hint; a rejected submit enqueues nothing, an admitted one
  is never dropped (availability over admitted work stays 1.0);
- the three accounting bugs stay fixed: percentiles cover EVERY
  completed ticket (not the ``latencies`` deque's sliding window),
  ``throughput_stats`` is JSON-safe at ``wall_s == 0`` (no ``inf``), and
  pending work is reported as *pending*, not failed-availability.

Deterministic by construction where it matters: ordering tests stage
work while the pumps are PAUSED, so the drain order on resume depends
only on the scheduler's class selection, not on submission timing. The
randomized sweep reads ``FRONTEND_SWEEP_SEEDS`` (nightly raises it).
"""
import asyncio
import json
import os
import time
from collections import deque

import numpy as np
import pytest

from repro.columnar import Table
from repro.core import FeatureSet, FeaturePlan, FeatureExecutor
from repro.serve import (DeadlineExceeded, FaultInjector, FaultPolicy,
                         FeatureFrontend, FeatureService, LatencyHistogram,
                         Overloaded, RequestClass, ServeError,
                         default_classes)


def _mixed_table(n=3000, imcu_rows=700, seed=0):
    rng = np.random.default_rng(seed)
    t = Table.from_data({
        "age": rng.integers(18, 80, n),
        "state": np.array(["CA", "OR", "WA", "NY"])[rng.integers(0, 4, n)],
        "income": rng.integers(20, 200, n) * 1000,
    }, imcu_rows=imcu_rows)
    fs = (FeatureSet().add("age", "zscore").add("state", "onehot")
          .add("income", "minmax"))
    return t, fs


def _reference(t, fs, requests):
    ex = FeatureExecutor(FeaturePlan(t, fs))
    return [np.asarray(ex.batch(r)) for r in requests]


def _svc(classes, **kw):
    t, fs = _mixed_table()
    return t, fs, FeatureService(FeaturePlan(t, fs), classes=classes, **kw)


# -- request classes / construction --------------------------------------------------
def test_request_class_validation():
    with pytest.raises(ValueError):
        RequestClass("")
    with pytest.raises(ValueError):
        RequestClass("x", priority=-1)
    with pytest.raises(ValueError):
        RequestClass("x", deadline_ms=0)
    with pytest.raises(ValueError):
        RequestClass("x", max_inflight=0)
    with pytest.raises(ValueError):
        RequestClass("x", queue_depth=-1)
    with pytest.raises(ValueError):
        RequestClass("x", coalesce=0)
    with pytest.raises(ValueError):
        RequestClass("x", aging_s=0)
    names = [rc.name for rc in default_classes()]
    assert names == ["interactive", "batch", "background"]


def test_service_rejects_duplicate_and_unknown_classes():
    t, fs = _mixed_table(n=1400)
    with pytest.raises(ValueError):
        FeatureService(FeaturePlan(t, fs),
                       classes=(RequestClass("a"), RequestClass("a")))
    with FeatureService(FeaturePlan(t, fs),
                        classes=(RequestClass("a"),)) as svc:
        with pytest.raises(ValueError):
            svc.submit(np.arange(8), klass="nope")
        assert set(svc.classes) == {"default", "a"}


def test_frontend_needs_classes():
    t, fs = _mixed_table(n=1400)
    with FeatureService(FeaturePlan(t, fs)) as svc:
        with pytest.raises(ValueError):
            FeatureFrontend(svc)
    with FeatureService(FeaturePlan(t, fs),
                        classes=default_classes()) as svc:
        with pytest.raises(ValueError):
            FeatureFrontend(svc, default_klass="nope")
        fe = FeatureFrontend(svc)
        # default class is the highest-priority one
        assert fe.default_klass == "interactive"
        with pytest.raises(ValueError):
            fe.submit(np.arange(8), klass="nope")


# -- LatencyHistogram: the unbiased-p99 fix ------------------------------------------
def test_histogram_unbiased_where_sliding_window_lies():
    """The bug this fixes: a maxlen deque forgets the slow head of a long
    run, so its p99 collapses to the recent fast tail. The histogram
    sees every sample."""
    window = deque(maxlen=64)                  # the old accounting
    hist = LatencyHistogram()
    for _ in range(100):                       # slow early phase: 100 ms
        window.append(0.1)
        hist.record(0.1)
    for _ in range(900):                       # fast steady state: 1 ms
        window.append(0.001)
        hist.record(0.001)
    # the window only holds recent fast samples -> biased p99
    assert np.percentile(window, 99) == pytest.approx(0.001)
    # the histogram still knows 10% of all samples took 100 ms
    assert hist.count == 1000
    assert hist.percentile(99) == pytest.approx(0.1, rel=0.15)
    assert hist.percentile(50) == pytest.approx(0.001, rel=0.15)
    assert hist.mean_s == pytest.approx(0.0109, rel=1e-6)
    s = hist.summary()
    assert s["samples"] == 1000
    assert s["min_ms"] == pytest.approx(1.0)
    assert s["max_ms"] == pytest.approx(100.0)
    json.dumps(s, allow_nan=False)


def test_histogram_edges_and_merge():
    h = LatencyHistogram()
    assert h.percentile(99) == 0.0 and h.mean_s == 0.0
    assert h.summary()["min_ms"] == 0.0
    with pytest.raises(ValueError):
        h.percentile(101)
    with pytest.raises(ValueError):
        LatencyHistogram(lo_s=0)
    # out-of-range samples clamp to edge buckets but report exact extrema
    h.record(1e-9)
    h.record(5e3)
    assert h.percentile(0) == pytest.approx(1e-9)
    assert h.percentile(100) == pytest.approx(5e3)
    other = LatencyHistogram()
    other.record(0.01)
    h.merge(other)
    assert h.count == 3 and h.max_s == 5e3
    with pytest.raises(ValueError):
        h.merge(LatencyHistogram(buckets_per_decade=12))


def test_service_percentiles_cover_all_ticket_history():
    """Regression for the window-biased p99: shrink the bench-compat deque
    far below the request count — ``latency_samples_total`` and the
    streaming histogram must still cover every completed ticket."""
    t, fs, svc = _svc((RequestClass("interactive", priority=3),))
    with svc:
        svc.latencies = deque(maxlen=32)       # forced tiny window
        fe = FeatureFrontend(svc)
        for i in range(100):
            fe.submit(np.arange(i % 600, i % 600 + 24),
                      klass="interactive")
        fe.collect()
        assert svc.stats["latency_samples_total"] == 100
        assert len(svc.latencies) == 32        # deque saturated...
        cs = svc.class_stats()["interactive"]
        assert cs["samples"] == cs["completed"] == 100
        assert svc.latency_percentile(99) > 0.0
        assert svc.latency_percentile(99, "interactive") > 0.0
        # a fresh observation window zeroes coverage but not the ledger
        svc.reset_latency_window()
        assert svc.stats["latency_samples_total"] == 0
        assert len(svc.latencies) == 0
        assert svc.class_stats()["interactive"]["samples"] == 0
        assert svc.class_stats()["interactive"]["completed"] == 100


# -- throughput_stats: inf + availability fixes --------------------------------------
def test_throughput_stats_json_safe_at_zero_wall():
    """Regression: ``wall_s <= 0`` used to yield rows_per_s = inf, which
    json.dump renders as the non-standard ``Infinity`` token."""
    t, fs, svc = _svc(None)
    with svc:
        tk = svc.submit(np.arange(64))
        svc.result(tk, timeout=30)
        for wall in (0.0, -1.0):
            st = svc.throughput_stats(wall)
            assert st["wall_s_invalid"] is True
            assert st["rows_per_s"] == 0.0
            json.dumps(st, allow_nan=False)
        ok = svc.throughput_stats(1.0)
        assert ok["wall_s_invalid"] is False
        assert ok["rows_per_s"] == pytest.approx(64.0)


def test_availability_reports_pending_not_failed():
    """Regression: mid-flight ``throughput_stats`` used to count still-
    pending tickets as availability loss (completed/requests). Pending
    work is pending; availability covers resolved tickets only."""
    t, fs, svc = _svc(None)
    with svc:
        svc.pause()
        tks = [svc.submit(np.arange(16 * i, 16 * i + 16)) for i in range(3)]
        st = svc.throughput_stats(1.0)
        assert st["pending"] == 3
        assert st["completed"] == 0
        assert st["availability"] == 1.0       # nothing RESOLVED failed
        svc.resume()
        for tk in tks:
            svc.result(tk, timeout=30)
        st = svc.throughput_stats(1.0)
        assert st["pending"] == 0
        assert st["completed"] == 3 and st["availability"] == 1.0


# -- priority pump scheduling --------------------------------------------------------
def test_priority_classes_drain_strictly_by_priority():
    """Paused-stage background FIRST, interactive second, with aging
    effectively off (huge aging_s): on resume the pump must drain ALL
    interactive before any background, so every background latency
    exceeds every interactive latency (background also started its clock
    earlier — the inequality is doubly forced)."""
    t, fs, svc = _svc((
        RequestClass("interactive", priority=3, aging_s=1000.0),
        RequestClass("background", priority=1, aging_s=1000.0),
    ))
    reqs_bg = [np.arange(700 * 2 + 32 * i, 700 * 2 + 32 * i + 32)
               for i in range(6)]
    reqs_in = [np.arange(32 * i, 32 * i + 32) for i in range(6)]
    want = _reference(t, fs, reqs_bg + reqs_in)
    with svc:
        svc.pause()
        tks = [svc.submit(r, klass="background") for r in reqs_bg]
        tks += [svc.submit(r, klass="interactive") for r in reqs_in]
        svc.resume()
        got = [svc.result(tk, timeout=60) for tk in tks]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    cs = svc.class_stats()
    assert cs["interactive"]["completed"] == 6
    assert cs["background"]["completed"] == 6
    assert cs["interactive"]["max_ms"] < cs["background"]["min_ms"]


def test_aging_rescues_background_from_starvation():
    """The inverse staging: a large interactive flood ahead of two
    background chunks whose aging_s is tiny. Strict priority would drain
    background LAST (max background latency above max interactive);
    anti-starvation aging must pull it forward instead."""
    t, fs, svc = _svc((
        RequestClass("interactive", priority=3, aging_s=1000.0),
        RequestClass("background", priority=1, aging_s=0.001),
    ))
    reqs_in = [np.arange(s, s + 48) for s in
               np.linspace(0, 2300, 60).astype(int)]
    reqs_bg = [np.arange(1400 + 64 * i, 1400 + 64 * i + 64)
               for i in range(2)]
    with svc:
        svc.pause()
        tks = [svc.submit(r, klass="interactive") for r in reqs_in]
        tks += [svc.submit(r, klass="background") for r in reqs_bg]
        svc.resume()
        for tk in tks:
            svc.result(tk, timeout=60)
    cs = svc.class_stats()
    assert cs["background"]["completed"] == 2
    # background finished BEFORE the interactive flood drained: submitted
    # after every interactive request yet completed with smaller latency
    assert cs["background"]["max_ms"] < cs["interactive"]["max_ms"]


# -- admission control ---------------------------------------------------------------
def test_admission_bounds_and_recovers():
    t, fs, svc = _svc((
        RequestClass("interactive", priority=3, max_inflight=2,
                     queue_depth=2),
    ))
    reqs = [np.arange(24 * i, 24 * i + 24) for i in range(5)]
    want = _reference(t, fs, reqs[:4])
    with svc:
        fe = FeatureFrontend(svc)
        svc.pause()
        tks = [fe.submit(r, tenant="app") for r in reqs[:4]]
        with pytest.raises(Overloaded) as ei:
            fe.submit(reqs[4], tenant="app")
        e = ei.value
        assert e.klass == "interactive" and e.tenant == "app"
        assert e.outstanding == 4 and e.bound == 4
        assert e.retry_after_s > 0
        st = fe.stats()
        adm = st["classes"]["interactive"]
        assert adm["admitted"] == 4 and adm["rejected"] == 1
        assert adm["admitted_queued"] == 2     # past max_inflight=2
        assert adm["outstanding"] == 4
        assert st["tenants"]["app"] == {
            "requests": 5, "admitted": 4, "rejected": 1}
        svc.resume()
        got = [fe.result(tk, timeout=30) for tk in tks]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
        # retrieval freed the window: the rejected request now admits
        tk = fe.submit(reqs[4], tenant="app")
        np.testing.assert_array_equal(
            fe.result(tk, timeout=30),
            _reference(t, fs, [reqs[4]])[0])
        st = fe.stats()
        assert st["classes"]["interactive"]["outstanding"] == 0
        assert st["availability_admitted"] == 1.0


def test_admission_zero_queue_depth_rejects_at_window():
    t, fs, svc = _svc((
        RequestClass("solo", max_inflight=1, queue_depth=0),))
    with svc:
        fe = FeatureFrontend(svc)
        svc.pause()
        fe.submit(np.arange(16))
        with pytest.raises(Overloaded):
            fe.submit(np.arange(16))
        svc.resume()
        fe.collect()
        fe.submit(np.arange(16))               # window freed
        fe.collect()


def test_admission_slot_survives_timeout_releases_on_error():
    """The window frees on OUTCOME retrieval: a plain wait timeout keeps
    the slot (the ticket is still outstanding); a resolved typed error or
    an unknown ticket releases it."""
    t, fs = _mixed_table()
    inj = FaultInjector().delay_launches(0.25, 1, shard=0)
    with FeatureService(FeaturePlan(t, fs), faults=inj,
                        classes=(RequestClass("a", max_inflight=1,
                                              queue_depth=0),)) as svc:
        fe = FeatureFrontend(svc)
        tk = fe.submit(np.arange(32))
        with pytest.raises(TimeoutError):
            fe.result(tk, timeout=0.01)
        assert fe.stats()["classes"]["a"]["outstanding"] == 1
        with pytest.raises(Overloaded):
            fe.submit(np.arange(32))           # slot still held
        np.testing.assert_array_equal(
            fe.result(tk, timeout=30),
            _reference(t, _mixed_table()[1], [np.arange(32)])[0])
        assert fe.stats()["classes"]["a"]["outstanding"] == 0
        # unknown ticket: KeyError propagates, release is a no-op
        with pytest.raises(KeyError):
            fe.result(999_999)
        assert fe.stats()["classes"]["a"]["outstanding"] == 0


def test_admission_releases_on_serve_error():
    t, fs = _mixed_table()
    inj = FaultInjector().fail_launches(10, shard=0)
    pol = FaultPolicy(max_retries=1, backoff_s=0.001, breaker_fails=100)
    with FeatureService(FeaturePlan(t, fs), faults=inj, fault_policy=pol,
                        classes=(RequestClass("a", max_inflight=1,
                                              queue_depth=0),)) as svc:
        fe = FeatureFrontend(svc)
        tk = fe.submit(np.arange(16))
        with pytest.raises(ServeError):
            fe.result(tk, timeout=30)
        st = fe.stats()
        assert st["classes"]["a"]["outstanding"] == 0
        assert st["classes"]["a"]["failed"] == 1
        assert st["availability_admitted"] == 0.0


# -- per-class deadlines -------------------------------------------------------------
def test_class_default_deadline_applies_and_overrides():
    t, fs, svc = _svc((
        RequestClass("tight", deadline_ms=20.0),))
    with svc:
        fe = FeatureFrontend(svc)
        svc.pause()
        tk_default = fe.submit(np.arange(24))              # class's 20 ms
        tk_long = fe.submit(np.arange(24), deadline_ms=60_000.0)
        time.sleep(0.08)                                   # age past 20 ms
        svc.resume()
        with pytest.raises(DeadlineExceeded):
            fe.result(tk_default, timeout=30)
        np.testing.assert_array_equal(
            fe.result(tk_long, timeout=30),
            _reference(t, fs, [np.arange(24)])[0])
        st = fe.stats()
        assert st["classes"]["tight"]["outstanding"] == 0
        assert st["classes"]["tight"]["failed"] == 1


# -- class-scoped fault injection ----------------------------------------------------
def test_faults_scope_to_request_class():
    inj = (FaultInjector().fail_launches(2, klass="batch"))
    with pytest.raises(Exception):
        inj.before_launch(0, 0, klass="batch")
    inj.before_launch(0, 0, klass="interactive")           # unscoped: fine
    inj.before_launch(0, 0)                                # classless: fine
    with pytest.raises(Exception):
        inj.before_launch(1, 2, klass="batch")
    assert inj.faults_injected == 2


def test_class_scoped_chaos_isolates_one_tenant_class():
    """Inject enough class-scoped faults that every batch launch fails
    through its retries: batch tickets resolve to typed ServeErrors while
    interactive work completes bit-exact — per-tenant-class blast radius."""
    t, fs = _mixed_table()
    inj = FaultInjector().fail_launches(50, klass="batch")
    pol = FaultPolicy(max_retries=1, backoff_s=0.001, breaker_fails=1000)
    reqs_in = [np.arange(48 * i, 48 * i + 48) for i in range(4)]
    reqs_ba = [np.arange(1400 + 48 * i, 1400 + 48 * i + 48)
               for i in range(3)]
    want = _reference(t, fs, reqs_in)
    with FeatureService(FeaturePlan(t, fs), faults=inj, fault_policy=pol,
                        classes=(RequestClass("interactive", priority=3),
                                 RequestClass("batch", priority=2)),
                        ) as svc:
        fe = FeatureFrontend(svc)
        tks_in = [fe.submit(r, klass="interactive") for r in reqs_in]
        tks_ba = [fe.submit(r, klass="batch") for r in reqs_ba]
        for tk, w in zip(tks_in, want):
            np.testing.assert_array_equal(fe.result(tk, timeout=60), w)
        for tk in tks_ba:
            with pytest.raises(ServeError):
                fe.result(tk, timeout=60)
    cs = svc.class_stats()
    assert cs["interactive"]["completed"] == 4
    assert cs["interactive"]["failed"] == 0
    assert cs["batch"]["failed"] == 3
    assert inj.faults_injected >= 6            # 3 tickets x (1 + 1 retry)


# -- the async + dict edges ----------------------------------------------------------
def test_async_featurize_bit_exact():
    t, fs, svc = _svc(default_classes())
    reqs = [np.arange(64), np.arange(800, 880), np.arange(1500, 1532)]
    want = _reference(t, fs, reqs)

    async def go(fe):
        return await asyncio.gather(
            fe.featurize(reqs[0], klass="interactive"),
            fe.featurize(reqs[1], klass="batch"),
            fe.featurize(reqs[2], klass="background"),
        )

    with svc:
        fe = FeatureFrontend(svc)
        got = asyncio.run(go(fe))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    assert fe.stats()["availability_admitted"] == 1.0


def test_handle_request_response_contract():
    t, fs, svc = _svc((
        RequestClass("interactive", priority=3, max_inflight=1,
                     queue_depth=0),
        RequestClass("batch", priority=2),
    ))
    want = _reference(t, fs, [np.arange(40)])[0]
    with svc:
        fe = FeatureFrontend(svc)
        r = fe.handle({"op": "featurize", "rows": np.arange(40),
                       "klass": "batch", "tenant": "app"})
        assert r["ok"] and isinstance(r["ticket"], int)
        out = fe.handle({"op": "result", "ticket": r["ticket"],
                         "timeout": 30})
        assert out["ok"]
        np.testing.assert_array_equal(out["features"], want)
        # stats endpoint must serialize strictly (the inf regression)
        st = fe.handle({"op": "stats"})
        assert st["ok"]
        json.dumps(st["stats"], allow_nan=False)
        # typed failure paths come back as tagged responses, not raises
        assert fe.handle({"op": "transmogrify"})["error"] == "bad_request"
        assert fe.handle({"op": "result", "ticket": 12345}
                         )["error"] == "unknown_ticket"
        assert fe.handle({"op": "featurize", "rows": [0, 1],
                          "klass": "nope"})["error"] == "bad_request"
        svc.pause()
        t1 = fe.handle({"op": "featurize", "rows": np.arange(8),
                        "klass": "interactive"})
        assert t1["ok"]
        over = fe.handle({"op": "featurize", "rows": np.arange(8),
                          "klass": "interactive", "tenant": "greedy"})
        assert over["error"] == "overloaded"
        assert over["klass"] == "interactive"
        assert over["tenant"] == "greedy"
        assert over["retry_after_ms"] > 0
        svc.resume()
        fe.collect()


# -- randomized sweep (nightly raises FRONTEND_SWEEP_SEEDS) --------------------------
@pytest.mark.parametrize("seed", range(int(
    os.environ.get("FRONTEND_SWEEP_SEEDS", 2))))
def test_frontend_sweep_mixed_classes_bit_exact(seed):
    """Randomized mixed-class traffic through the front door: whatever
    the class mix and admission pressure, every admitted ticket resolves
    bit-exact vs the fault-free reference and the ledger balances
    (availability 1.0, nothing pending, histogram covers everything)."""
    rng = np.random.default_rng(100 + seed)
    t, fs, svc = _svc((
        RequestClass("interactive", priority=3, coalesce=1, linger_us=0,
                     max_inflight=64, queue_depth=64),
        RequestClass("batch", priority=2, max_inflight=64, queue_depth=64),
        RequestClass("background", priority=1, aging_s=0.01,
                     max_inflight=64, queue_depth=64),
    ))
    names = ("interactive", "batch", "background")
    reqs = []
    for _ in range(24):
        lo = int(rng.integers(0, 2900))
        n = int(rng.integers(8, 96))
        reqs.append((np.arange(lo, min(lo + n, 3000)),
                     names[int(rng.integers(0, 3))]))
    want = _reference(t, fs, [r for r, _ in reqs])
    with svc:
        fe = FeatureFrontend(svc)
        tks = [fe.submit(r, klass=k, tenant=f"t{i % 3}")
               for i, (r, k) in enumerate(reqs)]
        got = [fe.result(tk, timeout=60) for tk in tks]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    st = fe.stats()
    assert st["availability_admitted"] == 1.0
    assert sum(c["outstanding"] for c in st["classes"].values()) == 0
    assert sum(c["pending"] for c in st["classes"].values()) == 0
    assert svc.stats["latency_samples_total"] == 24
    ts = svc.throughput_stats(1.0)
    assert ts["availability"] == 1.0 and ts["pending"] == 0
    json.dumps(st, allow_nan=False)
