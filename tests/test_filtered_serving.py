"""Filtered serving end-to-end: predicate compiler, executor/sharded
pushdown (scan -> compact -> local gather), FeatureService submit(where=),
dict-aware masked aggregates, and the query.py bugfix regressions
(per-IMCU filter_mask decode, vectorized join_codes).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.columnar.column import Column
from repro.columnar.table import Table
from repro.columnar import query as Q
from repro.core import FeaturePlan, FeatureExecutor, ShardedFeatureExecutor
from repro.core.feature_spec import FeatureSet
from repro.serve.feature_service import FeatureService


def _fixture(seed=0, n=4000, imcu_rows=700):
    rng = np.random.default_rng(seed)
    age = rng.integers(18, 91, n)
    state = rng.integers(0, 51, n)
    income = np.round(rng.lognormal(10, 1, n), -2)
    device = rng.integers(0, 5, n)
    t = Table({"age": Column.from_data(age, "age", imcu_rows=imcu_rows),
               "state": Column.from_data(state, "state",
                                         imcu_rows=imcu_rows),
               "income": Column.from_data(income, "income",
                                          imcu_rows=imcu_rows),
               "device": Column.from_data(device, "device",
                                          imcu_rows=imcu_rows)})
    fs = (FeatureSet().add("age", "zscore")
          .add("state", "onehot")
          .add("income", "minmax").add("income", "log")
          .add("device", "onehot"))
    return t, fs, dict(age=age, state=state, income=income, device=device)


PRED = Q.isin("state", [3, 7, 11]) & Q.gt("age", 60)


def _expected_mask(cols):
    return np.isin(cols["state"], [3, 7, 11]) & (cols["age"] > 60)


# -- predicate AST + compiler -------------------------------------------------------
def test_predicate_compile_classification():
    t, _, _ = _fixture()
    dicts = {c: t[c].dictionary for c in t.columns}
    # equality on any dictionary is a 1-wide range
    cp = Q.compile_predicate(Q.eq("device", 2), dicts)
    (term,) = cp.terms
    assert term.kind == 0 and term.lo == term.hi
    # a value range over load-order codes is (generically) a LUT
    cp = Q.compile_predicate(Q.between("state", 10, 20), dicts)
    assert cp.terms[0].kind in (0, 1)
    lut_term = Q.compile_predicate(Q.isin("state", [1, 17, 40]),
                                   dicts).terms[0]
    assert lut_term.match.shape[0] == 3
    # sorted dictionary -> range compiles to kind 0
    d_sorted, codes = __import__(
        "repro.columnar.dictionary",
        fromlist=["Dictionary"]).Dictionary.from_data(
            np.arange(100) % 37, sort_values=True)
    cp = Q.compile_predicate(Q.between("x", 5, 11), {"x": d_sorted})
    assert cp.terms[0].kind == 0
    # empty match set compiles to the hi < lo empty range
    cp = Q.compile_predicate(Q.eq("device", 99), dicts)
    assert cp.terms[0].kind == 0 and cp.terms[0].hi < cp.terms[0].lo
    with pytest.raises(KeyError):
        Q.compile_predicate(Q.eq("nope", 1), dicts)


def test_predicate_mixed_combinators_raise():
    with pytest.raises(ValueError):
        (Q.eq("a", 1) & Q.eq("b", 2)) | Q.eq("c", 3)
    with pytest.raises(ValueError):
        (Q.eq("a", 1) | Q.eq("b", 2)) & Q.eq("c", 3)
    # same-op composition flattens
    p = Q.eq("a", 1) & Q.eq("b", 2) & Q.eq("c", 3)
    assert len(p.parts) == 3 and p.op == "and"


# -- query.py bugfix regressions ----------------------------------------------------
def test_filter_mask_decodes_per_imcu_only():
    """Regression: filter_mask must never materialize the full code stream
    (col.codes()) — pruning leaves few live IMCUs and only those decode."""
    rng = np.random.default_rng(1)
    # clustered values so IMCU min/max pruning actually prunes
    data = np.repeat(np.arange(8), 500)
    col = Column.from_data(data, "clustered", imcu_rows=500, use_rle=False)
    full_decodes = []
    orig = Column.codes
    Column.codes = lambda self: full_decodes.append(1) or orig(self)
    try:
        mask = Q.filter_mask(col, lambda v: v == 3)
    finally:
        Column.codes = orig
    assert not full_decodes, "filter_mask decoded the WHOLE column"
    np.testing.assert_array_equal(mask, data == 3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nl=st.integers(0, 150),
       nr=st.integers(0, 150))
def test_join_codes_vectorized(seed, nl, nr):
    rng = np.random.default_rng(seed)
    l = Column.from_data(rng.integers(0, 12, max(nl, 1)), "k")
    r = Column.from_data(rng.integers(4, 18, max(nr, 1)), "k")
    li, ri = Q.join_codes(l, r)
    lv = l.dictionary.values[l.codes()]
    rv = r.dictionary.values[r.codes()]
    expected = {(i, j) for i in range(lv.shape[0])
                for j in range(rv.shape[0]) if lv[i] == rv[j]}
    assert set(zip(li.tolist(), ri.tolist())) == expected
    assert li.shape[0] == len(expected)
    np.testing.assert_array_equal(lv[li], rv[ri])


# -- executor pushdown --------------------------------------------------------------
def test_executor_filtered_rows_and_batch_where():
    t, fs, cols = _fixture()
    plan = FeaturePlan(t, fs, packed=True)
    ex = FeatureExecutor(plan)
    exp = _expected_mask(cols)
    assert ex.count_where(PRED) == int(exp.sum())
    rows = ex.filtered_rows(PRED)
    np.testing.assert_array_equal(rows, np.flatnonzero(exp))
    r2, feats = ex.batch_where(PRED)
    np.testing.assert_array_equal(r2, rows)
    np.testing.assert_array_equal(np.asarray(feats),
                                  np.asarray(ex.batch(rows)))
    # empty selection
    r0, f0 = ex.batch_where(Q.eq("state", 12345))
    assert r0.shape == (0,) and f0.shape == (0, plan.out_dim)


def test_executor_pushdown_guards():
    t, fs, _ = _fixture(n=500)
    plan32 = FeaturePlan(t, fs, packed=False)
    ex = FeatureExecutor(plan32)
    with pytest.raises(RuntimeError):
        ex.predicate_mask(PRED)
    plan = FeaturePlan(t, fs, packed=True)
    exp = FeatureExecutor(plan)
    with pytest.raises(KeyError):
        exp.groupby_where("not_a_column", PRED)


def test_masked_aggregates_dict_aware():
    t, fs, cols = _fixture()
    plan = FeaturePlan(t, fs, packed=True)
    ex = FeatureExecutor(plan)
    exp = _expected_mask(cols)
    vals, counts = ex.groupby_where("device", PRED)
    np.testing.assert_array_equal(
        counts, np.bincount(t["device"].codes()[exp], minlength=5))
    np.testing.assert_array_equal(vals, t["device"].dictionary.values)
    assert ex.agg_where(PRED, "age", "count") == exp.sum()
    assert np.isclose(ex.agg_where(PRED, "age", "sum"),
                      cols["age"][exp].sum())
    assert np.isclose(ex.agg_where(PRED, "age", "mean"),
                      cols["age"][exp].mean())
    # empty selection mean is NaN, count/sum 0
    none = Q.eq("state", 777)
    assert ex.agg_where(none, "age", "count") == 0
    assert ex.agg_where(none, "age", "sum") == 0.0
    assert np.isnan(ex.agg_where(none, "age", "mean"))
    with pytest.raises(ValueError):
        ex.agg_where(PRED, "age", "median")


# -- sharded pushdown ---------------------------------------------------------------
def test_sharded_pushdown_serves_matches_locally():
    t, fs, cols = _fixture()
    plan = FeaturePlan(t, fs, packed=True)
    sx = ShardedFeatureExecutor(plan)
    assert sx.n_shards > 1
    exp = _expected_mask(cols)
    assert sx.count_where(PRED) == int(exp.sum())
    np.testing.assert_array_equal(sx.filtered_rows(PRED),
                                  np.flatnonzero(exp))
    rows, feats = sx.batch_where(PRED)
    np.testing.assert_array_equal(rows, np.flatnonzero(exp))
    ref = FeatureExecutor(FeaturePlan(t, fs, packed=True)).batch(rows)
    np.testing.assert_allclose(np.asarray(feats), np.asarray(ref),
                               rtol=1e-6)
    vals, counts = sx.groupby_where("device", PRED)
    np.testing.assert_array_equal(
        counts, np.bincount(t["device"].codes()[exp], minlength=5))
    assert np.isclose(sx.agg_where(PRED, "age", "mean"),
                      cols["age"][exp].mean())


# -- service submit(where=) ---------------------------------------------------------
@pytest.mark.parametrize("sharded", [False, True])
def test_service_filtered_submit(sharded):
    t, fs, cols = _fixture()
    plan = FeaturePlan(t, fs, packed=True)
    exp_rows = np.flatnonzero(_expected_mask(cols))
    with FeatureService(plan, sharded=sharded) as svc:
        ref = svc.result(svc.submit(exp_rows))
        out = svc.result(svc.submit(where=PRED))
        np.testing.assert_array_equal(out, ref)
        assert out.shape == (exp_rows.shape[0], plan.out_dim)
        assert svc.stats["filtered_requests"] == 1
        # service-level query helpers
        assert svc.count_where(PRED) == exp_rows.shape[0]
        np.testing.assert_array_equal(svc.filtered_rows(PRED), exp_rows)
        _, counts = svc.groupby_where("device", PRED)
        assert counts.sum() == exp_rows.shape[0]
        assert np.isclose(svc.agg_where(PRED, "age", "mean"),
                          cols["age"][_expected_mask(cols)].mean())


def test_service_filtered_empty_selection_short_circuits():
    t, fs, _ = _fixture(n=600)
    plan = FeaturePlan(t, fs, packed=True)
    with FeatureService(plan) as svc:
        tk = svc.submit(where=Q.eq("state", 99999))
        assert svc.poll(tk)                       # already on host
        out = svc.result(tk)
        assert out.shape == (0, plan.out_dim)
        assert svc.stats["filtered_requests"] == 1
        assert svc.stats["launches"] == 0         # nothing hit the pump


def test_service_filtered_guards():
    t, fs, cols = _fixture(n=600)
    plan32 = FeaturePlan(t, fs, packed=False)
    with FeatureService(plan32) as svc:
        with pytest.raises(RuntimeError):
            svc.submit(where=PRED)
        with pytest.raises(RuntimeError):
            svc.count_where(PRED)
        with pytest.raises(ValueError):
            svc.submit()
    plan = FeaturePlan(t, fs, packed=True)
    with FeatureService(plan) as svc:
        with pytest.raises(ValueError):
            svc.submit(np.arange(4), where=PRED)


def test_service_filtered_interleaves_with_plain_requests():
    t, fs, cols = _fixture()
    plan = FeaturePlan(t, fs, packed=True)
    exp_rows = np.flatnonzero(_expected_mask(cols))
    rng = np.random.default_rng(5)
    with FeatureService(plan, sharded=True) as svc:
        plain = [rng.integers(0, t.n_rows, 200) for _ in range(4)]
        tickets = []
        for i, rows in enumerate(plain):
            tickets.append(("plain", rows, svc.submit(rows)))
            tickets.append(("where", None, svc.submit(where=PRED)))
        ref_ex = FeatureExecutor(FeaturePlan(t, fs, packed=True))
        where_ref = np.asarray(ref_ex.batch(exp_rows))
        for kind, rows, tk in tickets:
            out = svc.result(tk)
            if kind == "plain":
                np.testing.assert_allclose(
                    out, np.asarray(ref_ex.batch(rows)), rtol=1e-6)
            else:
                np.testing.assert_allclose(out, where_ref, rtol=1e-6)
